"""RL substrate: synthetic volumes, environment semantics, DQN learning."""

import numpy as np

from repro.configs.adfll_dqn import DQNConfig
from repro.core.erb import TaskTag, erb_init
from repro.rl.agent import DQNAgent
from repro.rl.env import LandmarkEnv
from repro.rl.synth import (
    MODALITIES,
    ORIENTATIONS,
    PATHOLOGIES,
    all_tasks,
    make_volume,
    paper_eight_tasks,
    patient_split,
)

CFG = DQNConfig(
    volume_shape=(16, 16, 16),
    box_size=(6, 6, 6),
    conv_features=(4,),
    hidden=(32,),
    max_episode_steps=12,
    batch_size=16,
    eps_decay_steps=50,
)


def test_twenty_four_environments():
    tasks = all_tasks()
    assert len(tasks) == len(MODALITIES) * len(ORIENTATIONS) * len(PATHOLOGIES) == 24
    assert len(set(t.name for t in tasks)) == 24
    assert len(paper_eight_tasks()) == 8


def test_volume_properties():
    for task in paper_eight_tasks()[:3]:
        vol, lm = make_volume(task, patient=5, n=16)
        assert vol.shape == (16, 16, 16)
        assert vol.min() >= 0.0 and vol.max() <= 1.0
        assert (lm >= 0).all() and (lm <= 15).all()


def test_volume_deterministic_and_orientation_consistent():
    t_ax = TaskTag("t1", "axial", "HGG")
    t_co = TaskTag("t1", "coronal", "HGG")
    v1, l1 = make_volume(t_ax, 3, n=16)
    v2, l2 = make_volume(t_ax, 3, n=16)
    np.testing.assert_array_equal(v1, v2)  # deterministic
    v3, l3 = make_volume(t_co, 3, n=16)
    # coronal is an axis permutation of the same anatomy
    assert v3.shape == v1.shape
    np.testing.assert_allclose(sorted(l3.tolist()), sorted(l1.tolist()))


def test_modalities_differ():
    vols = [make_volume(TaskTag(m, "axial", "HGG"), 1, n=16)[0] for m in MODALITIES]
    for i in range(len(vols)):
        for j in range(i + 1, len(vols)):
            assert not np.allclose(vols[i], vols[j])


def test_env_reward_is_distance_decrease(rng):
    vol, lm = make_volume(TaskTag("t2", "axial", "LGG"), 2, n=16)
    env = LandmarkEnv(vol, lm, CFG)
    locs = env.start_locs(8, rng)
    for a in range(6):
        acts = np.full(8, a, np.int32)
        new, r, done = env.step(locs, acts)
        np.testing.assert_allclose(r, env.dist(locs) - env.dist(new), atol=1e-5)
    # observations centered correctly and padded at borders
    obs = env.observe(np.array([[0, 0, 0], [8, 8, 8]], np.int32))
    assert obs.shape == (2, 6, 6, 6)
    assert np.isfinite(obs).all()


def test_patient_split_disjoint():
    train, test = patient_split(50)
    assert not set(train) & set(test)
    assert len(train) + len(test) == 50


def test_dqn_agent_learns_on_fixed_task(rng):
    """A few rounds of DQN on one small volume must beat random policy."""
    vol, lm = make_volume(TaskTag("t1", "axial", "HGG"), 0, n=16)
    env = LandmarkEnv(vol, lm, CFG)
    agent = DQNAgent(0, CFG, seed=0)
    before = agent.evaluate(env, n_episodes=8)
    erb = erb_init(1024, CFG.box_size, task=TaskTag("t1", "axial", "HGG"))
    for _ in range(3):
        agent.collect(env, erb, n_episodes=16)
        agent.train_steps(60, erb)
    after = agent.evaluate(env, n_episodes=8)
    assert after < before, (before, after)


def test_train_round_produces_shared_erb(rng):
    vol, lm = make_volume(TaskTag("flair", "axial", "HGG"), 0, n=16)
    env = LandmarkEnv(vol, lm, CFG)
    agent = DQNAgent(1, CFG, seed=1)
    shared, loss = agent.train_round(
        env,
        TaskTag("flair", "axial", "HGG"),
        incoming=(),
        erb_capacity=512,
        share_size=64,
        train_steps=10,
    )
    assert 0 < shared.size <= 64
    assert shared.meta.source_agent == 1
    assert agent.rounds_done == 1
    assert len(agent.personal_erbs) == 1
    assert np.isfinite(loss)
