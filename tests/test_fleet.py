"""Fleet engine: bit-equivalence, no-recompilation, device-resident replay.

The load-bearing guarantee: driving ADFLL rounds through the vectorized
fleet engine — lazily batched, scan-fused, vmapped over agents — changes
*nothing* about round semantics. Batched flushes produce bit-identical
params, losses, history, and eval distances to sequential (flush-per-
round) driving, because the per-slot math of the fleet chunk is bitwise
invariant to how many agents share a dispatch. The legacy per-step path
(``backend="stepwise"``) is only fusion-ULPs away and keeps identical
metadata (arrival order, sim times, replay selection).
"""

import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np

from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.core.erb import TaskTag, erb_add, erb_flatten, erb_init
from repro.core.federated import ADFLLSystem
from repro.core.replay import SelectiveReplaySampler
from repro.rl.agent import DQNAgent, dqn_step_traces, make_dqn_steps
from repro.rl.env import LandmarkEnv
from repro.rl.fleet import FleetEngine, collect_fleet, make_fleet_steps
from repro.rl.synth import make_volume, paper_eight_tasks, patient_split

DQN = DQNConfig(
    volume_shape=(16, 16, 16),
    box_size=(6, 6, 6),
    conv_features=(4,),
    hidden=(32,),
    max_episode_steps=12,
    batch_size=16,
    eps_decay_steps=100,
    target_update=8,  # force target syncs inside the scanned chunk
)


def _sys_cfg(engine: str, **kw) -> ADFLLConfig:
    return ADFLLConfig(
        n_agents=2,
        agent_hub=(0, 1),
        agent_speed=(1.0, 2.0),
        rounds=2,
        train_steps_per_round=12,
        erb_capacity=512,
        erb_share_size=64,
        hub_sync_period=0.25,
        engine=engine,
        **kw,
    )


TASKS = paper_eight_tasks()
TRAIN_P, TEST_P = patient_split(16)


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def _tree_maxdiff(a, b) -> float:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return max(
        float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
        for x, y in zip(la, lb)
    )


def _run_system(engine: str, *, planes=("erb",)):
    sysm = ADFLLSystem(
        _sys_cfg(engine, share_planes=tuple(planes)), DQN, TASKS, TRAIN_P, seed=0
    )
    sysm.run()
    ev = sysm.evaluate(TASKS[:2], TEST_P)
    return sysm, ev


def _filled_erb(rng: np.random.Generator, capacity: int = 256):
    erb = erb_init(capacity, DQN.box_size, task=TaskTag("t1", "axial", "HGG"))
    n = capacity
    erb_add(
        erb,
        {
            "obs": rng.standard_normal((n, *DQN.box_size)).astype(np.float32),
            "loc": rng.random((n, 3)).astype(np.float32),
            "action": rng.integers(0, DQN.n_actions, n).astype(np.int32),
            "reward": rng.standard_normal(n).astype(np.float32),
            "next_obs": rng.standard_normal((n, *DQN.box_size)).astype(np.float32),
            "next_loc": rng.random((n, 3)).astype(np.float32),
            "done": (rng.random(n) < 0.1).astype(np.float32),
        },
    )
    return erb


# -- the tentpole guarantee --------------------------------------------------
def test_fleet_vs_sequential_bit_equivalence():
    """Same seeds -> identical params, history, and eval distance for a
    2-agent ADFLL run, batched-lazy vs flush-per-round sequential."""
    lazy, ev_lazy = _run_system("fleet")
    seq, ev_seq = _run_system("fleet-eager")
    assert any(n > 1 for n in lazy.engine.flush_sizes), "nothing batched"
    assert all(n == 1 for n in seq.engine.flush_sizes)
    for aid in lazy.agents:
        assert _tree_equal(lazy.agents[aid].params, seq.agents[aid].params)
        assert _tree_equal(
            lazy.agents[aid].target_params, seq.agents[aid].target_params
        )
    assert ev_lazy == ev_seq  # bit-identical greedy rollouts
    assert [dataclasses.astuple(r) for r in lazy.history] == [
        dataclasses.astuple(r) for r in seq.history
    ]


def test_fleet_vs_sequential_with_weight_plane():
    """Staleness-discounted weight mixing rides the same guarantee."""
    planes = ("erb", "weights")
    lazy, ev_lazy = _run_system("fleet", planes=planes)
    seq, ev_seq = _run_system("fleet-eager", planes=planes)
    assert any(r.n_mixed > 0 for r in lazy.history), "no mixing happened"
    for aid in lazy.agents:
        assert _tree_equal(lazy.agents[aid].params, seq.agents[aid].params)
    assert ev_lazy == ev_seq
    assert [dataclasses.astuple(r) for r in lazy.history] == [
        dataclasses.astuple(r) for r in seq.history
    ]


def test_fleet_vs_legacy_stepwise_semantics():
    """The legacy per-step path differs only by float-fusion ULPs: every
    RoundRecord field except the loss is identical (arrival order,
    staleness mixing, sim-time accounting unchanged)."""
    fleet, _ = _run_system("fleet")
    legacy, _ = _run_system("stepwise")
    assert legacy.engine is None
    ha = [dataclasses.astuple(r) for r in fleet.history]
    hb = [dataclasses.astuple(r) for r in legacy.history]
    assert len(ha) == len(hb)
    for ra, rb in zip(ha, hb):
        assert ra[:6] == rb[:6] and ra[7:] == rb[7:]  # all but loss exact
        assert abs(ra[6] - rb[6]) < 1e-4
    for aid in fleet.agents:
        assert (
            _tree_maxdiff(fleet.agents[aid].params, legacy.agents[aid].params) < 1e-5
        )


def test_chunk_is_bitwise_invariant_to_fleet_width():
    """One batched 3-slot flush == three 1-slot flushes, bit for bit."""
    data_rng = np.random.default_rng(7)
    erb = _filled_erb(data_rng)
    shared = FleetEngine(DQN)
    solo = [FleetEngine(DQN) for _ in range(3)]
    sampler = SelectiveReplaySampler()
    for i in range(3):
        assert shared.add_slot(seed=i) == i
        solo[i].add_slot(seed=i)
    # submit identical plans to the shared fleet and the solo engines
    futs = []
    for i in range(3):
        plan_rng = np.random.default_rng(100 + i)
        plans = [sampler.plan(plan_rng, DQN.batch_size, erb) for _ in range(9)]
        futs.append(shared.submit(i, plans))
    shared.flush()
    assert shared.flush_sizes == [3]
    for i in range(3):
        plan_rng = np.random.default_rng(100 + i)
        plans = [sampler.plan(plan_rng, DQN.batch_size, erb) for _ in range(9)]
        fut = solo[i].submit(0, plans)
        solo[i].flush()
        assert _tree_equal(shared.get_params(i), solo[i].get_params(0))
        assert _tree_equal(shared.get_target(i), solo[i].get_target(0))
        assert _tree_equal(shared.get_opt(i), solo[i].get_opt(0))
        assert futs[i].loss == fut.loss


def test_flush_on_read_and_future_resolution():
    engine = FleetEngine(DQN)
    agent = DQNAgent(0, DQN, seed=3, engine=engine)
    erb = _filled_erb(np.random.default_rng(1))
    before = agent.params
    fut = agent._submit_steps(5, erb, ())
    assert not fut.done
    seen = []
    fut.on_done(seen.append)
    after = agent.params  # read forces the flush
    assert fut.done and np.isfinite(fut.loss) and seen == [fut.loss]
    assert not _tree_equal(before, after)
    assert agent.step_count == 5


# -- no recompilation across same-config agents ------------------------------
def test_make_steps_compile_once_across_agents():
    # unique config objects so module-level caches/counters start fresh
    cfg = dataclasses.replace(DQN, eps_decay_steps=997)
    assert make_dqn_steps(cfg) is make_dqn_steps(cfg)
    assert make_fleet_steps(cfg) is make_fleet_steps(cfg)

    agents = [DQNAgent(i, cfg, seed=i, backend="stepwise") for i in range(3)]
    erb = _filled_erb(np.random.default_rng(2))
    for a in agents:
        a.train_steps(2, erb)
    assert dqn_step_traces(cfg) == 1  # one trace serves all three agents

    engine = FleetEngine(cfg)
    fleet_agents = [DQNAgent(i, cfg, seed=i, engine=engine) for i in range(3)]
    for _ in range(2):  # two identical batched flushes, one compile
        for a in fleet_agents:
            a._submit_steps(4, erb, ())
        engine.flush()
    assert engine.steps.n_traces == 1
    assert make_fleet_steps(cfg).n_traces == 1


# -- host planning == host materialization -----------------------------------
def test_sampler_plan_matches_sample():
    """plan() + materialize() is the decomposition of sample(): same rng
    stream, same rows, same shuffle."""
    rng_data = np.random.default_rng(0)
    current = _filled_erb(rng_data, 128)
    personal = [_filled_erb(rng_data, 64)]
    incoming = [_filled_erb(rng_data, 64), _filled_erb(rng_data, 32)]
    sampler = SelectiveReplaySampler()
    r1, r2 = np.random.default_rng(5), np.random.default_rng(5)
    direct = sampler.sample(r1, 32, current, personal=personal, incoming=incoming)
    plan = sampler.plan(r2, 32, current, personal=personal, incoming=incoming)
    via_plan = sampler.materialize(plan)
    assert set(direct) == set(via_plan)
    for k in direct:
        np.testing.assert_array_equal(direct[k], via_plan[k])
    # both consumed the stream identically
    assert r1.bit_generator.state == r2.bit_generator.state


# -- vectorized observation gather -------------------------------------------
def _observe_reference(env: LandmarkEnv, locs: np.ndarray) -> np.ndarray:
    """The pre-vectorization implementation: per-call np.pad + row loop."""
    b = locs.shape[0]
    bx, by, bz = env.cfg.box_size
    half = np.array([bx // 2, by // 2, bz // 2])
    pad = max(bx, by, bz)
    vol = np.pad(env.volume, pad)
    out = np.empty((b, bx, by, bz), np.float32)
    for i in range(b):
        c = locs[i] + pad - half
        out[i] = vol[c[0] : c[0] + bx, c[1] : c[1] + by, c[2] : c[2] + bz]
    return out


def test_observe_matches_loop_reference(rng):
    vol, lm = make_volume(TaskTag("t2", "axial", "LGG"), 4, n=16)
    env = LandmarkEnv(vol, lm, DQN)
    n = env.n
    locs = np.concatenate(
        [
            rng.integers(0, n, size=(32, 3)),
            np.array([[0, 0, 0], [n - 1, n - 1, n - 1], [0, n - 1, 7]]),
        ]
    ).astype(np.int32)
    want = _observe_reference(env, locs)
    got = env.observe(locs)
    assert got.dtype == np.float32 and got.flags["C_CONTIGUOUS"]
    np.testing.assert_array_equal(got, want)
    # second call exercises the pad-once cache
    np.testing.assert_array_equal(env.observe(locs), want)


# -- stacked collection == per-agent collection ------------------------------
def test_collect_fleet_matches_per_agent_collect():
    """One vmapped q-value dispatch per environment step for the whole
    cohort writes the same ERB bytes and leaves the same rng state as
    per-agent acting: each lane is the agent's own program on its own
    batch, and every epsilon-greedy draw comes from that agent's own
    stream in the per-agent order."""
    cfg = dataclasses.replace(DQN, max_episode_steps=8)
    engine = FleetEngine(cfg)
    fleet = [DQNAgent(i, cfg, seed=i, engine=engine) for i in range(3)]
    legacy = [DQNAgent(i, cfg, seed=i, backend="stepwise") for i in range(3)]
    task = TaskTag("t1", "axial", "HGG")
    vol, lm = make_volume(task, 2, n=16)
    erbs_f = [erb_init(256, cfg.box_size, task=task) for _ in range(3)]
    erbs_l = [erb_init(256, cfg.box_size, task=task) for _ in range(3)]
    collect_fleet(fleet, [LandmarkEnv(vol, lm, cfg) for _ in range(3)], erbs_f, 6)
    for a, erb in zip(legacy, erbs_l):
        a.collect(LandmarkEnv(vol, lm, cfg), erb, 6)
    for ef, el, fa, la in zip(erbs_f, erbs_l, fleet, legacy):
        assert ef.size == el.size > 0
        np.testing.assert_array_equal(erb_flatten(ef), erb_flatten(el))
        assert fa.rng.bit_generator.state == la.rng.bit_generator.state


def test_agent_collect_routes_through_stacked_program():
    """A lone fleet agent's ``collect`` delegates to ``collect_fleet`` and
    still matches the legacy loop exactly."""
    cfg = dataclasses.replace(DQN, max_episode_steps=8)
    engine = FleetEngine(cfg)
    fa = DQNAgent(0, cfg, seed=5, engine=engine)
    la = DQNAgent(0, cfg, seed=5, backend="stepwise")
    task = TaskTag("t2", "axial", "LGG")
    vol, lm = make_volume(task, 1, n=16)
    erb_f = erb_init(256, cfg.box_size, task=task)
    erb_l = erb_init(256, cfg.box_size, task=task)
    fa.collect(LandmarkEnv(vol, lm, cfg), erb_f, 4)
    la.collect(LandmarkEnv(vol, lm, cfg), erb_l, 4)
    assert erb_f.size == erb_l.size > 0
    np.testing.assert_array_equal(erb_flatten(erb_f), erb_flatten(erb_l))
    assert fa.rng.bit_generator.state == la.rng.bit_generator.state


# -- pow2 slot bucketing -----------------------------------------------------
def test_padded_capacity_and_dead_slot_hygiene():
    cfg = dataclasses.replace(DQN, eps_decay_steps=499)  # fresh caches
    engine = FleetEngine(cfg)
    agents = [DQNAgent(i, cfg, seed=i, engine=engine) for i in range(3)]
    assert engine.n_slots == 3 and engine.capacity == 4  # pow2 bucket
    stacked = engine.stacked_params()
    assert all(
        np.asarray(leaf).shape[0] == 3
        for leaf in jax.tree_util.tree_leaves(stacked)
    )  # dead padding rows never leak out of the engine
    erb = _filled_erb(np.random.default_rng(3))
    for a in agents:
        a._submit_steps(4, erb, ())
    # adding an agent into a spare padded row must not force a flush:
    # pending jobs keep batching across the membership change
    late = DQNAgent(3, cfg, seed=3, engine=engine)
    assert engine.n_slots == 4 and engine.capacity == 4
    assert engine.flush_sizes == []
    solo = FleetEngine(cfg)
    solo_agent = DQNAgent(0, cfg, seed=3, engine=solo)
    assert _tree_equal(engine.get_params(late.slot), solo_agent.params)
    assert engine.flush_sizes == []  # the late slot had no pending work
    _ = agents[0].params  # reading a pending slot flushes all three at once
    assert engine.flush_sizes == [3]
    # growing past the bucket boundary re-tiles to the next power of two
    DQNAgent(4, cfg, seed=4, engine=engine)
    assert engine.n_slots == 5 and engine.capacity == 8


# -- device-mesh sharding (8 host-platform devices, subprocess) --------------
_MESH_SCRIPT = r"""
import numpy as np
import jax

assert jax.device_count() == 8, jax.devices()

from repro.configs.adfll_dqn import DQNConfig
from repro.core.erb import TaskTag, erb_add, erb_flatten, erb_init
from repro.core.replay import SelectiveReplaySampler
from repro.models.sharding import make_fleet_mesh
from repro.rl.agent import DQNAgent
from repro.rl.env import LandmarkEnv
from repro.rl.fleet import FleetEngine, collect_fleet
from repro.rl.synth import make_volume

CFG = DQNConfig(
    volume_shape=(16, 16, 16),
    box_size=(6, 6, 6),
    conv_features=(4,),
    hidden=(32,),
    max_episode_steps=8,
    batch_size=16,
    eps_decay_steps=100,
    target_update=8,
)


def tree_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


mesh = make_fleet_mesh(8)
assert mesh is not None and mesh.size == 8

single = FleetEngine(CFG)
shard = FleetEngine(CFG, mesh=mesh)
a_shard = [DQNAgent(i, CFG, seed=i, engine=shard) for i in range(4)]
for i in range(4):
    single.add_slot(seed=i)
assert shard.capacity == 8  # slots padded up to the mesh size

# stacked collection under the mesh == per-agent reference acting
task = TaskTag("t1", "axial", "HGG")
vol, lm = make_volume(task, 2, n=16)
ref = [DQNAgent(i, CFG, seed=i, backend="stepwise") for i in range(4)]
erbs_m = [erb_init(256, CFG.box_size, task=task) for _ in range(4)]
erbs_r = [erb_init(256, CFG.box_size, task=task) for _ in range(4)]
collect_fleet(a_shard, [LandmarkEnv(vol, lm, CFG) for _ in range(4)], erbs_m, 4)
for a, erb in zip(ref, erbs_r):
    a.collect(LandmarkEnv(vol, lm, CFG), erb, 4)
for em, er, am, ar in zip(erbs_m, erbs_r, a_shard, ref):
    assert em.size == er.size > 0
    assert np.array_equal(erb_flatten(em), erb_flatten(er))
    assert am.rng.bit_generator.state == ar.rng.bit_generator.state

# identical plan streams: the sharded chunk is bit-identical to the
# single-device chunk, flush after flush
sampler = SelectiveReplaySampler()
data = np.random.default_rng(7)
n = 256
erb = erb_init(n, CFG.box_size, task=task)
erb_add(
    erb,
    {
        "obs": data.standard_normal((n, *CFG.box_size)).astype(np.float32),
        "loc": data.random((n, 3)).astype(np.float32),
        "action": data.integers(0, CFG.n_actions, n).astype(np.int32),
        "reward": data.standard_normal(n).astype(np.float32),
        "next_obs": data.standard_normal((n, *CFG.box_size)).astype(np.float32),
        "next_loc": data.random((n, 3)).astype(np.float32),
        "done": (data.random(n) < 0.1).astype(np.float32),
    },
)
for round_idx in range(2):
    for eng in (single, shard):
        for i in range(4):
            rng = np.random.default_rng(100 + 10 * round_idx + i)
            plans = [sampler.plan(rng, CFG.batch_size, erb) for _ in range(6)]
            eng.submit(i, plans)
        eng.flush()
    for i in range(4):
        assert tree_equal(single.get_params(i), shard.get_params(i))
        assert tree_equal(single.get_target(i), shard.get_target(i))
        assert tree_equal(single.get_opt(i), shard.get_opt(i))

# a partial flush (subset of the live slots) exercises the non-resident
# gather/scatter path under the mesh — same bit-identity guarantee
for eng in (single, shard):
    for i in range(3):
        rng = np.random.default_rng(500 + i)
        plans = [sampler.plan(rng, CFG.batch_size, erb) for _ in range(6)]
        eng.submit(i, plans)
    eng.flush()
for i in range(4):
    assert tree_equal(single.get_params(i), shard.get_params(i))

# identical flushes, one compile: explicit mesh shardings on the
# chunk's inputs/outputs must not retrace (the partial flush pads to
# the same bucket width, so it reuses the same trace)
assert shard.steps.n_traces == 1, shard.steps.n_traces
assert single.steps.n_traces == 1, single.steps.n_traces
assert shard.steps is not single.steps  # mesh-keyed cache entries

print("MESH-OK")
"""


def test_sharded_mesh_bit_identity_and_no_recompile():
    """The 8-device assertions must run in a subprocess: the host-platform
    device count only takes effect when set before jax initializes, and
    conftest pins this process to one CPU device."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "MESH-OK" in proc.stdout


def test_agent_sampler_inherits_use_pallas_flag():
    agent = DQNAgent(0, DQN, seed=0, backend="stepwise")
    assert agent.sampler.use_pallas is False
    agent_p = DQNAgent(1, DQN, seed=1, backend="stepwise", use_pallas=True)
    assert agent_p.sampler.use_pallas is True
