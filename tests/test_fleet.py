"""Fleet engine: bit-equivalence, no-recompilation, device-resident replay.

The load-bearing guarantee: driving ADFLL rounds through the vectorized
fleet engine — lazily batched, scan-fused, vmapped over agents — changes
*nothing* about round semantics. Batched flushes produce bit-identical
params, losses, history, and eval distances to sequential (flush-per-
round) driving, because the per-slot math of the fleet chunk is bitwise
invariant to how many agents share a dispatch. The legacy per-step path
(``backend="stepwise"``) is only fusion-ULPs away and keeps identical
metadata (arrival order, sim times, replay selection).
"""

import dataclasses

import jax
import numpy as np

from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.core.erb import TaskTag, erb_add, erb_init
from repro.core.federated import ADFLLSystem
from repro.core.replay import SelectiveReplaySampler
from repro.rl.agent import DQNAgent, dqn_step_traces, make_dqn_steps
from repro.rl.env import LandmarkEnv
from repro.rl.fleet import FleetEngine, make_fleet_steps
from repro.rl.synth import make_volume, paper_eight_tasks, patient_split

DQN = DQNConfig(
    volume_shape=(16, 16, 16),
    box_size=(6, 6, 6),
    conv_features=(4,),
    hidden=(32,),
    max_episode_steps=12,
    batch_size=16,
    eps_decay_steps=100,
    target_update=8,  # force target syncs inside the scanned chunk
)


def _sys_cfg(engine: str, **kw) -> ADFLLConfig:
    return ADFLLConfig(
        n_agents=2,
        agent_hub=(0, 1),
        agent_speed=(1.0, 2.0),
        rounds=2,
        train_steps_per_round=12,
        erb_capacity=512,
        erb_share_size=64,
        hub_sync_period=0.25,
        engine=engine,
        **kw,
    )


TASKS = paper_eight_tasks()
TRAIN_P, TEST_P = patient_split(16)


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def _tree_maxdiff(a, b) -> float:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return max(
        float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
        for x, y in zip(la, lb)
    )


def _run_system(engine: str, *, planes=("erb",)):
    sysm = ADFLLSystem(
        _sys_cfg(engine, share_planes=tuple(planes)), DQN, TASKS, TRAIN_P, seed=0
    )
    sysm.run()
    ev = sysm.evaluate(TASKS[:2], TEST_P)
    return sysm, ev


def _filled_erb(rng: np.random.Generator, capacity: int = 256):
    erb = erb_init(capacity, DQN.box_size, task=TaskTag("t1", "axial", "HGG"))
    n = capacity
    erb_add(
        erb,
        {
            "obs": rng.standard_normal((n, *DQN.box_size)).astype(np.float32),
            "loc": rng.random((n, 3)).astype(np.float32),
            "action": rng.integers(0, DQN.n_actions, n).astype(np.int32),
            "reward": rng.standard_normal(n).astype(np.float32),
            "next_obs": rng.standard_normal((n, *DQN.box_size)).astype(np.float32),
            "next_loc": rng.random((n, 3)).astype(np.float32),
            "done": (rng.random(n) < 0.1).astype(np.float32),
        },
    )
    return erb


# -- the tentpole guarantee --------------------------------------------------
def test_fleet_vs_sequential_bit_equivalence():
    """Same seeds -> identical params, history, and eval distance for a
    2-agent ADFLL run, batched-lazy vs flush-per-round sequential."""
    lazy, ev_lazy = _run_system("fleet")
    seq, ev_seq = _run_system("fleet-eager")
    assert any(n > 1 for n in lazy.engine.flush_sizes), "nothing batched"
    assert all(n == 1 for n in seq.engine.flush_sizes)
    for aid in lazy.agents:
        assert _tree_equal(lazy.agents[aid].params, seq.agents[aid].params)
        assert _tree_equal(
            lazy.agents[aid].target_params, seq.agents[aid].target_params
        )
    assert ev_lazy == ev_seq  # bit-identical greedy rollouts
    assert [dataclasses.astuple(r) for r in lazy.history] == [
        dataclasses.astuple(r) for r in seq.history
    ]


def test_fleet_vs_sequential_with_weight_plane():
    """Staleness-discounted weight mixing rides the same guarantee."""
    planes = ("erb", "weights")
    lazy, ev_lazy = _run_system("fleet", planes=planes)
    seq, ev_seq = _run_system("fleet-eager", planes=planes)
    assert any(r.n_mixed > 0 for r in lazy.history), "no mixing happened"
    for aid in lazy.agents:
        assert _tree_equal(lazy.agents[aid].params, seq.agents[aid].params)
    assert ev_lazy == ev_seq
    assert [dataclasses.astuple(r) for r in lazy.history] == [
        dataclasses.astuple(r) for r in seq.history
    ]


def test_fleet_vs_legacy_stepwise_semantics():
    """The legacy per-step path differs only by float-fusion ULPs: every
    RoundRecord field except the loss is identical (arrival order,
    staleness mixing, sim-time accounting unchanged)."""
    fleet, _ = _run_system("fleet")
    legacy, _ = _run_system("stepwise")
    assert legacy.engine is None
    ha = [dataclasses.astuple(r) for r in fleet.history]
    hb = [dataclasses.astuple(r) for r in legacy.history]
    assert len(ha) == len(hb)
    for ra, rb in zip(ha, hb):
        assert ra[:6] == rb[:6] and ra[7:] == rb[7:]  # all but loss exact
        assert abs(ra[6] - rb[6]) < 1e-4
    for aid in fleet.agents:
        assert (
            _tree_maxdiff(fleet.agents[aid].params, legacy.agents[aid].params) < 1e-5
        )


def test_chunk_is_bitwise_invariant_to_fleet_width():
    """One batched 3-slot flush == three 1-slot flushes, bit for bit."""
    data_rng = np.random.default_rng(7)
    erb = _filled_erb(data_rng)
    shared = FleetEngine(DQN)
    solo = [FleetEngine(DQN) for _ in range(3)]
    sampler = SelectiveReplaySampler()
    for i in range(3):
        assert shared.add_slot(seed=i) == i
        solo[i].add_slot(seed=i)
    # submit identical plans to the shared fleet and the solo engines
    futs = []
    for i in range(3):
        plan_rng = np.random.default_rng(100 + i)
        plans = [sampler.plan(plan_rng, DQN.batch_size, erb) for _ in range(9)]
        futs.append(shared.submit(i, plans))
    shared.flush()
    assert shared.flush_sizes == [3]
    for i in range(3):
        plan_rng = np.random.default_rng(100 + i)
        plans = [sampler.plan(plan_rng, DQN.batch_size, erb) for _ in range(9)]
        fut = solo[i].submit(0, plans)
        solo[i].flush()
        assert _tree_equal(shared.get_params(i), solo[i].get_params(0))
        assert _tree_equal(shared.get_target(i), solo[i].get_target(0))
        assert _tree_equal(shared.get_opt(i), solo[i].get_opt(0))
        assert futs[i].loss == fut.loss


def test_flush_on_read_and_future_resolution():
    engine = FleetEngine(DQN)
    agent = DQNAgent(0, DQN, seed=3, engine=engine)
    erb = _filled_erb(np.random.default_rng(1))
    before = agent.params
    fut = agent._submit_steps(5, erb, ())
    assert not fut.done
    seen = []
    fut.on_done(seen.append)
    after = agent.params  # read forces the flush
    assert fut.done and np.isfinite(fut.loss) and seen == [fut.loss]
    assert not _tree_equal(before, after)
    assert agent.step_count == 5


# -- no recompilation across same-config agents ------------------------------
def test_make_steps_compile_once_across_agents():
    # unique config objects so module-level caches/counters start fresh
    cfg = dataclasses.replace(DQN, eps_decay_steps=997)
    assert make_dqn_steps(cfg) is make_dqn_steps(cfg)
    assert make_fleet_steps(cfg) is make_fleet_steps(cfg)

    agents = [DQNAgent(i, cfg, seed=i, backend="stepwise") for i in range(3)]
    erb = _filled_erb(np.random.default_rng(2))
    for a in agents:
        a.train_steps(2, erb)
    assert dqn_step_traces(cfg) == 1  # one trace serves all three agents

    engine = FleetEngine(cfg)
    fleet_agents = [DQNAgent(i, cfg, seed=i, engine=engine) for i in range(3)]
    for _ in range(2):  # two identical batched flushes, one compile
        for a in fleet_agents:
            a._submit_steps(4, erb, ())
        engine.flush()
    assert engine.steps.n_traces == 1
    assert make_fleet_steps(cfg).n_traces == 1


# -- host planning == host materialization -----------------------------------
def test_sampler_plan_matches_sample():
    """plan() + materialize() is the decomposition of sample(): same rng
    stream, same rows, same shuffle."""
    rng_data = np.random.default_rng(0)
    current = _filled_erb(rng_data, 128)
    personal = [_filled_erb(rng_data, 64)]
    incoming = [_filled_erb(rng_data, 64), _filled_erb(rng_data, 32)]
    sampler = SelectiveReplaySampler()
    r1, r2 = np.random.default_rng(5), np.random.default_rng(5)
    direct = sampler.sample(r1, 32, current, personal=personal, incoming=incoming)
    plan = sampler.plan(r2, 32, current, personal=personal, incoming=incoming)
    via_plan = sampler.materialize(plan)
    assert set(direct) == set(via_plan)
    for k in direct:
        np.testing.assert_array_equal(direct[k], via_plan[k])
    # both consumed the stream identically
    assert r1.bit_generator.state == r2.bit_generator.state


# -- vectorized observation gather -------------------------------------------
def _observe_reference(env: LandmarkEnv, locs: np.ndarray) -> np.ndarray:
    """The pre-vectorization implementation: per-call np.pad + row loop."""
    b = locs.shape[0]
    bx, by, bz = env.cfg.box_size
    half = np.array([bx // 2, by // 2, bz // 2])
    pad = max(bx, by, bz)
    vol = np.pad(env.volume, pad)
    out = np.empty((b, bx, by, bz), np.float32)
    for i in range(b):
        c = locs[i] + pad - half
        out[i] = vol[c[0] : c[0] + bx, c[1] : c[1] + by, c[2] : c[2] + bz]
    return out


def test_observe_matches_loop_reference(rng):
    vol, lm = make_volume(TaskTag("t2", "axial", "LGG"), 4, n=16)
    env = LandmarkEnv(vol, lm, DQN)
    n = env.n
    locs = np.concatenate(
        [
            rng.integers(0, n, size=(32, 3)),
            np.array([[0, 0, 0], [n - 1, n - 1, n - 1], [0, n - 1, 7]]),
        ]
    ).astype(np.int32)
    want = _observe_reference(env, locs)
    got = env.observe(locs)
    assert got.dtype == np.float32 and got.flags["C_CONTIGUOUS"]
    np.testing.assert_array_equal(got, want)
    # second call exercises the pad-once cache
    np.testing.assert_array_equal(env.observe(locs), want)


def test_agent_sampler_inherits_use_pallas_flag():
    agent = DQNAgent(0, DQN, seed=0, backend="stepwise")
    assert agent.sampler.use_pallas is False
    agent_p = DQNAgent(1, DQN, seed=1, backend="stepwise", use_pallas=True)
    assert agent_p.sampler.use_pallas is True
