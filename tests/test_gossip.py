"""Gossip topology: peer sampling, anti-entropy convergence, bandwidth-time
accounting, and compressed weight-plane round-trip fidelity."""

import jax
import numpy as np
import pytest

from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.core.erb import TaskTag, erb_init
from repro.core.federated import ADFLLSystem
from repro.core.gossip import (
    BandwidthMeter,
    FullMeshSampler,
    GossipTopology,
    LinkModel,
    RandomKSampler,
    RingSampler,
    TimeVaryingSampler,
    make_sampler,
)
from repro.core.network import Network
from repro.core.plane import (
    CompressedWeightPlane,
    CompressedWeightSnapshot,
    ERBPlane,
    WeightPlane,
    WeightSnapshot,
    mix_params,
    new_snap_id,
)
from repro.core.scheduler import Scheduler
from repro.rl.synth import paper_eight_tasks, patient_split

TASK = TaskTag("t1", "axial", "HGG")


def _params(seed=0, shape=(64, 32)):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal(shape).astype(np.float32),
        "b": rng.standard_normal((shape[1],)).astype(np.float32),
    }


def _snap(agent_id, round_idx, seed=0, sim_time=0.0):
    return WeightSnapshot(
        new_snap_id(), agent_id, round_idx, sim_time, _params(seed)
    )


def _erb_record(seed=0):
    erb = erb_init(4, (2, 2, 2), task=TASK, source_agent=seed)
    erb.size = 4
    return erb


# ---------------------------------------------------------------------------
# peer samplers
# ---------------------------------------------------------------------------
def test_ring_sampler_successors():
    s = RingSampler(fanout=2)
    assert s.peers(0, [0, 1, 2, 3]) == [1, 2]
    assert s.peers(3, [0, 1, 2, 3]) == [0, 1]
    assert s.peers(0, [0]) == []


def test_full_mesh_sampler_everyone():
    s = FullMeshSampler()
    assert s.peers(2, [0, 1, 2, 3]) == [0, 1, 3]


def test_random_sampler_deterministic_under_seed():
    ids = list(range(10))
    a = RandomKSampler(k=3, seed=7)
    b = RandomKSampler(k=3, seed=7)
    picks_a = [a.peers(0, ids) for _ in range(20)]
    picks_b = [b.peers(0, ids) for _ in range(20)]
    assert picks_a == picks_b
    for p in picks_a:
        assert len(p) == 3 and 0 not in p and len(set(p)) == 3


def test_random_sampler_seed_changes_stream():
    ids = list(range(10))
    a = [RandomKSampler(k=3, seed=1).peers(0, ids) for _ in range(5)]
    b = [RandomKSampler(k=3, seed=2).peers(0, ids) for _ in range(5)]
    assert a != b


def test_timevarying_sampler_cycles_exponential_offsets():
    s = TimeVaryingSampler()
    ids = list(range(8))
    offsets = []
    for r in range(6):
        s.new_round(float(r))
        (peer,) = s.peers(0, ids)
        offsets.append(peer)
    # log2(8)=3 offsets: 1, 2, 4, then wrap
    assert offsets == [1, 2, 4, 1, 2, 4]


def test_make_sampler_factory():
    assert isinstance(make_sampler("ring"), RingSampler)
    assert isinstance(make_sampler("random", fanout=3), RandomKSampler)
    assert isinstance(make_sampler("full"), FullMeshSampler)
    assert isinstance(make_sampler("timevary"), TimeVaryingSampler)
    with pytest.raises(ValueError):
        make_sampler("smallworld")


# ---------------------------------------------------------------------------
# anti-entropy convergence
# ---------------------------------------------------------------------------
def _topology(sampler, n_agents=6, link=None, seed=0):
    planes = {"erb": ERBPlane()}
    g = GossipTopology(
        planes,
        sampler,
        link=link,
        rng=np.random.default_rng(seed),
    )
    for a in range(n_agents):
        g.add_agent(a)
    return g, planes["erb"]


@pytest.mark.parametrize("name", ["ring", "random", "full", "timevary"])
def test_anti_entropy_converges_all_records_everywhere(name):
    g, plane = _topology(make_sampler(name, fanout=2, seed=3), n_agents=6)
    for a in range(6):
        g.insert_local(a, _erb_record(seed=a), plane)
    for _ in range(12):  # immediate delivery: no scheduler
        g.anti_entropy()
        if g.converged("erb"):
            break
    assert g.converged("erb")
    assert len(g.all_known("erb")) == 6
    for a in range(6):
        assert len(g.local_store(a, "erb")) == 6


def test_anti_entropy_converges_under_link_drop():
    link = LinkModel(drop=0.5)
    g, plane = _topology(RingSampler(fanout=2), n_agents=5, link=link, seed=1)
    for a in range(5):
        g.insert_local(a, _erb_record(seed=a), plane)
    for _ in range(80):
        g.anti_entropy()
        if g.converged("erb"):
            break
    assert g.converged("erb")
    assert g.stats.n_dropped > 0


def test_departed_agent_store_is_dropped():
    g, plane = _topology(FullMeshSampler(), n_agents=3)
    g.insert_local(0, _erb_record(seed=0), plane)
    g.remove_agent(0)
    g.anti_entropy()
    assert g.all_known("erb") == set()  # unreplicated knowledge left with it


def test_departed_agent_is_not_resurrected_by_late_push():
    """A push for a removed agent must be refused, not silently re-create
    its store (which would revive it in every later anti-entropy round)."""
    g, plane = _topology(FullMeshSampler(), n_agents=2)
    g.remove_agent(1)
    assert not g.insert_local(1, _erb_record(seed=1), plane)
    assert g.pull_local(1, set(), "erb") == []
    assert sorted(g.stores) == [0]


def test_symmetric_pair_reconciled_once_per_round():
    """_exchange is push-pull (both directions), so a full mesh must visit
    each unordered pair exactly once per round — no double-sent bytes."""
    g, plane = _topology(FullMeshSampler(), n_agents=4)
    for a in range(4):
        g.insert_local(a, _erb_record(seed=a), plane)
    g.anti_entropy()
    assert g.stats.n_exchanges == 6  # C(4,2), not 12
    assert g.converged("erb")
    assert g.stats.n_sent == g.stats.n_delivered  # lossless: no duplicates


def test_removing_agent_mid_flight_round_is_safe():
    """Hub topology: removing an agent whose round is still in flight must
    not crash the finish event (its untrained round is simply lost)."""
    sysm = _tiny_sys("hub")
    sysm.run(until=0.2)  # rounds outstanding
    sysm.remove_agent(0)
    sysm.run()
    alive = [a for a in sysm.agents.values() if getattr(a, "active", True)]
    assert all(a.rounds_done >= 2 for a in alive)
    assert all(r.agent_id != 0 or r.start < 0.5 for r in sysm.history)


# ---------------------------------------------------------------------------
# bandwidth-time accounting
# ---------------------------------------------------------------------------
def test_link_transfer_time_prices_bytes():
    link = LinkModel(latency=0.5, rate=100.0)
    assert link.transfer_time(0) == pytest.approx(0.5)
    assert link.transfer_time(200) == pytest.approx(2.5)
    free = LinkModel()
    assert free.transfer_time(10**9) == 0.0


def test_meter_accounts_bytes_per_plane():
    m = BandwidthMeter()
    m.account("erb", 100)
    m.account("erb", 50)
    m.account("weights", 7)
    assert m.bytes_by_plane == {"erb": 150, "weights": 7}
    assert m.msgs_by_plane == {"erb": 2, "weights": 1}
    assert m.total_bytes == 157


def test_gossip_delivery_lands_at_link_transfer_time():
    """A record of B bytes over a (latency, rate) link must arrive at
    exactly now + latency + B/rate on the scheduler clock."""
    plane = ERBPlane()
    rec = _erb_record()
    nbytes = plane.payload_nbytes(rec)
    link = LinkModel(latency=0.25, rate=float(nbytes))  # => 1.25 total
    g, plane = _topology(RingSampler(), n_agents=2, link=link)
    g.insert_local(0, rec, plane)
    sched = Scheduler()
    sched.at(1.0, lambda s, t: g.anti_entropy(s))
    arrivals = []
    sched.every(
        0.05, lambda s, t: arrivals.append((t, len(g.local_store(1, "erb"))))
    )
    sched.run(until=3.0)
    before = [t for t, n in arrivals if n == 0]
    after = [t for t, n in arrivals if n == 1]
    assert max(before) < 1.0 + 1.25 <= min(after)
    assert g.meter.bytes_by_plane["erb"] >= nbytes


def test_hub_push_charges_link_time_and_bytes():
    from repro.core.hub import Hub

    net = Network(
        hubs=[Hub(0)],
        rng=np.random.default_rng(0),
        link=LinkModel(latency=0.1, rate=1000.0),
    )
    net.attach_agent(0, 0)
    rec = _erb_record()
    nbytes = net.planes["erb"].payload_nbytes(rec)
    pushed = net.agent_push(0, rec)
    assert pushed
    assert pushed.comm_time == pytest.approx(0.1 + nbytes / 1000.0)
    assert pushed.nbytes == nbytes
    assert net.meter.bytes_by_plane["erb"] == nbytes
    # pulling it back out charges the downlink too
    pulled = net.agent_pull(0, set())
    assert len(pulled) == 1
    assert pulled.comm_time == pytest.approx(0.1 + nbytes / 1000.0)
    assert pulled.nbytes == nbytes
    assert net.meter.bytes_by_plane["erb"] == 2 * nbytes


def test_comm_time_extends_simulated_makespan():
    """Same system, same seeds: a slow link must yield a strictly larger
    simulated makespan than a free one."""
    tiny = DQNConfig(
        volume_shape=(12, 12, 12),
        box_size=(4, 4, 4),
        conv_features=(2,),
        hidden=(8,),
        batch_size=4,
        max_episode_steps=4,
        eps_decay_steps=20,
    )
    tasks = paper_eight_tasks()[:2]
    train_p, _ = patient_split(8)

    def makespan(rate):
        cfg = ADFLLConfig(
            n_agents=2,
            n_hubs=1,
            agent_hub=(0, 0),
            agent_speed=(1.0, 2.0),
            rounds=2,
            erb_capacity=128,
            erb_share_size=16,
            train_steps_per_round=2,
            hub_sync_period=0.5,
            link_rate=rate,
        )
        sysm = ADFLLSystem(cfg, tiny, tasks, train_p, seed=0)
        return sysm.run().makespan

    assert makespan(2**18) > makespan(float("inf"))


# ---------------------------------------------------------------------------
# compressed weight plane
# ---------------------------------------------------------------------------
def test_int8_roundtrip_within_quantization_tolerance():
    plane = CompressedWeightPlane(compression="int8")
    params = _params(seed=5)
    snap = WeightSnapshot(new_snap_id(), 0, 0, 0.0, params)
    c = plane.encode(snap)
    assert isinstance(c, CompressedWeightSnapshot)
    assert c.snap_id == snap.snap_id and c.mode == "dense"
    deq = c.dequantize()
    for k in params:
        tol = np.max(np.abs(params[k])) / 127.0  # one quantization step
        np.testing.assert_allclose(deq[k], params[k], atol=tol + 1e-7)


def test_topk_error_feedback_converges_on_static_params():
    """Repeated pushes of the same params flush the residual: the
    transmitted reconstruction converges to the true parameters."""
    plane = CompressedWeightPlane(compression="topk", k_frac=0.1)
    params = _params(seed=6)
    errs = []
    for r in range(40):
        c = plane.encode(WeightSnapshot(new_snap_id(), 0, r, float(r), params))
        deq = c.dequantize()
        errs.append(max(float(np.max(np.abs(deq[k] - params[k]))) for k in params))
    assert errs[-1] < errs[0] * 1e-2
    assert errs[-1] < 1e-3


def test_compressed_bytes_at_least_4x_smaller():
    plane = CompressedWeightPlane(compression="topk", k_frac=0.05)
    params = _params(seed=7)
    dense_nbytes = sum(
        np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(params)
    )
    wire = 0
    n_msgs = 4
    for r in range(n_msgs):
        c = plane.encode(WeightSnapshot(new_snap_id(), 0, r, float(r), params))
        wire += plane.payload_nbytes(c)
    assert wire * 4 <= dense_nbytes * n_msgs
    # delta messages alone are far smaller than 1/4
    delta = plane.encode(WeightSnapshot(new_snap_id(), 0, n_msgs, 0.0, params))
    assert delta.payload_nbytes * 10 <= dense_nbytes


def test_compressed_mix_close_to_uncompressed_mix():
    """Dequantize-and-apply must land within quantization tolerance of
    mixing the raw snapshots."""
    base = _params(seed=8)
    peer = _params(seed=9)
    raw = WeightSnapshot(new_snap_id(), 1, 0, 0.0, peer)
    plane = CompressedWeightPlane(compression="int8")
    comp = plane.encode(raw)
    mixed_raw = mix_params(base, [raw], [0.5])
    mixed_comp = mix_params(base, [comp], [0.5])
    for k in base:
        tol = 0.5 * np.max(np.abs(peer[k])) / 127.0 + 1e-6
        np.testing.assert_allclose(mixed_comp[k], mixed_raw[k], atol=tol)


def test_compressed_plane_keeps_weightplane_retention():
    plane = CompressedWeightPlane(max_versions=1, compression="int8")
    store = {}
    old = plane.encode(WeightSnapshot(new_snap_id(), 0, 0, 0.0, _params(1)))
    new = plane.encode(WeightSnapshot(new_snap_id(), 0, 3, 1.0, _params(2)))
    assert plane.admit(store, old)
    assert plane.admit(store, new)
    assert not plane.admit(store, old)  # stale: refused
    assert set(store) == {new.snap_id}


def test_unknown_compression_rejected():
    with pytest.raises(ValueError):
        CompressedWeightPlane(compression="fp4")


def test_dropped_push_does_not_advance_delta_chain():
    """Pure hub + dropout: a lost upload must not advance the sender-side
    reference, so the next delivered snapshot is still a dense keyframe
    any receiver can decode without the lost delta."""
    from repro.core.hub import Hub

    plane = CompressedWeightPlane(compression="topk", k_frac=0.1)
    net = Network(
        hubs=[Hub(0)], dropout=1.0, rng=np.random.default_rng(0)
    )
    net.register_plane(plane)
    net.attach_agent(0, 0)
    assert not net.agent_push(0, _snap(0, 0, seed=1), plane="weights")
    assert plane._ref == {}  # chain untouched by the dropped upload
    net.dropout = 0.0
    assert net.agent_push(0, _snap(0, 1, seed=1), plane="weights")
    (rec,) = net.agent_pull(0, set(), plane="weights")
    assert rec.mode == "dense"  # first *delivered* snapshot is a keyframe


def test_gossip_attach_before_enable_refused():
    """Pure gossip with no overlay would silently lose the agent."""
    net = Network(hubs=[], topology="gossip")
    with pytest.raises(RuntimeError):
        net.attach_agent(0)


# ---------------------------------------------------------------------------
# scheduler additions (phase + cancel)
# ---------------------------------------------------------------------------
def test_scheduler_every_phase_offsets_first_tick():
    s = Scheduler()
    ticks = []
    s.every(1.0, lambda sc, t: ticks.append(t), until=3.0, phase=0.25)
    s.run()
    assert ticks == [0.25, 1.25, 2.25]


def test_scheduler_cancel_stops_periodic_timer():
    s = Scheduler()
    ticks = []
    s.every(1.0, lambda sc, t: ticks.append(t), tag="beat")
    s.at(3.5, lambda sc, t: sc.cancel("beat"))
    s.run(until=10.0)
    assert ticks == [1.0, 2.0, 3.0]


# ---------------------------------------------------------------------------
# end-to-end: gossip and hybrid systems through the scheduler
# ---------------------------------------------------------------------------
TINY_DQN = DQNConfig(
    volume_shape=(12, 12, 12),
    box_size=(4, 4, 4),
    conv_features=(2,),
    hidden=(8,),
    batch_size=4,
    max_episode_steps=4,
    eps_decay_steps=20,
)


def _tiny_sys(topology, seed=0, **kw):
    cfg = ADFLLConfig(
        n_agents=3,
        n_hubs=2,
        agent_hub=(0, 1, 0),
        agent_speed=(1.0, 2.0, 1.0),
        rounds=2,
        erb_capacity=128,
        erb_share_size=16,
        train_steps_per_round=3,
        hub_sync_period=0.5,
        share_planes=("erb", "weights"),
        topology=topology,
        gossip_sampler="random",
        gossip_fanout=2,
        gossip_period=0.25,
        **kw,
    )
    tasks = paper_eight_tasks()[:2]
    train_p, _ = patient_split(8)
    return ADFLLSystem(cfg, TINY_DQN, tasks, train_p, seed=seed)


def test_gossip_system_shares_both_planes_without_hubs():
    sysm = _tiny_sys("gossip", weight_compression="topk")
    sysm.run()
    assert sysm.network.hubs == []
    assert all(a.rounds_done >= 2 for a in sysm.agents.values())
    assert any(r.n_incoming > 0 for r in sysm.history)  # ERBs flowed p2p
    assert any(r.n_mixed > 0 for r in sysm.history)  # weights flowed p2p
    assert sysm.network.meter.bytes_by_plane["erb"] > 0
    assert sysm.network.meter.bytes_by_plane["weights"] > 0
    assert len(sysm.network.all_known("erb")) >= 3


def test_hybrid_system_merges_hub_and_gossip_without_duplicates():
    sysm = _tiny_sys("hybrid")
    sysm.run()
    assert all(a.rounds_done >= 2 for a in sysm.agents.values())
    # every consumed ERB is unique per agent despite the two transports
    for a in sysm.agents.values():
        assert len(a.seen_erb_ids) == len(set(a.seen_erb_ids))
    assert len(sysm.network.all_known("erb")) >= 3


def test_gossip_system_deterministic_under_fixed_seed():
    def fingerprint():
        sysm = _tiny_sys("gossip", seed=3, link_latency=0.001, link_rate=2.0**20)
        sysm.run()
        hist = [
            (r.agent_id, r.round_idx, r.task, round(r.end, 9), r.n_incoming)
            for r in sysm.history
        ]
        leaves = [
            float(np.asarray(x).sum())
            for a in sorted(sysm.agents)
            for x in jax.tree_util.tree_leaves(sysm.agents[a].params)
        ]
        return hist, leaves

    h1, p1 = fingerprint()
    h2, p2 = fingerprint()
    assert h1 == h2
    np.testing.assert_allclose(p1, p2, rtol=0, atol=0)


def test_weight_plane_payloads_shrink_with_compression():
    raw = _tiny_sys("gossip", seed=1)
    raw.run()
    comp = _tiny_sys("gossip", seed=1, weight_compression="topk")
    comp.run()
    raw_bytes = raw.network.meter.bytes_by_plane["weights"]
    comp_bytes = comp.network.meter.bytes_by_plane["weights"]
    assert comp_bytes * 2 < raw_bytes
