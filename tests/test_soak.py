"""Tier-2 soak: a longer observed federation run with span-level trace
validation.  Opt in with ``REPRO_SOAK=1`` (CI runs it on a schedule and
on manual dispatch, not per-push):

    REPRO_SOAK=1 PYTHONPATH=src python -m pytest tests/test_soak.py -q

Assertions are structural, over the whole captured trace: every span is
closed (finite ``t0 <= t1``), per-agent round spans are disjoint and
ordered, the two clock domains stay inside their run's bounds, flush
spans reconcile with the flush counter, nothing is dropped, and the
streamed JSONL trace round-trips completely."""

import math
import os

import pytest

from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.experiments import ScenarioSpec
from repro.experiments.runner import run
from repro.telemetry import Telemetry, load_trace

pytestmark = [
    pytest.mark.soak,
    pytest.mark.skipif(
        os.environ.get("REPRO_SOAK") != "1",
        reason="soak tests are opt-in: set REPRO_SOAK=1",
    ),
]

SOAK_DQN = DQNConfig(
    volume_shape=(12, 12, 12),
    box_size=(4, 4, 4),
    conv_features=(2,),
    hidden=(8,),
    batch_size=4,
    max_episode_steps=6,
    eps_decay_steps=40,
)
SOAK_SYS = ADFLLConfig(
    n_agents=3,
    n_hubs=1,
    agent_hub=(0, 0, 0),
    agent_speed=(1.0, 1.5, 2.0),
    rounds=6,
    erb_capacity=256,
    erb_share_size=16,
    train_steps_per_round=4,
    hub_sync_period=0.5,
    share_planes=("erb", "weights"),
)


@pytest.fixture(scope="module")
def soak_run(tmp_path_factory):
    trace_path = tmp_path_factory.mktemp("soak") / "soak.jsonl"
    tel = Telemetry(enabled=True, stream_path=trace_path)
    spec = ScenarioSpec(
        name="soak",
        system="adfll",
        task_set="paper8",
        n_tasks=3,
        n_patients=8,
        dqn=SOAK_DQN,
        sys=SOAK_SYS,
        eval_patients=2,
        eval_episodes=2,
    )
    report = run(spec, telemetry=tel)
    wall_end = tel.wall()
    tel.close()
    return report, tel, load_trace(trace_path), wall_end


def _spans(events, name=None, clock=None):
    return [
        e
        for e in events
        if e["kind"] == "span"
        and (name is None or e["name"] == name)
        and (clock is None or e["clock"] == clock)
    ]


def test_no_unclosed_spans(soak_run):
    _, _, trace, _ = soak_run
    spans = _spans(trace["events"])
    assert spans
    for e in spans:
        assert math.isfinite(e["t0"]) and math.isfinite(e["t1"])
        assert e["t1"] >= e["t0"], f"unclosed/negative span: {e}"


def test_round_spans_nest_per_agent(soak_run):
    report, _, trace, _ = soak_run
    rounds = _spans(trace["events"], name="round", clock="sim")
    assert len(rounds) == report.n_rounds
    by_track = {}
    for e in rounds:
        by_track.setdefault(e["track"], []).append(e)
    assert len(by_track) == SOAK_SYS.n_agents
    for track, spans in by_track.items():
        spans.sort(key=lambda e: e["t0"])
        for prev, cur in zip(spans, spans[1:], strict=False):
            # one agent trains sequentially: its rounds never overlap
            assert cur["t0"] >= prev["t1"], f"overlapping rounds on {track}"


def test_dual_clocks_stay_in_bounds(soak_run):
    report, _, trace, wall_end = soak_run
    eps = 1e-9
    for e in trace["events"]:
        assert e["clock"] in ("sim", "wall")
        if e["clock"] == "sim":
            assert -eps <= e["t0"] and e["t1"] <= report.makespan + eps
        else:
            assert -eps <= e["t0"] and e["t1"] <= wall_end + eps


def test_flush_spans_reconcile_with_counters(soak_run):
    _, tel, trace, _ = soak_run
    flushes = _spans(trace["events"], name="fleet.flush", clock="wall")
    assert flushes
    assert len(flushes) == tel.registry.counter_value("fleet.flushes")
    # every flush span wraps at least the chunk dispatch: nonzero width
    assert all(e["t1"] > e["t0"] for e in flushes)


def test_nothing_dropped_and_stream_complete(soak_run):
    _, tel, trace, _ = soak_run
    assert tel.tracer.n_dropped == 0
    assert tel.registry.n_dropped_series == 0
    assert len(trace["events"]) == tel.sink.n_written
    dropped = [
        m["value"] for m in trace["metrics"] if m["name"] == "trace.dropped"
    ]
    assert dropped == [0.0]


def test_observatory_consistent_with_engine_counters(soak_run):
    report, tel, _, _ = soak_run
    learning = report.extra["learning"]
    assert len(learning) == SOAK_SYS.n_agents
    total_steps = sum(doc["n_steps"] for doc in learning.values())
    assert total_steps == tel.registry.counter_value("fleet.steps_trained")
    assert report.extra["health"]["status"] in ("ok", "warn")
