"""The sweep subsystem: deterministic grid expansion (within and across
processes), the JSONL report store and resume semantics, the scipy-free
stats against precomputed references, significance-aware aggregation,
report diffing, and both CLIs' unknown-name handling."""

import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core.experiment import EvalPoint, Report
from repro.experiments import runner
from repro.experiments.suggest import close_matches, unknown_name_message
from repro.sweeps import (
    ReportStore,
    SweepSpec,
    SweepVariant,
    apply_overrides,
    compare,
    forgetting_of,
    get_sweep,
    list_sweeps,
    mean_ci,
    paired_permutation_test,
    paired_ttest,
    run_sweep,
    spec_hash,
    summarize,
    t_crit,
)
from repro.sweeps.__main__ import main as sweeps_cli_main
from repro.sweeps.executor import failed_cells
from repro.sweeps.registry import _REGISTRY
from repro.sweeps.store import STATUS_BUDGET, STATUS_ERROR, STATUS_OK

# ---------------------------------------------------------------------------
# grid expansion determinism
# ---------------------------------------------------------------------------
def test_expansion_is_deterministic_and_fast_variant_is_distinct():
    sw = get_sweep("ci_smoke")
    g1, g2 = sw.expand(fast=True), sw.expand(fast=True)
    assert [c.key for c in g1] == [c.key for c in g2]
    # variants outer, seeds inner
    assert [(c.label, c.seed) for c in g1] == [
        (v.label, s) for v in sw.variants for s in sw.seeds
    ]
    # the fast grid must never collide with the full grid in the store
    full = {c.key for c in sw.expand(fast=False)}
    assert full.isdisjoint({c.key for c in g1})
    # every cell spec carries its own seed (spec and sys in lockstep)
    for c in g1:
        assert c.spec.seed == c.seed and c.spec.sys.seed == c.seed


def test_expansion_keys_are_stable_across_processes():
    """The store key must not depend on PYTHONHASHSEED or process state —
    resuming an interrupted sweep from another process hinges on it."""
    sw = get_sweep("ci_smoke")
    here = [c.key for c in sw.expand(fast=True)]
    code = (
        "from repro.sweeps import get_sweep;"
        "print('\\n'.join(c.key for c in get_sweep('ci_smoke').expand(fast=True)))"
    )
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=dict(os.environ, PYTHONPATH="src", PYTHONHASHSEED="271828"),
        check=True,
    )
    assert out.stdout.split() == here


def test_apply_overrides_nested_and_unknown_paths():
    sw = get_sweep("ci_smoke")
    cell = sw.expand()[0]
    assert cell.spec.sys.rounds == 2  # the smoke override applied
    assert cell.spec.n_tasks == 2
    base = cell.spec
    over = apply_overrides(base, (("dqn.batch_size", 4), ("n_patients", 8)))
    assert over.dqn.batch_size == 4 and over.n_patients == 8
    with pytest.raises(ValueError, match="no field"):
        apply_overrides(base, (("sys.bogus_field", 1),))
    with pytest.raises(ValueError, match="no field"):
        apply_overrides(base, (("bogus", 1),))


def test_sweep_spec_validation():
    v = SweepVariant("a", "paper_fig2")
    with pytest.raises(ValueError, match="duplicate"):
        SweepSpec(name="x", variants=(v, SweepVariant("a", "baseline_partial")))
    with pytest.raises(ValueError, match="seed"):
        SweepSpec(name="x", variants=(v,), seeds=())
    with pytest.raises(ValueError, match="baseline"):
        SweepSpec(name="x", variants=(v,), baseline="nope")
    with pytest.raises(ValueError, match="no variants"):
        SweepSpec(name="x")


def test_builtin_sweeps_cover_the_paper_claims():
    names = {s.name for s in list_sweeps()}
    assert {"paper_table1_sweep", "paper_table2_hub_failure", "ci_smoke"} <= names
    t1 = get_sweep("paper_table1_sweep")
    assert len(t1.seeds) >= 5 and t1.baseline == "adfll"
    assert {v.scenario for v in t1.variants} == {
        "paper_fig2",
        "baseline_all_knowing",
        "baseline_partial",
        "baseline_sequential",
    }
    t2 = get_sweep("paper_table2_hub_failure")
    assert {v.scenario for v in t2.variants} >= {
        "paper_table2_hub_failure",
        "paper_table2_hybrid_failover",
    }
    assert get_sweep("ci_smoke").cell_budget_s is not None


# ---------------------------------------------------------------------------
# stats: precomputed references + edge cases
# ---------------------------------------------------------------------------
A5 = [7.2, 8.1, 6.9, 7.8, 7.4]
B5 = [15.3, 14.8, 16.1, 15.0, 14.6]


def test_paired_ttest_matches_reference():
    t, p = paired_ttest(A5, B5)
    assert t == pytest.approx(-17.373964922078468, abs=1e-12)
    assert p == pytest.approx(6.442051303582614e-05, rel=1e-9)
    # symmetry
    t2, p2 = paired_ttest(B5, A5)
    assert t2 == pytest.approx(-t) and p2 == pytest.approx(p)


def test_permutation_test_exact_small_sample():
    # n=5: all 32 sign patterns enumerated; every |mean| <= the observed
    # one except none -> only the two all-same patterns reach it: 2/32
    assert paired_permutation_test(A5, B5) == pytest.approx(0.0625)
    assert paired_permutation_test(B5, A5) == pytest.approx(0.0625)


def test_permutation_test_monte_carlo_branch_is_seeded():
    rng = np.random.default_rng(0)
    a = rng.normal(0.0, 1.0, 20)
    b = a + rng.normal(1.0, 0.3, 20)  # strong paired shift
    p1 = paired_permutation_test(a, b, n_resamples=2000, seed=7)
    p2 = paired_permutation_test(a, b, n_resamples=2000, seed=7)
    assert p1 == p2  # seeded Monte Carlo
    assert 0.0 < p1 < 0.01  # add-one estimator keeps p > 0


def test_stats_edge_cases_n_lt_2_and_zero_variance():
    t, p = paired_ttest([1.0], [2.0])
    assert np.isnan(t) and np.isnan(p)
    assert paired_ttest([1, 2, 3], [1, 2, 3]) == (0.0, 1.0)
    assert paired_permutation_test([1.0], [2.0]) == 1.0
    assert paired_permutation_test([1, 2, 3], [1, 2, 3]) == 1.0
    m, hw = mean_ci([])
    assert np.isnan(m) and np.isnan(hw)
    m, hw = mean_ci([5.0])
    assert m == 5.0 and np.isnan(hw)
    assert mean_ci([2.0, 2.0, 2.0]) == (2.0, 0.0)


def test_mean_ci_matches_reference():
    m, hw = mean_ci(A5)
    assert m == pytest.approx(7.48)
    assert m - hw == pytest.approx(6.888415185314209, abs=1e-9)
    assert t_crit(0.05, 4) == pytest.approx(2.7764451051977863, abs=1e-9)


# ---------------------------------------------------------------------------
# report store
# ---------------------------------------------------------------------------
def _row(key, status=STATUS_OK, err=7.0, seed=0, label="v"):
    return {
        "key": key,
        "label": label,
        "scenario": "s",
        "seed": seed,
        "status": status,
        "elapsed_s": 0.1,
        "summary": {"mean_dist_err": err},
    }


def test_store_roundtrip_last_row_wins_and_torn_tail(tmp_path):
    store = ReportStore(tmp_path / "s.jsonl")
    assert store.load() == {}
    store.append(_row("k1", status=STATUS_ERROR))
    store.append(_row("k2"))
    store.append(_row("k1"))  # retry superseded the failure
    with open(store.path, "a") as f:
        f.write('{"key": "k3", "status"')  # crash mid-append
    rows = store.load()
    assert set(rows) == {"k1", "k2"}
    assert rows["k1"]["status"] == STATUS_OK
    assert set(store.completed()) == {"k1", "k2"}
    with pytest.raises(ValueError):
        store.append({"status": "ok"})
    assert store.prune(["k2"]) == 1
    assert set(store.load()) == {"k2"}


# ---------------------------------------------------------------------------
# executor: resume, budgets, failures (runner stubbed; workers=1 inline)
# ---------------------------------------------------------------------------
def _tiny_sweep(**kw):
    base = dict(
        name="t",
        variants=(
            SweepVariant("a", "plane_erb_only"),
            SweepVariant("b", "topo_gossip"),
        ),
        seeds=(0, 1),
        baseline="a",
    )
    base.update(kw)
    return SweepSpec(**base)


def _fake_report(spec):
    rep = Report(scenario=spec.name, system=spec.system, seed=spec.seed)
    rep.mean_dist_err = 5.0 + spec.seed + (0.5 if "gossip" in spec.name else 0.0)
    rep.best_agent_err = rep.mean_dist_err
    rep.makespan = 2.0
    rep.eval_curve = [EvalPoint(t=2.0, n_agents=1, mean_err=rep.mean_dist_err)]
    return rep


def test_run_sweep_executes_resumes_and_aggregates(tmp_path, monkeypatch):
    calls = []

    def fake_run(spec, **kw):
        calls.append(spec.name)
        return _fake_report(spec)

    monkeypatch.setattr(runner, "run", fake_run)
    sw = _tiny_sweep()
    store = ReportStore(tmp_path / "t.jsonl")
    summary = run_sweep(sw, workers=1, store=store)
    assert len(calls) == 4 and not failed_cells(summary)
    assert summary["variants"]["a"]["n_ok"] == 2
    st = summary["variants"]["a"]["metrics"]["mean_dist_err"]
    assert st["mean"] == pytest.approx(5.5) and st["n"] == 2
    assert st["values"] == {"0": 5.0, "1": 6.0}
    # paired comparison exists against the baseline
    comps = {
        (c["variant"], c["metric"]): c for c in summary["comparisons"]
    }
    assert comps[("b", "mean_dist_err")]["delta"] == pytest.approx(0.5)

    # resume: all four cells cached, nothing re-executed
    calls.clear()
    summary2 = run_sweep(sw, workers=1, store=store)
    assert calls == []
    assert all(c["cached"] for c in summary2["cells"])
    assert summary2["variants"] == summary["variants"]

    # partial resume: drop one cell from the store -> exactly one re-runs
    keys = [c.key for c in sw.expand()]
    store.prune(keys[1:])
    summary3 = run_sweep(sw, workers=1, store=store)
    assert calls == ["plane_erb_only"]
    assert sum(not c["cached"] for c in summary3["cells"]) == 1


def test_budget_exceeded_marks_the_cell_failed(tmp_path, monkeypatch):
    def slow_run(spec, **kw):
        import time

        time.sleep(5.0)  # far past the budget: the alarm must interrupt
        return _fake_report(spec)

    monkeypatch.setattr(runner, "run", slow_run)
    sw = _tiny_sweep(seeds=(0,), cell_budget_s=0.05)
    t0 = time.monotonic()
    summary = run_sweep(sw, workers=1)
    bad = failed_cells(summary)
    assert len(bad) == 2
    assert all(c["status"] == STATUS_BUDGET for c in bad)
    # enforcement is real: the cells were interrupted, not slept to completion
    assert time.monotonic() - t0 < 4.0
    # over-budget cells contribute no metrics
    assert summary["variants"]["a"]["n_ok"] == 0
    assert summary["variants"]["a"]["metrics"]["mean_dist_err"]["mean"] is None


def test_worker_exception_records_an_error_cell(tmp_path, monkeypatch):
    def boom(spec, **kw):
        raise RuntimeError("kaboom")

    monkeypatch.setattr(runner, "run", boom)
    sw = _tiny_sweep(seeds=(0,))
    store = ReportStore(tmp_path / "t.jsonl")
    summary = run_sweep(sw, workers=1, store=store)
    bad = failed_cells(summary)
    assert {c["status"] for c in bad} == {STATUS_ERROR}
    # failed rows persist but do not count as completed -> retried next run
    assert store.completed() == {}

    monkeypatch.setattr(runner, "run", lambda spec, **kw: _fake_report(spec))
    summary2 = run_sweep(sw, workers=1, store=store)
    assert not failed_cells(summary2)


# ---------------------------------------------------------------------------
# aggregation + compare
# ---------------------------------------------------------------------------
def test_forgetting_of_curve_shapes():
    def s(errs):
        return {"eval_curve": [{"mean_err": e} for e in errs]}

    assert forgetting_of(s([8.0, 5.0, 7.0])) == pytest.approx(2.0)
    assert forgetting_of(s([8.0, 5.0])) == 0.0  # final is the best
    assert forgetting_of(s([6.0])) == 0.0
    assert forgetting_of({"eval_curve": []}) is None


def _summary_with(err_by_label_seed, sweep=None):
    sw = sweep or _tiny_sweep()
    rows = []
    for (label, seed), err in err_by_label_seed.items():
        cell = next(c for c in sw.expand() if c.label == label and c.seed == seed)
        rows.append(_row(cell.key, err=err, seed=seed, label=label))
    return summarize(sw, rows)


def test_compare_flags_significant_regressions(tmp_path):
    sw = _tiny_sweep(seeds=(0, 1, 2, 3, 4), baseline=None)
    a = _summary_with(
        {("a", s): 7.0 + 0.1 * s for s in range(5)}
        | {("b", s): 7.0 + 0.1 * s for s in range(5)},
        sweep=sw,
    )
    b = _summary_with(
        {("a", s): 7.0 + 0.1 * s for s in range(5)}  # unchanged
        | {("b", s): 12.0 + 0.3 * s for s in range(5)},  # much worse
        sweep=sw,
    )
    rows, regs = compare(a, b)
    assert len(regs) == 1
    assert regs[0]["variant"] == "b" and regs[0]["metric"] == "mean_dist_err"
    assert regs[0]["p_ttest"] < 0.05 and regs[0]["delta"] == pytest.approx(5.4)
    # an improvement is significant but NOT a regression
    rows_back, regs_back = compare(b, a)
    assert regs_back == []
    assert any(r["significant"] and not r["regression"] for r in rows_back)

    # the CLI wires this to exit codes
    pa, pb = tmp_path / "a.json", tmp_path / "b.json"
    pa.write_text(json.dumps(a))
    pb.write_text(json.dumps(b))
    assert sweeps_cli_main(["--compare", str(pa), str(pa)]) == 0
    assert sweeps_cli_main(["--compare", str(pa), str(pb)]) == 1
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"variants": {}}))
    assert sweeps_cli_main(["--compare", str(pa), str(empty)]) == 2


def test_single_seed_compare_cannot_reach_significance(tmp_path):
    sw = _tiny_sweep(seeds=(0,), baseline=None)
    a = _summary_with({("a", 0): 7.0, ("b", 0): 7.0}, sweep=sw)
    b = _summary_with({("a", 0): 7.0, ("b", 0): 12.0}, sweep=sw)
    rows, regs = compare(a, b)
    assert regs == []  # n=1: no p-value, never "significant"
    assert all(r["p_ttest"] is None for r in rows)


def test_check_regression_is_ci_aware_for_sweep_summaries(tmp_path):
    from benchmarks.check_regression import compare as gate

    def sweep_doc(mean, ci):
        return {
            "variants": {
                "v": {"metrics": {"mean_dist_err": {"mean": mean, "ci95": ci}}}
            }
        }

    # worse by >20% and >0.75 absolute, but CIs overlap -> pass
    assert gate(sweep_doc(5.0, 0.5), sweep_doc(6.5, 1.5), tol=0.2, abs_floor=0.75) == []
    # same deltas with tight CIs -> fail
    fails = gate(sweep_doc(5.0, 0.1), sweep_doc(6.5, 0.1), tol=0.2, abs_floor=0.75)
    assert len(fails) == 1 and "CIs separated" in fails[0]
    # legacy point-run files keep the original semantics
    legacy_base = {"configs": {"v": {"mean_dist_err": 5.0}}}
    legacy_cur = {"configs": {"v": {"mean_dist_err": 6.5}}}
    assert len(gate(legacy_base, legacy_cur, tol=0.2, abs_floor=0.75)) == 1
    assert gate(legacy_base, legacy_base, tol=0.2, abs_floor=0.75) == []
    # missing config still fails
    assert len(gate(legacy_base, {"configs": {}}, tol=0.2, abs_floor=0.75)) == 1


# ---------------------------------------------------------------------------
# CLIs: suggestions and exit codes
# ---------------------------------------------------------------------------
def test_suggestion_helper():
    assert close_matches("paper_fig3", ["paper_fig2", "topo_hub"]) == ["paper_fig2"]
    msg = unknown_name_message("scenario", "paper_fig3", ["paper_fig2"])
    assert "paper_fig3" in msg and "paper_fig2" in msg
    assert "--list" in unknown_name_message("scenario", "zzz", ["qq"])


def test_sweeps_cli_list_and_unknown_name(capsys):
    assert sweeps_cli_main(["--list"]) == 0
    assert "paper_table1_sweep" in capsys.readouterr().out
    assert sweeps_cli_main(["--sweep", "paper_table1_swep"]) == 2
    assert "did you mean" in capsys.readouterr().err
    assert sweeps_cli_main(["--sweep", "ci_smoke", "--seeds", "0"]) == 2
    assert sweeps_cli_main(["--sweep", "ci_smoke", "--budget", "0"]) == 2


def test_experiments_cli_unknown_scenario_suggests(capsys):
    from repro.experiments.__main__ import main as exp_main

    assert exp_main(["--scenario", "paper_fig3"]) == 2
    err = capsys.readouterr().err
    assert "unknown scenario" in err and "paper_fig2" in err


def test_registry_rejects_duplicate_sweeps():
    sw = next(iter(_REGISTRY.values()))
    from repro.sweeps import register_sweep

    with pytest.raises(ValueError, match="already registered"):
        register_sweep(sw)
