"""Launch/analysis layer: flop counter, collective parser, configs, specs."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import (
    ASSIGNED,
    INPUT_SHAPES,
    get_config,
    list_configs,
    param_count,
)
from repro.launch.analysis import _shape_bytes, count_flops, parse_collectives


def test_registry_has_all_assigned_archs():
    names = list_configs()
    for a in ASSIGNED:
        assert a in names
    assert len(ASSIGNED) == 10
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}


@pytest.mark.parametrize(
    "arch,lo,hi",
    [
        ("h2o-danube-3-4b", 3.0e9, 5.5e9),
        ("qwen2.5-14b", 12e9, 17e9),
        ("starcoder2-15b", 13e9, 18e9),
        ("deepseek-v2-lite-16b", 13e9, 19e9),
        ("qwen3-moe-235b-a22b", 2.0e11, 2.7e11),
        ("jamba-1.5-large-398b", 3.3e11, 4.6e11),
        ("xlstm-125m", 0.9e8, 2.2e8),
    ],
)
def test_param_counts_match_published_sizes(arch, lo, hi):
    total, active = param_count(get_config(arch))
    assert lo <= total <= hi, (arch, total)
    assert active <= total


def test_active_params_for_moe():
    total, active = param_count(get_config("qwen3-moe-235b-a22b"))
    # A22B: ~20-26B active of ~235B total
    assert 1.5e10 <= active <= 3.0e10


def test_flop_counter_exact_on_scan():
    def f(x, w):
        def body(c, _):
            return c @ w, None

        y, _ = jax.lax.scan(body, x, None, length=8)
        return jnp.sum(y)

    x = jnp.zeros((64, 64))
    w = jnp.zeros((64, 64))
    fl = count_flops(f, x, w)
    expect = 8 * 2 * 64**3
    assert abs(fl - expect) / expect < 0.01


def test_flop_counter_counts_grad():
    def f(x, w):
        return jnp.sum(jnp.tanh(x @ w))

    def g(x, w):
        return jax.grad(f, argnums=1)(x, w)

    x = jnp.zeros((32, 32))
    w = jnp.zeros((32, 32))
    fwd = count_flops(f, x, w)
    both = count_flops(g, x, w)
    # grad-only jaxpr (argnums=1) keeps fwd + the dw matmul; elementwise
    # tanh flops inflate fwd slightly, so assert >1.8x
    assert both >= 1.8 * fwd


def test_shape_bytes():
    assert _shape_bytes("f32[16,128]") == 16 * 128 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[4,4], s32[2])") == 64 + 8


def test_collective_parser_on_synthetic_hlo():
    hlo = """
HloModule test

%cond (p: s32[]) -> pred[] {
  %p = s32[] parameter(0)
  %c = s32[] constant(24)
  ROOT %lt = pred[] compare(%p, %c), direction=LT
}

%body (p: s32[]) -> s32[] {
  %p = s32[] parameter(0)
  %ag = f32[16,128]{1,0} all-gather(%x), dimensions={0}
  ROOT %n = s32[] add(%p, %one)
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%b), to_apply=%sum
  %w = s32[] while(%init), condition=%cond, body=%body
  ROOT %r = f32[4]{0} copy(%a)
}
"""
    res = parse_collectives(hlo)
    assert res["all-reduce"] == 4096
    assert res["all-gather"] == 24 * 16 * 128 * 4  # trip-multiplied


# ---------------------------------------------------------------------------
# serving CLI: prefill -> decode cache handoff
# ---------------------------------------------------------------------------


def test_load_prefill_copies_exact_and_prefix_leaves():
    from repro.launch.serve import _load_prefill

    dst = {
        "k": jnp.zeros((2, 4, 96, 8, 16), jnp.float32),
        "state": jnp.zeros((4, 32), jnp.float32),
    }
    src = {
        "k": jnp.ones((2, 4, 64, 8, 16), jnp.float32),
        "state": jnp.ones((4, 32), jnp.float32),
    }
    out = _load_prefill(None, dst, src, s=64)
    assert float(out["k"][:, :, :64].min()) == 1.0  # prefix copied
    assert float(out["k"][:, :, 64:].max()) == 0.0  # tail untouched
    assert float(out["state"].min()) == 1.0  # exact-shape leaf replaced


def test_load_prefill_raises_on_mismatched_leaf():
    from repro.launch.serve import _load_prefill

    dst = {"k": jnp.zeros((2, 4, 96, 8, 16), jnp.float32)}
    rank = {"k": jnp.ones((4, 64, 8, 16), jnp.float32)}  # rank mismatch
    with pytest.raises(ValueError, match="does not fit"):
        _load_prefill(None, dst, rank, s=64)
    wide = {"k": jnp.ones((2, 4, 64, 8, 32), jnp.float32)}  # axis too wide
    with pytest.raises(ValueError, match="does not fit"):
        _load_prefill(None, dst, wide, s=64)
