import os

# Smoke tests and benches must see ONE device — the 512-device override
# lives exclusively in repro.launch.dryrun (its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

# Property tests prefer real hypothesis (declared in requirements-dev);
# hermetic environments without it fall back to the in-repo mini engine,
# registered before any test module imports `hypothesis`.
try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro.testing.hypothesis_fallback import install

    install()


@pytest.fixture
def rng():
    return np.random.default_rng(0)
