import os

# Smoke tests and benches must see ONE device — the 512-device override
# lives exclusively in repro.launch.dryrun (its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
