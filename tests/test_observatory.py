"""Fleet observatory: the observe-only contract (bit-identity with the
observatory disabled AND enabled), per-agent learning-dynamics series,
knowledge-propagation / health report documents, Holm–Bonferroni
adjustment, the bounded streaming trace writer, and the rendered
dashboard (live run and saved trace)."""

import json
import math

import pytest

from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.experiments import ScenarioSpec
from repro.experiments.runner import run
from repro.sweeps.stats import holm_bonferroni
from repro.telemetry import (
    JsonlTraceSink,
    Telemetry,
    load_trace,
    render_dashboard,
    write_dashboard,
)
from repro.telemetry.__main__ import main as tel_main

TINY_DQN = DQNConfig(
    volume_shape=(12, 12, 12),
    box_size=(4, 4, 4),
    conv_features=(2,),
    hidden=(8,),
    batch_size=4,
    max_episode_steps=4,
    eps_decay_steps=20,
)
TINY_SYS = ADFLLConfig(
    n_agents=2,
    n_hubs=1,
    agent_hub=(0, 0),
    agent_speed=(1.0, 2.0),
    rounds=2,
    erb_capacity=128,
    erb_share_size=16,
    train_steps_per_round=2,
    hub_sync_period=0.5,
    share_planes=("erb", "weights"),  # exercise mixes + snapshot stamping
)


def _tiny_spec(**kw):
    base = dict(
        name="tiny",
        system="adfll",
        task_set="paper8",
        n_tasks=2,
        n_patients=8,
        dqn=TINY_DQN,
        sys=TINY_SYS,
        eval_patients=2,
        eval_episodes=2,
    )
    base.update(kw)
    return ScenarioSpec(**base)


def _fingerprint(report):
    s = dict(report.summary())
    s.pop("extra", None)
    curve = [
        (p.t, p.mean_err, tuple(sorted(p.per_agent.items())))
        for p in report.eval_curve
    ]
    hist = [
        (r.agent_id, r.task, r.start, r.end, r.n_incoming, r.loss)
        for r in report.history
    ]
    return json.dumps(s, sort_keys=True, default=str), curve, hist


@pytest.fixture(scope="module")
def observed():
    """One observed tiny run shared by the read-only assertions."""
    tel = Telemetry(enabled=True)
    report = run(_tiny_spec(), telemetry=tel)
    return tel, report


# ---------------------------------------------------------------------------
# observe-only contract: enabled observatory changes nothing
# ---------------------------------------------------------------------------
def test_enabled_observatory_is_bit_identical(observed):
    _, traced = observed
    base = run(_tiny_spec())
    assert _fingerprint(base) == _fingerprint(traced)


# ---------------------------------------------------------------------------
# learning dynamics
# ---------------------------------------------------------------------------
def test_per_agent_learning_series_and_summary(observed):
    tel, report = observed
    learning = report.extra["learning"]
    assert sorted(learning) == ["0", "1"]
    for label, doc in learning.items():
        assert doc["n_chunks"] >= 1
        assert doc["n_steps"] == doc["n_chunks"] * TINY_SYS.train_steps_per_round
        assert doc["last_loss"] is not None and math.isfinite(doc["last_loss"])
        assert doc["min_loss"] is not None and math.isfinite(doc["min_loss"])
        assert len(doc["loss_curve"]) == doc["n_chunks"]
        # the registry carries the same series, labeled by agent
        h = tel.registry.histogram("agent.loss", agent=label)
        assert h is not None and h["count"] == doc["n_chunks"]
        steps = tel.registry.counter_value("agent.steps_trained", agent=label)
        assert steps == doc["n_steps"]
    # loss is also a per-agent counter *event* timeline for the dashboard
    tracks = {
        e["track"]
        for e in tel.tracer.events
        if e["kind"] == "counter" and e["name"] == "agent.loss"
    }
    assert tracks == {"agent0", "agent1"}


# ---------------------------------------------------------------------------
# knowledge propagation
# ---------------------------------------------------------------------------
def test_propagation_document(observed):
    _, report = observed
    prop = report.extra["propagation"]
    # both agents pushed at least one round -> full version vector
    assert sorted(prop["version_vector"]) == ["0", "1"]
    assert all(r >= 1 for r in prop["version_vector"].values())
    assert prop["erb"]["n_pushed"] == 4  # 2 agents x 2 rounds
    assert prop["mix"]["n_mixes"] >= 1
    assert prop["mix"]["staleness"] is not None
    assert prop["mix"]["staleness"]["n"] == prop["mix"]["n_snapshots"]
    # influence re-weights sum over sources, one weight per folded snap
    assert all(v > 0 for v in prop["mix"]["influence_by_source"].values())
    assert prop["n_dropped_tracked"] == 0


def test_version_vectors_stamped_on_outgoing_records():
    from repro.core.federated import ADFLLSystem
    from repro.rl.synth import paper_eight_tasks, patient_split

    tasks = list(paper_eight_tasks())[:2]
    train_p, _ = patient_split(8)
    tel = Telemetry(enabled=True)
    system = ADFLLSystem(TINY_SYS, TINY_DQN, tasks, train_p, telemetry=tel)
    system.run()
    hub = system.network.hubs[0]
    erbs = list(hub.store("erb").values())
    snaps = list(hub.store("weights").values())
    assert erbs and snaps
    assert all(isinstance(e.meta.version_vector, tuple) for e in erbs)
    # at least the later records carry a non-empty vector
    assert any(e.meta.version_vector for e in erbs)
    assert any(s.version_vector for s in snaps)
    for s in snaps:
        for aid, rnd in s.version_vector:
            assert 0 <= aid < TINY_SYS.n_agents
            assert 0 <= rnd <= TINY_SYS.rounds


def test_default_records_carry_empty_version_vector():
    from repro.core.erb import TaskTag, erb_init
    from repro.core.plane import WeightSnapshot

    erb = erb_init(8, (4, 4, 4), task=TaskTag("t", "axial", "HGG"))
    assert erb.meta.version_vector == ()
    snap = WeightSnapshot(
        snap_id="s0", agent_id=0, round_idx=0, sim_time=0.0, params={}
    )
    assert snap.version_vector == ()


# ---------------------------------------------------------------------------
# health
# ---------------------------------------------------------------------------
def test_health_verdict_shape(observed):
    _, report = observed
    health = report.extra["health"]
    assert health["status"] in ("ok", "warn", "alert")
    assert set(health["counts"]) == {i["kind"] for i in health["incidents"]}
    # a healthy tiny run never alerts
    kinds = set(health["counts"])
    assert not kinds & {"nonfinite_params", "nonfinite_loss", "loss_divergence"}


def test_health_detectors_fire_on_bad_stats():
    import numpy as np

    from repro.observatory import Observatory

    tel = Telemetry(enabled=True)
    obs = Observatory(tel)
    obs.register_slot(0, 0)
    good = {
        "loss": np.full((2, 1), 1.0),
        "td_abs": np.zeros((2, 1)),
        "q_max": np.zeros((2, 1)),
        "grad_norm": np.zeros((2, 1)),
        "params_finite": np.array([True]),
    }
    for t in range(3):
        obs.on_flush([0], good, 1, float(t))
    diverged = dict(good, loss=np.full((2, 1), 100.0))
    obs.on_flush([0], diverged, 1, 3.0)
    nan = dict(good, loss=np.full((2, 1), np.nan), params_finite=np.array([False]))
    obs.on_flush([0], nan, 1, 4.0)
    verdict = obs.health.verdict(makespan=5.0)
    assert verdict["status"] == "alert"
    assert verdict["counts"]["loss_divergence"] == 1
    assert verdict["counts"]["nonfinite_params"] == 1
    # detectors fire once per agent, and each incident is a trace instant
    obs.on_flush([0], nan, 1, 5.0)
    assert obs.health.verdict(makespan=5.0)["counts"]["nonfinite_params"] == 1
    names = {e["name"] for e in tel.tracer.events if e["kind"] == "instant"}
    assert {"health.loss_divergence", "health.nonfinite_params"} <= names


def test_straggler_detection():
    import numpy as np

    from repro.observatory import Observatory

    obs = Observatory(Telemetry(enabled=True))
    stats = {
        "loss": np.full((1, 2), 1.0),
        "td_abs": np.zeros((1, 2)),
        "q_max": np.zeros((1, 2)),
        "grad_norm": np.zeros((1, 2)),
        "params_finite": np.array([True, True]),
    }
    obs.register_slot(0, 0)
    obs.register_slot(1, 1)
    obs.on_flush([0, 1], stats, 2, 1.0)  # both active early
    only0 = {
        k: (v[:, :1] if v.ndim == 2 else v[:1]) for k, v in stats.items()
    }
    obs.on_flush([0], only0, 1, 99.0)  # agent 0 keeps training
    verdict = obs.health.verdict(makespan=100.0)
    assert verdict["stragglers"] == [1]
    assert verdict["status"] == "warn"


# ---------------------------------------------------------------------------
# Holm–Bonferroni
# ---------------------------------------------------------------------------
def test_holm_bonferroni_adjustment():
    assert holm_bonferroni([]) == []
    assert holm_bonferroni([None]) == [None]
    # classic step-down: sorted p x (m - rank), running max, clipped
    adj = holm_bonferroni([0.01, 0.04, 0.03])
    assert adj == pytest.approx([0.03, 0.06, 0.06])
    # None / NaN positions pass through and do not count toward m
    adj = holm_bonferroni([0.01, None, float("nan"), 0.04])
    assert adj[1] is None and math.isnan(adj[2])
    assert adj[0] == pytest.approx(0.02)
    assert adj[3] == pytest.approx(0.04)
    # monotone in the input order of the sorted p's, never above 1
    assert holm_bonferroni([0.9, 0.8]) == [1.0, 1.0]


def test_compare_gates_on_adjusted_p():
    from repro.sweeps.aggregate import compare

    def _summary(vals_by_variant):
        return {
            "variants": {
                label: {
                    "metrics": {
                        m: {"values": {str(i): x for i, x in enumerate(vals)}}
                        for m, vals in ms.items()
                    }
                }
                for label, ms in vals_by_variant.items()
            }
        }

    a = _summary({"x": {"mean_dist_err": [1.0, 1.01, 0.99, 1.0, 1.02]}})
    b = _summary({"x": {"mean_dist_err": [1.5, 1.53, 1.47, 1.51, 1.54]}})
    rows, regressions = compare(a, b, alpha=0.05)
    (row,) = rows
    assert row["p_ttest_adj"] is not None
    assert row["p_ttest_adj"] >= row["p_ttest"]
    assert row["significant"] and row["regression"]
    assert regressions == [row]


# ---------------------------------------------------------------------------
# streaming trace writer
# ---------------------------------------------------------------------------
def test_streaming_sink_roundtrip(tmp_path):
    path = tmp_path / "stream.jsonl"
    tel = Telemetry(enabled=True, stream_path=path)
    for i in range(10):
        tel.instant("tick", "t", float(i))
    tel.count("comm.bytes", 42, plane="erb")
    assert len(tel.tracer.events) == 0  # streamed, not buffered
    tel.close()
    tel.close()  # idempotent
    doc = load_trace(path)
    assert len(doc["events"]) == 10
    counters = {m["name"]: m["value"] for m in doc["metrics"]}
    assert counters["comm.bytes"] == 42
    assert counters["trace.dropped"] == 0


def test_streaming_sink_byte_cap_drops_and_counts(tmp_path):
    path = tmp_path / "capped.jsonl"
    tel = Telemetry(enabled=True, stream_path=path, stream_max_bytes=600)
    for i in range(100):
        tel.instant("tick", "t", float(i))
    assert tel.sink.n_written < 100
    assert tel.tracer.n_dropped == 100 - tel.sink.n_written
    tel.close()
    doc = load_trace(path)
    assert len(doc["events"]) == tel.sink.n_written
    # metric rows are exempt from the cap: the dropped tally survives
    dropped = {
        m["value"] for m in doc["metrics"] if m["name"] == "trace.dropped"
    }
    assert dropped == {float(tel.tracer.n_dropped)}


def test_sink_refuses_after_close(tmp_path):
    sink = JsonlTraceSink(tmp_path / "s.jsonl")
    assert sink.write({"kind": "instant", "name": "a"})
    sink.close()
    assert not sink.write({"kind": "instant", "name": "b"})


# ---------------------------------------------------------------------------
# dashboard
# ---------------------------------------------------------------------------
def test_dashboard_from_live_run(tmp_path, observed):
    tel, _ = observed
    trace = {"events": list(tel.tracer.events), "metrics": tel.registry.summary()}
    out = write_dashboard(tmp_path / "dash.html", trace)
    html = out.read_text()
    assert html.startswith("<!doctype html>")
    for panel in (
        "Learning dynamics",
        "Staleness heatmap",
        "Health",
        "Span aggregates",
        "<svg",
        "<polyline",
    ):
        assert panel in html
    # self-contained: no external fetches (the SVG xmlns URI is a
    # namespace identifier, never dereferenced)
    for needle in ("src=", "href=", "<link", "@import", "url("):
        assert needle not in html


def test_dashboard_cli_from_saved_trace(tmp_path, capsys):
    trace_path = tmp_path / "t.jsonl"
    tel = Telemetry(enabled=True, stream_path=trace_path)
    tel.span("round", "agent0", 0.0, 1.0)
    tel.counter("agent.loss", "agent0", 0.5, 1.25)
    tel.close()
    out = tmp_path / "d.html"
    assert tel_main(["dashboard", str(trace_path), "-o", str(out)]) == 0
    assert "wrote" in capsys.readouterr().out
    html = out.read_text()
    assert "Learning dynamics" in html and "Span aggregates" in html


def test_dashboard_tolerates_empty_trace_and_embeds_sweep():
    html = render_dashboard(
        {"events": [], "metrics": []},
        sweep_summary={
            "comparisons": [
                {"arm": "x", "metric": "m", "p_ttest": 0.2, "p_ttest_adj": 0.4}
            ]
        },
        title="empty",
    )
    assert "Sweep comparison" in html
    assert "0.4" in html
