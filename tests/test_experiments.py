"""The declarative scenario/experiment API: protocol conformance,
registry integrity, churn determinism, hooks, heterogeneous links, and
the explicit PullResult/PushResult comm accounting."""

import dataclasses

import numpy as np
import pytest

from repro import experiments
from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.core.erb import TaskTag, erb_init
from repro.core.experiment import ChurnEvent, ExperimentHooks, HubFailure
from repro.core.federated import ADFLLSystem, CentralAggregationSystem
from repro.core.gossip import LinkModel, SiteLinks
from repro.core.hub import Hub
from repro.core.network import Network, PullResult, PushResult
from repro.experiments import BaselineSystem, ScenarioSpec, System
from repro.experiments.protocol import SupportsChurn
from repro.rl.synth import paper_eight_tasks, patient_split

TINY_DQN = DQNConfig(
    volume_shape=(12, 12, 12),
    box_size=(4, 4, 4),
    conv_features=(2,),
    hidden=(8,),
    batch_size=4,
    max_episode_steps=4,
    eps_decay_steps=20,
)
TINY_SYS = ADFLLConfig(
    n_agents=2,
    n_hubs=1,
    agent_hub=(0, 0),
    agent_speed=(1.0, 2.0),
    rounds=2,
    erb_capacity=128,
    erb_share_size=16,
    train_steps_per_round=2,
    hub_sync_period=0.5,
)
TASKS = paper_eight_tasks()[:2]
TRAIN_P, TEST_P = patient_split(8)


def _tiny_spec(**kw):
    base = dict(
        name="tiny",
        system="adfll",
        task_set="paper8",
        n_tasks=2,
        n_patients=8,
        dqn=TINY_DQN,
        sys=TINY_SYS,
        eval_patients=2,
        eval_episodes=2,
    )
    base.update(kw)
    return ScenarioSpec(**base)


# ---------------------------------------------------------------------------
# protocol conformance
# ---------------------------------------------------------------------------
def test_all_systems_conform_to_the_protocol():
    adfll = ADFLLSystem(TINY_SYS, TINY_DQN, TASKS, TRAIN_P)
    fedavg = CentralAggregationSystem(2, TINY_DQN, TASKS, TRAIN_P, rounds=1)
    assert isinstance(adfll, System)
    assert isinstance(adfll, SupportsChurn)
    assert isinstance(fedavg, System)
    assert not isinstance(fedavg, SupportsChurn)
    for kind in ("all_knowing", "partial", "sequential"):
        b = BaselineSystem(kind, TINY_DQN, TASKS, TRAIN_P, steps=2)
        assert isinstance(b, System)
        assert not isinstance(b, SupportsChurn)


def test_baseline_systems_run_and_evaluate():
    for kind, label in (
        ("all_knowing", "AgentX"),
        ("partial", "AgentY"),
        ("sequential", "AgentM"),
    ):
        b = BaselineSystem(kind, TINY_DQN, TASKS, TRAIN_P, steps=2, seed=7)
        report = b.run()
        assert report.system == kind and report.n_rounds >= 1
        errs = b.evaluate(TASKS, TEST_P, max_patients=2, n_episodes=2)
        assert set(errs) == {label}
        assert all(np.isfinite(v) for v in errs[label].values())


def test_baseline_evaluate_before_run_is_an_error():
    b = BaselineSystem("partial", TINY_DQN, TASKS, TRAIN_P)
    with pytest.raises(RuntimeError):
        b.evaluate(TASKS, TEST_P)


def test_central_aggregation_via_protocol():
    sysm = CentralAggregationSystem(
        2, TINY_DQN, TASKS, TRAIN_P, rounds=1, steps=2, erb_capacity=64
    )
    report = sysm.run()
    assert report.system == "fedavg" and report.n_rounds == 2
    errs = sysm.evaluate(TASKS, TEST_P, max_patients=2, n_episodes=2)
    assert set(errs) == {"FedAvg"}
    assert all(np.isfinite(v) for v in errs["FedAvg"].values())


# ---------------------------------------------------------------------------
# registry + spec
# ---------------------------------------------------------------------------
def test_registry_has_the_required_scenarios():
    names = {s.name for s in experiments.list_scenarios()}
    assert len(names) >= 5
    assert {
        "paper_fig2",
        "churn_addition_fig4",
        "churn_deletion_fig5",
        "gossip_hetero",
        "fedavg_sync",
    } <= names
    churn_spec = experiments.get_scenario("churn_addition_fig4")
    assert churn_spec.churn and all(e.action == "add" for e in churn_spec.churn)
    hetero = experiments.get_scenario("gossip_hetero")
    assert hetero.agent_sites and hetero.intra_link and hetero.inter_link


def test_specs_are_frozen_and_variants_derive():
    spec = experiments.get_scenario("paper_fig2")
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.seed = 99
    reseeded = spec.with_seed(99)
    assert reseeded.seed == 99 and reseeded.sys.seed == 99  # one seed
    fast = spec.fast()
    assert (
        fast.sys.train_steps_per_round
        <= min(spec.sys.train_steps_per_round, spec.fast_train_steps)
    )
    assert spec.sys.train_steps_per_round == 80  # original untouched


def test_duplicate_registration_is_rejected():
    spec = experiments.get_scenario("paper_fig2")
    with pytest.raises(ValueError):
        experiments.register(spec)


def test_unknown_scenario_names_fail_loudly():
    with pytest.raises(KeyError, match="registered"):
        experiments.get_scenario("no_such_scenario")
    with pytest.raises(ValueError):
        ScenarioSpec(name="x", system="no_such_system")


# ---------------------------------------------------------------------------
# runner: end-to-end, churn determinism, hooks
# ---------------------------------------------------------------------------
def test_runner_produces_a_complete_report():
    report = experiments.run(_tiny_spec(), seed=1)
    assert report.scenario == "tiny" and report.system == "adfll"
    assert report.seed == 1
    assert report.makespan > 0 and report.n_rounds >= 4
    assert np.isfinite(report.mean_dist_err)
    assert report.best_agent_err <= report.mean_dist_err
    assert set(report.task_errors) == {"Agent1", "Agent2"}
    assert report.eval_patients == 2 and report.eval_episodes == 2
    assert report.eval_curve[-1].mean_err == pytest.approx(report.mean_dist_err)
    assert report.records_known.get("erb", 0) > 0


def _churn_fingerprint():
    spec = _tiny_spec(
        sys=dataclasses.replace(TINY_SYS, rounds=1),
        churn=(
            ChurnEvent(at=0.6, action="add", count=2),
            ChurnEvent(at=1.2, action="remove", count=1),
        ),
    )
    report = experiments.run(spec, seed=5)
    hist = [
        (r.agent_id, r.round_idx, r.task, round(r.end, 9), r.n_incoming)
        for r in report.history
    ]
    curve = [
        (round(p.t, 9), p.n_agents, round(p.mean_err, 9)) for p in report.eval_curve
    ]
    return hist, curve, report.makespan


def test_churn_schedule_is_deterministic():
    h1, c1, m1 = _churn_fingerprint()
    h2, c2, m2 = _churn_fingerprint()
    assert h1 == h2 and c1 == c2 and m1 == m2
    # the schedule actually changed membership: agents 2,3 joined, one left
    agent_ids = {a for a, *_ in h1}
    assert {2, 3} & agent_ids
    # probes fired at both churn times plus the final evaluation
    assert [t for t, _, _ in c1[:-1]] == [0.6, 1.2]
    assert c1[0][1] == 2  # before the addition: two live agents


def test_churn_remove_handles_unknown_ids_and_empty_membership():
    spec = _tiny_spec(
        sys=dataclasses.replace(TINY_SYS, rounds=1),
        churn=(
            ChurnEvent(at=0.4, action="remove", agent_id=99),  # unknown: no-op
            ChurnEvent(at=0.8, action="remove", count=5),  # removes everyone
        ),
        eval_at_churn=False,
    )
    report = experiments.run(spec, seed=3)
    assert report.task_errors == {}  # no live agents left to evaluate
    assert np.isnan(report.mean_dist_err) and np.isnan(report.best_agent_err)


def test_lifecycle_hooks_fire_and_do_not_perturb_the_run():
    class Counter(ExperimentHooks):
        def __init__(self):
            self.counts = {}

        def _bump(self, key):
            self.counts[key] = self.counts.get(key, 0) + 1

        def on_round_start(self, system, agent_id, task, t):
            self._bump("round_start")

        def on_push(self, system, agent_id, plane, result, t):
            self._bump(f"push_{plane}")

        def on_round_end(self, system, record):
            self._bump("round_end")

        def on_churn(self, system, event, agent_ids, t):
            self._bump("churn")

    spec = _tiny_spec(churn=(ChurnEvent(at=0.6, action="add"),))
    counter = Counter()
    with_hooks = experiments.run(spec, seed=2, hooks=(counter,))
    bare = experiments.run(spec, seed=2)
    assert counter.counts["round_end"] == with_hooks.n_rounds
    assert counter.counts["round_start"] >= counter.counts["round_end"]
    assert counter.counts["push_erb"] > 0
    assert counter.counts["churn"] == 1
    # hooks are observers: identical trajectory with and without them
    assert [
        (r.agent_id, r.task, round(r.end, 9)) for r in with_hooks.history
    ] == [(r.agent_id, r.task, round(r.end, 9)) for r in bare.history]


def test_history_recorder_is_a_hook_not_inline_state():
    sysm = ADFLLSystem(TINY_SYS, TINY_DQN, TASKS, TRAIN_P, seed=0)
    assert sysm.history is sysm._recorder.records
    sysm.run()
    assert len(sysm.history) == len(sysm._recorder.records) > 0


# ---------------------------------------------------------------------------
# seed unification
# ---------------------------------------------------------------------------
def test_single_seed_drives_every_stream():
    """The ctor seed (defaulting to cfg.seed) seeds the agents too — the
    old split where agents read cfg.seed while the rng read the ctor
    seed is gone."""
    cfg = dataclasses.replace(TINY_SYS, seed=0)
    a = ADFLLSystem(cfg, TINY_DQN, TASKS, TRAIN_P, seed=11)
    b = ADFLLSystem(dataclasses.replace(cfg, seed=11), TINY_DQN, TASKS, TRAIN_P)
    assert a.seed == b.seed == 11
    import jax

    for aid in a.agents:
        for xa, xb in zip(
            jax.tree_util.tree_leaves(a.agents[aid].params),
            jax.tree_util.tree_leaves(b.agents[aid].params),
            strict=True,
        ):
            np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


# ---------------------------------------------------------------------------
# evaluate_on_tasks explicit parameters
# ---------------------------------------------------------------------------
def test_evaluate_on_tasks_parameters_are_explicit():
    from repro.core.federated import evaluate_on_tasks

    agent = BaselineSystem("partial", TINY_DQN, TASKS, TRAIN_P, steps=2)
    agent.run()
    few = evaluate_on_tasks(
        agent.agent, TASKS[:1], TEST_P, TINY_DQN, max_patients=1, n_episodes=1
    )
    all_p = evaluate_on_tasks(
        agent.agent, TASKS[:1], TEST_P, TINY_DQN, max_patients=None, n_episodes=1
    )
    assert set(few) == set(all_p) == {TASKS[0].name}
    assert np.isfinite(few[TASKS[0].name]) and np.isfinite(all_p[TASKS[0].name])


# ---------------------------------------------------------------------------
# PullResult / PushResult comm accounting (ex-last_comm_time)
# ---------------------------------------------------------------------------
def _erb(seed=0):
    rng = np.random.default_rng(seed)
    erb = erb_init(8, (4, 4, 4), task=TaskTag("t1", "axial", "HGG"))
    from repro.core.erb import erb_add

    erb_add(
        erb,
        {
            "obs": rng.standard_normal((2, 4, 4, 4)).astype(np.float32),
            "loc": rng.standard_normal((2, 3)).astype(np.float32),
            "action": rng.integers(0, 6, 2).astype(np.int32),
            "reward": rng.standard_normal(2).astype(np.float32),
            "next_obs": rng.standard_normal((2, 4, 4, 4)).astype(np.float32),
            "next_loc": rng.standard_normal((2, 3)).astype(np.float32),
            "done": np.zeros(2, np.float32),
        },
    )
    return erb


def test_pull_result_accounts_per_record_link_time():
    link = LinkModel(latency=0.25, rate=1000.0)
    net = Network(hubs=[Hub(0)], rng=np.random.default_rng(0), link=link)
    net.attach_agent(0, 0)
    net.attach_agent(1, 0)
    nbytes = []
    for s in range(3):
        rec = _erb(seed=s)
        nbytes.append(net.planes["erb"].payload_nbytes(rec))
        res = net.agent_push(0, rec)
        assert isinstance(res, PushResult) and res
        assert res.comm_time == pytest.approx(link.transfer_time(nbytes[-1]))
    pulled = net.agent_pull(1, set())
    assert isinstance(pulled, PullResult) and len(pulled) == 3
    # the explicit result sums exactly what last_comm_time used to expose
    expected = sum(link.transfer_time(n) for n in nbytes)
    assert pulled.comm_time == pytest.approx(expected)
    assert pulled.nbytes == sum(nbytes)
    # list-compatible: iteration, indexing, equality
    assert list(pulled) == [pulled[0], pulled[1], pulled[2]]
    assert net.agent_pull(1, net.all_known("erb")) == []


def test_free_links_charge_zero_comm_time():
    net = Network(hubs=[Hub(0)], rng=np.random.default_rng(0))
    net.attach_agent(0, 0)
    res = net.agent_push(0, _erb())
    assert res and res.comm_time == 0.0 and res.nbytes > 0
    pulled = net.agent_pull(0, set())
    assert pulled.comm_time == 0.0 and len(pulled) == 1


# ---------------------------------------------------------------------------
# per-link heterogeneous rates
# ---------------------------------------------------------------------------
def test_site_links_pick_intra_vs_inter():
    fast = LinkModel(latency=0.001, rate=1e6)
    slow = LinkModel(latency=0.1, rate=1e3)
    sl = SiteLinks(
        default=LinkModel(),
        agent_site={0: 0, 1: 0, 2: 1},
        hub_site={0: 0},
        intra=fast,
        inter=slow,
    )
    assert sl.pair(0, 1) is fast
    assert sl.pair(0, 2) is slow
    assert sl.pair(0, 99) == LinkModel()  # unknown endpoint -> default
    assert sl.agent_hub(0, 0) is fast
    assert sl.agent_hub(2, 0) is slow
    assert sl.agent_hub(0, None) == LinkModel()


def test_network_hub_leg_is_priced_per_site():
    fast = LinkModel(latency=0.0, rate=float("inf"))
    slow = LinkModel(latency=0.5, rate=1000.0)
    net = Network(hubs=[Hub(0)], rng=np.random.default_rng(0))
    net.attach_agent(0, 0)
    net.attach_agent(1, 0)
    net.configure_sites({0: 0, 1: 1}, hub_site={0: 0}, intra=fast, inter=slow)
    local = net.agent_push(0, _erb(seed=0))  # same site as the hub
    remote = net.agent_push(1, _erb(seed=1))  # cross-site
    assert local.comm_time == 0.0
    assert remote.comm_time == pytest.approx(slow.transfer_time(remote.nbytes))


def test_gossip_hetero_scenario_runs_and_prices_cross_site_traffic():
    report = experiments.run("gossip_hetero", fast=True, seed=0)
    assert np.isfinite(report.mean_dist_err)
    assert report.extra["gossip"]["delivered"] > 0
    assert report.total_bytes > 0


# ---------------------------------------------------------------------------
# hub failures (Table 2)
# ---------------------------------------------------------------------------
def test_registry_has_the_table2_hub_failure_scenarios():
    for name in (
        "paper_table2_hub_failure",
        "paper_table2_total_failure",
        "paper_table2_hybrid_failover",
    ):
        spec = experiments.get_scenario(name)
        assert spec.hub_failures and all(e.at > 0 for e in spec.hub_failures)
    hybrid = experiments.get_scenario("paper_table2_hybrid_failover")
    assert hybrid.sys.topology == "hybrid"
    # failover kills every hub
    assert {e.hub_id for e in hybrid.hub_failures} == set(
        range(hybrid.sys.n_hubs)
    )


def test_hub_failure_schedule_fires_probes_and_rehomes():
    two_hubs = dataclasses.replace(TINY_SYS, n_hubs=2, agent_hub=(0, 1))
    spec = _tiny_spec(
        sys=two_hubs,
        hub_failures=(HubFailure(at=0.7, hub_id=1),),
    )

    class Obs(ExperimentHooks):
        def __init__(self):
            self.events = []

        def on_hub_failure(self, system, event, orphaned, t):
            self.events.append((event.hub_id, tuple(orphaned), t))

    obs = Obs()
    report = experiments.run(spec, seed=3, hooks=(obs,))
    assert obs.events == [(1, (1,), 0.7)]  # agent 1 orphaned at t=0.7
    assert np.isfinite(report.mean_dist_err)
    # a probe fired at the failure time, before the final evaluation
    assert report.eval_curve[0].t == pytest.approx(0.7)
    assert report.eval_curve[0].n_agents == 2


def test_total_hub_failure_is_survivable_in_pure_hub_topology():
    spec = _tiny_spec(
        hub_failures=(HubFailure(at=0.7, hub_id=0),),  # TINY_SYS has one hub
        eval_at_churn=False,
    )
    report = experiments.run(spec, seed=3)
    # orphaned agents finish their rounds on local data alone
    assert np.isfinite(report.mean_dist_err)
    assert report.n_rounds >= 4


def test_hub_failure_determinism_and_gossip_rejection():
    spec = _tiny_spec(hub_failures=(HubFailure(at=0.7, hub_id=0),))
    r1 = experiments.run(spec, seed=5)
    r2 = experiments.run(spec, seed=5)
    assert [
        (r.agent_id, r.task, round(r.end, 9)) for r in r1.history
    ] == [(r.agent_id, r.task, round(r.end, 9)) for r in r2.history]
    with pytest.raises(ValueError, match="no hubs"):
        _tiny_spec(
            sys=dataclasses.replace(TINY_SYS, topology="gossip"),
            hub_failures=(HubFailure(at=0.7, hub_id=0),),
        )
    with pytest.raises(ValueError):
        HubFailure(at=0.5, hub_id=-1)


def test_orphaned_agents_cannot_push_or_pull_via_dead_hubs():
    net = Network(hubs=[Hub(0)], rng=np.random.default_rng(0))
    net.attach_agent(0, 0)
    assert net.agent_push(0, _erb(seed=0))
    assert net.fail_hub(0) == [0]
    assert 0 not in net.agent_hub  # no survivor to re-home to
    res = net.agent_push(0, _erb(seed=1))
    assert not res and res.nbytes == 0
    assert net.agent_pull(0, set()) == []
    assert net.n_dropped >= 1
    # a joiner after total failure stays detached instead of crashing
    # (churn "add" events can follow a total hub failure in a scenario)
    net.attach_agent(1)
    assert 1 not in net.agent_hub
    assert not net.agent_push(1, _erb(seed=2))


# ---------------------------------------------------------------------------
# task curricula
# ---------------------------------------------------------------------------
def test_blocked_and_shuffled_curricula():
    cfg = dataclasses.replace(TINY_SYS, task_curriculum="blocked")
    sysm = ADFLLSystem(cfg, TINY_DQN, TASKS, TRAIN_P, seed=0)
    draws = [sysm._next_task().name for _ in range(6)]
    # one task per cohort of n_agents draws before advancing
    assert draws[0] == draws[1] and draws[2] == draws[3]
    assert draws[0] != draws[2]

    cfg = dataclasses.replace(TINY_SYS, task_curriculum="shuffled")
    s1 = ADFLLSystem(cfg, TINY_DQN, TASKS, TRAIN_P, seed=0)
    s2 = ADFLLSystem(cfg, TINY_DQN, TASKS, TRAIN_P, seed=0)
    seq1 = [s1._next_task().name for _ in range(4)]
    seq2 = [s2._next_task().name for _ in range(4)]
    assert seq1 == seq2  # seeded
    assert sorted(seq1[:2]) == sorted(t.name for t in TASKS)  # a full pass

    with pytest.raises(ValueError):
        ADFLLSystem(
            dataclasses.replace(TINY_SYS, task_curriculum="nope"),
            TINY_DQN,
            TASKS,
            TRAIN_P,
        )
