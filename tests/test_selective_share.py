"""Beyond-paper: reward-weighted selective sharing (Rolnick-style)."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.erb import TaskTag, erb_add, erb_init, erb_share_slice

TASK = TaskTag("t1", "axial", "HGG")
OBS = (4, 4, 4)


def _erb_with_rewards(rewards):
    n = len(rewards)
    erb = erb_init(max(n, 4), OBS, task=TASK)
    batch = {
        "obs": np.zeros((n, *OBS), np.float32),
        "loc": np.zeros((n, 3), np.float32),
        "action": np.arange(n, dtype=np.int32),
        "reward": np.asarray(rewards, np.float32),
        "next_obs": np.zeros((n, *OBS), np.float32),
        "next_loc": np.zeros((n, 3), np.float32),
        "done": np.zeros(n, np.float32),
    }
    return erb_add(erb, batch)


def test_reward_strategy_prefers_high_surprise():
    # 50 boring (0 reward) + 10 surprising experiences
    rewards = [0.0] * 50 + [5.0] * 10
    erb = _erb_with_rewards(rewards)
    hits = 0
    trials = 50
    for s in range(trials):
        shared = erb_share_slice(erb, 5, np.random.default_rng(s), strategy="reward")
        hits += int((np.abs(shared.data["reward"]) > 1).sum())
    # uniform would pick ~10/60 * 5 = 0.83 surprising per share;
    # reward-weighted should pick far more
    assert hits / trials > 2.5


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 40),
    share=st.integers(1, 20),
    strategy=st.sampled_from(["uniform", "reward"]),
)
def test_share_strategies_preserve_invariants(n, share, strategy):
    rng = np.random.default_rng(0)
    erb = _erb_with_rewards(rng.standard_normal(n).tolist())
    shared = erb_share_slice(erb, share, rng, strategy=strategy)
    assert shared.size == min(n, share)
    # no duplicate experiences in a share (sampling without replacement)
    assert len(set(shared.data["action"].tolist())) == shared.size
