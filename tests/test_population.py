"""Population simulator: spec validation, availability-timeline
determinism, Handle-based scheduler cancellation, the log ring buffer,
availability-aware gossip, offline round deferral, and cross-process
bit-reproducibility of PopulationSpec-driven runs."""

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import experiments
from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.core.erb import TaskTag, erb_init
from repro.core.experiment import ChurnEvent, ExperimentHooks, HubFailure
from repro.core.federated import ADFLLSystem
from repro.core.gossip import FullMeshSampler, GossipTopology
from repro.core.plane import ERBPlane
from repro.core.scheduler import Scheduler
from repro.experiments import ScenarioSpec
from repro.population import (
    Cohort,
    Departure,
    Diurnal,
    HubOutage,
    PopulationSpec,
    Sessions,
    Trace,
    availability_segments,
    load_windows,
    member_rng,
    save_windows,
)
from repro.rl.synth import paper_eight_tasks, patient_split

TINY_DQN = DQNConfig(
    volume_shape=(12, 12, 12),
    box_size=(4, 4, 4),
    conv_features=(2,),
    hidden=(8,),
    batch_size=4,
    max_episode_steps=4,
    eps_decay_steps=20,
)
TINY_SYS = ADFLLConfig(
    n_agents=2,
    n_hubs=1,
    agent_hub=(0, 0),
    agent_speed=(1.0, 2.0),
    rounds=2,
    erb_capacity=128,
    erb_share_size=16,
    train_steps_per_round=2,
    hub_sync_period=0.5,
)
TASKS = paper_eight_tasks()[:2]
TRAIN_P, TEST_P = patient_split(8)


# ---------------------------------------------------------------------------
# scheduler: Handle cancellation + log ring buffer
# ---------------------------------------------------------------------------
def test_handle_cancels_a_pending_event_and_skips_its_log_entry():
    s = Scheduler()
    fired = []
    h = s.at(1.0, lambda sc, t: fired.append("a"), tag="a")
    s.at(2.0, lambda sc, t: fired.append("b"), tag="b")
    assert h.active
    h.cancel()
    assert not h.active
    s.run()
    assert fired == ["b"]
    assert [tag for _, tag in s.log] == ["b"]  # skipped events are not logged


def test_every_handle_cancels_from_outside():
    s = Scheduler()
    ticks = []
    h = s.every(1.0, lambda sc, t: ticks.append(t))
    s.at(2.5, lambda sc, t: h.cancel())
    s.run(until=10.0)
    assert ticks == [1.0, 2.0]


def test_every_handle_cancels_from_inside_its_own_callback():
    # the documented limitation of tag-based cancel: the periodic re-arm
    # happens after the callback returns, so only the Handle can do this
    s = Scheduler()
    ticks = []

    def fn(sc, t):
        ticks.append(t)
        if len(ticks) == 3:
            h.cancel()

    h = s.every(1.0, fn)
    s.run(until=10.0)
    assert ticks == [1.0, 2.0, 3.0]


def test_tag_cancel_shim_still_stops_periodic_timers():
    s = Scheduler()
    ticks = []
    s.every(1.0, lambda sc, t: ticks.append(t), tag="tick")
    s.at(2.5, lambda sc, t: sc.cancel("tick"))
    s.run(until=10.0)
    assert ticks == [1.0, 2.0]


def test_log_ring_buffer_keeps_newest_and_counts_drops():
    s = Scheduler(log_max=3)
    for i in range(10):
        s.at(float(i), lambda sc, t: None, tag=f"e{i}")
    s.run()
    assert len(s.log) == 3
    assert s.log_dropped == 7
    assert [tag for _, tag in s.log] == ["e7", "e8", "e9"]
    unbounded = Scheduler()  # default: unbounded list, nothing dropped
    unbounded.at(0.0, lambda sc, t: None, tag="x")
    unbounded.run()
    assert unbounded.log_dropped == 0 and len(unbounded.log) == 1


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------
def test_spec_validation_rejects_bad_values():
    with pytest.raises(ValueError):
        Diurnal(period=0.0)
    with pytest.raises(ValueError):
        Diurnal(on_fraction=0.0)
    with pytest.raises(ValueError):
        Sessions(mean_on=0.0)
    with pytest.raises(ValueError):
        Sessions(distribution="weibull")
    with pytest.raises(ValueError):
        Trace(windows=((0.5, 0.2),))  # off before on
    with pytest.raises(ValueError):
        Trace(windows=((0.0, 1.0), (0.5, 2.0)))  # overlapping
    with pytest.raises(ValueError):
        Trace(windows=((0.0, 3.0),), repeat=2.0)  # repeat inside windows
    with pytest.raises(ValueError):
        Cohort(n_agents=0)
    with pytest.raises(ValueError):
        Cohort(n_agents=1, arrive_at=1.0, depart_at=0.5)
    with pytest.raises(ValueError):
        Cohort(n_agents=1, speed=0.0)
    with pytest.raises(ValueError):
        Departure(at=1.0, agent_id=3, count=2)
    with pytest.raises(ValueError):
        HubOutage(at=1.0, hub_id=-1)
    with pytest.raises(ValueError):
        PopulationSpec()  # empty


def test_population_spec_event_times_scaled_and_n_agents():
    pop = PopulationSpec(
        cohorts=(
            Cohort(n_agents=10),
            Cohort(n_agents=40, arrive_at=1.0, depart_at=3.0),
        ),
        departures=(Departure(at=2.0, count=2),),
        hub_outages=(HubOutage(at=2.5, hub_id=0),),
    )
    assert pop.n_agents == 50
    assert pop.event_times() == (0.0, 1.0, 2.0, 2.5, 3.0)
    small = pop.scaled(0.1)
    assert [c.n_agents for c in small.cohorts] == [1, 4]
    assert small.cohorts[1].depart_at == 3.0  # dynamics untouched
    assert pop.scaled(1.0) is pop


def test_from_churn_lifts_classic_schedules():
    pop = PopulationSpec.from_churn(
        events=(
            ChurnEvent(at=1.6, action="add", count=4, speed=2.0, hub=1),
            ChurnEvent(at=0.8, action="remove", count=2),
        ),
        hub_failures=(HubFailure(at=1.5, hub_id=0),),
    )
    (cohort,) = pop.cohorts
    assert (cohort.arrive_at, cohort.n_agents, cohort.speed, cohort.hub) == (
        1.6,
        4,
        2.0,
        1,
    )
    (dep,) = pop.departures
    assert (dep.at, dep.count) == (0.8, 2)
    (outage,) = pop.hub_outages
    assert (outage.at, outage.hub_id) == (1.5, 0)


def test_scenario_spec_population_validation():
    pop = PopulationSpec(cohorts=(Cohort(n_agents=2),))
    base = dict(
        name="t",
        system="adfll",
        n_tasks=2,
        n_patients=8,
        dqn=TINY_DQN,
        sys=TINY_SYS,
    )
    spec = ScenarioSpec(population=pop, fast_population_scale=0.5, **base)
    assert spec.fast().population.cohorts[0].n_agents == 1
    with pytest.raises(ValueError, match="exclusive"):
        ScenarioSpec(
            population=pop, churn=(ChurnEvent(at=1.0, action="add"),), **base
        )
    with pytest.raises(ValueError, match="not 'adfll'"):
        ScenarioSpec(**{**base, "system": "sequential"}, population=pop)
    with pytest.raises(ValueError, match="no cohorts"):
        ScenarioSpec(
            population=PopulationSpec(departures=(Departure(at=1.0),)), **base
        )
    with pytest.raises(ValueError, match="no hubs"):
        ScenarioSpec(
            population=PopulationSpec(
                cohorts=(Cohort(n_agents=2),),
                hub_outages=(HubOutage(at=1.0, hub_id=0),),
            ),
            **{**base, "sys": dataclasses.replace(TINY_SYS, topology="gossip")},
        )


# ---------------------------------------------------------------------------
# availability timelines (pure, deterministic)
# ---------------------------------------------------------------------------
def _take(avail, seed, n, member_idx=0):
    segs = availability_segments(
        avail, np.random.default_rng(seed), member_idx=member_idx
    )
    out = []
    for _ in range(n):
        seg = next(segs, None)
        if seg is None:
            break
        out.append(seg)
    return out


def test_diurnal_segments_alternate_and_cover_the_period():
    segs = _take(Diurnal(period=2.0, on_fraction=0.75, phase=0.5), seed=0, n=7)
    assert segs[0] == (1.0, True)  # 0.5 into a 1.5-long on-window
    assert [on for _, on in segs] == [True, False, True, False, True, False, True]
    assert all(
        d == pytest.approx(1.5 if on else 0.5) for d, on in segs[1:]
    )
    always_on = _take(Diurnal(on_fraction=1.0), seed=0, n=3)
    assert always_on == []  # finite stream = online forever


def test_session_segments_draw_from_the_distribution():
    fixed = _take(Sessions(mean_on=2.0, mean_off=0.5, distribution="fixed"), 0, 4)
    assert fixed == [(2.0, True), (0.5, False), (2.0, True), (0.5, False)]
    exp = _take(Sessions(mean_on=1.0, mean_off=1.0, distribution="exp"), 3, 200)
    on_mean = np.mean([d for d, on in exp if on])
    assert 0.5 < on_mean < 2.0  # law of large numbers, loose bounds
    logn = _take(Sessions(distribution="lognormal", sigma=1.0), 3, 10)
    assert all(d > 0 for d, _ in logn)


def test_trace_segments_replay_windows_and_stagger():
    tr = Trace(windows=((0.5, 1.0), (2.0, 3.0)))
    assert _take(tr, 0, 10) == [
        (0.5, False),
        (0.5, True),
        (1.0, False),
        (1.0, True),
    ]  # finite: online forever after the last window
    staggered = _take(tr, 0, 10, member_idx=2)
    assert staggered[0] == (0.5 + 2 * tr.stagger, False) or tr.stagger == 0.0
    tiled = _take(Trace(windows=((0.0, 1.0),), repeat=2.0), 0, 6)
    assert tiled == [(1.0, True), (1.0, False)] * 3  # infinite tiling


def test_timelines_are_bit_identical_for_identical_seeds():
    avail = Sessions(distribution="lognormal", sigma=0.8)
    a = _take(avail, seed=(7, 0x706F70, 1, 2), n=50)
    b = _take(avail, seed=(7, 0x706F70, 1, 2), n=50)
    assert a == b
    c = _take(avail, seed=(8, 0x706F70, 1, 2), n=50)
    assert a != c
    # the compile-time member streams are disjoint per (cohort, member)
    r1, r2 = member_rng(7, 0, 0), member_rng(7, 0, 1)
    assert r1.uniform() != r2.uniform()


def test_trace_files_round_trip(tmp_path):
    windows = ((0.25, 1.5), (2.0, 2.75))
    path = tmp_path / "avail.jsonl"
    save_windows(path, windows)
    assert load_windows(path) == windows
    assert Trace(windows=load_windows(path)).windows == windows
    path.write_text('{"on": 0.1}\n')
    with pytest.raises(ValueError, match="bad trace row"):
        load_windows(path)


# ---------------------------------------------------------------------------
# availability-aware gossip
# ---------------------------------------------------------------------------
class _RecordingSampler(FullMeshSampler):
    def __init__(self):
        self.seen = []

    def peers(self, agent_id, ids):
        self.seen.append(tuple(ids))
        return super().peers(agent_id, ids)


def test_gossip_never_samples_an_offline_peer():
    online = {0: True, 1: False, 2: True}
    sampler = _RecordingSampler()
    topo = GossipTopology(
        {"erb": ERBPlane()},
        sampler,
        rng=np.random.default_rng(0),
        online=lambda a: online[a],
    )
    task = TaskTag("t1", "axial", "HGG")
    for a in (0, 1, 2):
        topo.add_agent(a)
        erb = erb_init(4, (2, 2, 2), task=task, source_agent=a)
        erb.size = 4
        topo.insert_local(a, erb, topo.planes["erb"])
    for _ in range(4):
        topo.anti_entropy()
    assert sampler.seen and all(1 not in ids for ids in sampler.seen)
    # the offline agent neither received nor spread records
    assert len(topo.local_store(1, "erb")) == 1
    assert len(topo.local_store(0, "erb")) == 2  # its own + the online peer's
    online[1] = True  # back online: next round reaches it
    topo.anti_entropy()
    assert len(topo.local_store(1, "erb")) == 3


# ---------------------------------------------------------------------------
# offline agents in the system
# ---------------------------------------------------------------------------
def test_offline_agent_defers_rounds_until_back_online():
    toggles = []

    class Obs(ExperimentHooks):
        def on_availability(self, system, agent_id, on, t):
            toggles.append((agent_id, on, t))

    system = ADFLLSystem(
        dataclasses.replace(TINY_SYS, rounds=1),
        TINY_DQN,
        TASKS,
        TRAIN_P,
        hooks=(Obs(),),
    )
    system.set_online(0, False)
    system.sched.at(1.5, lambda s, t: system.set_online(0, True))
    report = system.run()
    starts = {r.agent_id: r.start for r in report.history}
    assert starts[1] == 0.0  # the online agent started immediately
    assert starts[0] >= 1.5  # the offline one waited for its window
    assert all(a.rounds_done >= 1 for a in system.agents.values())
    assert toggles == [(0, False, 0.0), (0, True, 1.5)]


def test_population_run_applies_cohorts_departures_and_availability():
    pop = PopulationSpec(
        cohorts=(
            Cohort(
                n_agents=2,
                availability=Trace(windows=((0.6, 1.4),), stagger=0.2),
            ),
            Cohort(n_agents=2, arrive_at=0.5, arrive_spread=0.4, speed_sigma=0.5),
        ),
        departures=(Departure(at=2.0, count=1),),
    )
    spec = ScenarioSpec(
        name="tiny_pop",
        system="adfll",
        n_tasks=2,
        n_patients=8,
        dqn=TINY_DQN,
        sys=dataclasses.replace(TINY_SYS, rounds=1),
        population=pop,
        eval_patients=2,
        eval_episodes=2,
    )

    def fingerprint():
        report = experiments.run(spec, seed=9)
        hist = [
            (r.agent_id, r.round_idx, r.task, round(r.start, 9), round(r.end, 9))
            for r in report.history
        ]
        return hist, report.makespan, report.extra["population"]

    h1, m1, p1 = fingerprint()
    h2, m2, p2 = fingerprint()
    assert (h1, m1, p1) == (h2, m2, p2)
    assert p1["n_agents"] == 4 and p1["n_departed"] == 1
    assert p1["n_toggles"] > 0 and p1["availability"] < 1.0
    agent_ids = {a for a, *_ in h1}
    assert len(agent_ids) >= 3  # both cohorts actually trained


# ---------------------------------------------------------------------------
# cross-process bit-identity (mirrors the sweep grid-key test)
# ---------------------------------------------------------------------------
_XPROC_CODE = """
import dataclasses, json
from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.core.federated import ADFLLSystem
from repro.population import Cohort, PopulationSpec, Trace
from repro.rl.synth import paper_eight_tasks, patient_split

dqn = DQNConfig(
    volume_shape=(12, 12, 12), box_size=(4, 4, 4), conv_features=(2,),
    hidden=(8,), batch_size=4, max_episode_steps=4, eps_decay_steps=20,
)
cfg = ADFLLConfig(
    n_agents=0, agent_hub=(), agent_speed=(), n_hubs=1, rounds=1,
    erb_capacity=128, erb_share_size=16, train_steps_per_round=1,
    hub_sync_period=0.5, seed=11,
)
pop = PopulationSpec(cohorts=(
    Cohort(n_agents=2, availability=Trace(windows=((0.4, 1.1),), stagger=0.3)),
    Cohort(n_agents=1, arrive_at=0.5, arrive_spread=0.5, speed_sigma=0.4),
))
system = ADFLLSystem(cfg, dqn, paper_eight_tasks()[:2], patient_split(8)[0])
system.apply_population(pop)
report = system.run()
print(json.dumps({
    "history": [
        (r.agent_id, r.round_idx, r.task, round(r.start, 9), round(r.end, 9))
        for r in report.history
    ],
    "makespan": round(report.makespan, 9),
    "population": report.extra["population"],
}, sort_keys=True))
"""


def _xproc_run(hashseed: str) -> dict:
    out = subprocess.run(
        [sys.executable, "-c", _XPROC_CODE],
        capture_output=True,
        text=True,
        env=dict(os.environ, PYTHONPATH="src", PYTHONHASHSEED=hashseed),
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_population_runs_bit_identical_across_processes():
    a = _xproc_run("0")
    b = _xproc_run("271828")  # hash randomization must not matter
    assert a == b
    assert a["population"]["n_agents"] == 3
    assert a["population"]["timeline_digest"] == b["population"]["timeline_digest"]
