"""Substrate: optimizer, checkpointing, data pipeline, sharding rules."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_pytree, save_pytree
from repro.data.pipeline import (
    TokenStreamConfig,
    federated_shards,
    lm_task_erb,
    token_batches,
)
from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_schedule,
    global_norm,
)


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=1000)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(cfg, params)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}
        params, opt, gn = adamw_update(cfg, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adamw_clips_gradients():
    cfg = AdamWConfig(clip_norm=1.0)
    g = {"w": jnp.full((4,), 100.0)}
    assert float(global_norm(g)) == pytest.approx(200.0)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[3] < 1.0 and lrs[4] == pytest.approx(0.0, abs=1e-6)


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
        "c": jnp.ones((4,), jnp.int32),
    }
    path = os.path.join(tmp_path, "ck.npz")
    save_pytree(path, tree)
    back = restore_pytree(path, tree)
    for x, y in zip(
        jax.tree_util.tree_leaves(tree),
        jax.tree_util.tree_leaves(back),
        strict=True,
    ):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"a": jnp.ones((2, 2))}
    path = os.path.join(tmp_path, "ck.npz")
    save_pytree(path, tree)
    with pytest.raises(ValueError):
        restore_pytree(path, {"a": jnp.ones((3, 3))})


def test_token_stream_deterministic_and_bounded():
    sc = TokenStreamConfig(vocab_size=101, seq_len=16, batch_size=4, seed=3)
    a = next(token_batches(sc, style=1))
    b = next(token_batches(sc, style=1))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].max() < 101 and a["tokens"].min() >= 0
    # labels are next-token shifted
    c = next(token_batches(sc, style=2))
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_federated_shards_disjoint():
    sc = TokenStreamConfig(vocab_size=64, seq_len=8, batch_size=2, seed=0)
    shards = federated_shards(sc, 3)
    firsts = [next(s)["tokens"] for s in shards]
    assert not np.array_equal(firsts[0], firsts[1])
    assert not np.array_equal(firsts[1], firsts[2])


def test_lm_task_erb_wraps_batches():
    sc = TokenStreamConfig(vocab_size=64, seq_len=8, batch_size=2, seed=0)
    erb = lm_task_erb(sc, style=0, n_batches=3)
    assert erb.size == 6
    assert erb.data["tokens"].shape == (6, 8)
    assert erb.meta.task.modality == "style0"


# ---------------------------------------------------------------------------
# sharding rules (1-device mesh keeps pytest device-count clean)
# ---------------------------------------------------------------------------
def test_leaf_pspec_rules():
    from jax.sharding import PartitionSpec as P

    from repro.configs.base import get_config
    from repro.models.sharding import ShardingPolicy, leaf_pspec

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pol = ShardingPolicy()
    cfg = get_config("qwen3-moe-235b-a22b")
    # axis size 1 divides everything -> template axes survive
    assert leaf_pspec("groups/b0/mixer/wq/w", (94, 4096, 8192), mesh, pol, cfg) == P(
        None, "data", "model"
    )
    assert leaf_pspec("groups/b0/ffn/w1", (94, 128, 4096, 1536), mesh, pol, cfg) == P(
        None, "model", "data", None
    )
    assert leaf_pspec("embed/tok", (151936, 4096), mesh, pol, cfg) == P(
        "model", "data"
    )
    # unknown leaves replicate
    assert leaf_pspec("whatever/unknown", (3, 3), mesh, pol, cfg) == P(None, None)


def test_moe_local_equals_shard_map_on_one_device(rng):
    """moe_apply must agree between the local path and the shard_map path
    (1-device mesh)."""
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.models.model import init_params
    from repro.models.moe import moe_apply

    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    moe_p = jax.tree_util.tree_map(lambda x: x[0], params["groups"]["b0"]["ffn"])
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    y_local, aux_local = moe_apply(cfg, moe_p, x, mesh=None)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    y_mesh, aux_mesh = moe_apply(cfg, moe_p, x, mesh=mesh, batch_axes=("data",))
    np.testing.assert_allclose(
        np.asarray(y_local), np.asarray(y_mesh), atol=1e-5, rtol=1e-5
    )
