"""rmsnorm kernel: shape/dtype sweep vs oracle + hypothesis property."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref


@pytest.mark.parametrize("shape", [(8, 64), (2, 16, 128), (5, 96), (1, 256)])
@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-6), (jnp.bfloat16, 2e-2)])
def test_rmsnorm_matches_ref(rng, shape, dtype, atol):
    x = jnp.asarray(rng.standard_normal(shape), dtype)
    scale = jnp.asarray(rng.standard_normal(shape[-1]), jnp.float32)
    out = rmsnorm(x, scale, block_rows=4)
    ref = rmsnorm_ref(x, scale)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol, rtol=atol
    )


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 33), d=st.sampled_from([8, 32, 96]), seed=st.integers(0, 5))
def test_rmsnorm_property_sweep(n, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    scale = jnp.asarray(rng.standard_normal(d), jnp.float32)
    out = np.asarray(rmsnorm(x, scale, block_rows=8))
    ref = np.asarray(rmsnorm_ref(x, scale))
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)
    # unit RMS after normalization (pre-scale) is the invariant
    y = out / np.asarray(scale)[None, :]
    np.testing.assert_allclose(np.sqrt((y**2).mean(-1)), 1.0, atol=1e-3)
