"""Online inference plane: continuous batching, hot swaps, bit-identity.

The load-bearing guarantee mirrors the fleet engine's: batching changes
*nothing* about what a request computes. Every request runs as an
independent vmap lane gathering its own version-ring row, so a
continuous batch of requests — admitted and retired at different ticks,
across a param hot swap — produces final voxels bit-identical to
serving each request alone (``max_batch=1``) on the version it pinned.
"""

import dataclasses

import numpy as np
import pytest

import repro.core  # noqa: F401  (resolve the core<->rl import cycle first)
from repro.configs.adfll_dqn import DQNConfig
from repro.rl.env import LandmarkEnv, apply_actions
from repro.rl.fleet import FleetEngine
from repro.serve import (
    LocalizationService,
    ParamPublisher,
    ServeReport,
    TrafficSpec,
    build_session,
    run_session,
    synthetic_requests,
)
from repro.serve.queue import RequestQueue, _Ticket
from repro.serve.report import RequestRecord

CFG = DQNConfig(
    volume_shape=(16, 16, 16),
    box_size=(6, 6, 6),
    conv_features=(4,),
    hidden=(32,),
    max_episode_steps=12,
    batch_size=16,
    eps_decay_steps=100,
)


def _stacked_params(n_agents: int, seed: int = 0):
    """A hand-built published pytree: per-seed inits stacked [N, ...]."""
    import jax

    from repro.rl.dqn import dqn_init

    params = [dqn_init(jax.random.PRNGKey(seed + i), CFG) for i in range(n_agents)]
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *params)


def _requests(n: int, seed: int = 0, n_agents: int = 2):
    spec = TrafficSpec(n_requests=n, seed=seed)
    return synthetic_requests(spec, CFG, n_agents=n_agents)


def _final_locs(service: LocalizationService, ids):
    return {i: tuple(int(v) for v in service.results[i].final_loc) for i in ids}


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


def test_service_completes_all_requests_batched():
    params = _stacked_params(2)
    service = LocalizationService(CFG, params=params, max_batch=8)
    requests = _requests(20)
    ids = [service.submit(r) for r in requests]
    report = service.drain()
    assert report.n_requests == 20
    assert sorted(service.results) == sorted(ids)
    for r in report.requests:
        assert 1 <= r.n_ticks <= CFG.max_episode_steps
        assert r.dist_err is not None  # synthetic traffic carries landmarks
    # continuous batching really batched: fewer ticks than serial sum
    assert report.n_ticks < sum(r.n_ticks for r in report.requests)


def test_no_recompiles_after_warmup():
    params = _stacked_params(2)
    service = LocalizationService(CFG, params=params, max_batch=8)
    traces_after_warmup = service.steps.n_traces
    service.serve(_requests(20))
    assert service.steps.n_traces == traces_after_warmup
    assert service.report.recompiles == 0
    # only pow2 buckets were dispatched
    assert set(service.report.batch_sizes) <= set(service.buckets)


def test_bucket_ladder_is_pow2():
    params = _stacked_params(1, seed=3)
    service = LocalizationService(CFG, params=params, max_batch=6, warmup=False)
    assert service.buckets == [1, 2, 4, 8]


def test_batched_results_bit_identical_to_unbatched():
    params = _stacked_params(2)
    requests = _requests(12)
    batched = LocalizationService(CFG, params=params, max_batch=8)
    ids_b = [batched.submit(r) for r in requests]
    batched.drain()
    single = LocalizationService(CFG, params=params, max_batch=1)
    ids_s = [single.submit(r) for r in requests]
    single.drain()
    locs_b = _final_locs(batched, ids_b)
    locs_s = _final_locs(single, ids_s)
    for ib, i_s in zip(ids_b, ids_s, strict=True):
        assert locs_b[ib] == locs_s[i_s]
        assert batched.results[ib].n_ticks == single.results[i_s].n_ticks


# ---------------------------------------------------------------------------
# hot swap
# ---------------------------------------------------------------------------


def _two_version_publisher(n_agents: int = 2):
    """A publisher whose publishes alternate two distinct param sets."""
    versions = [_stacked_params(n_agents, seed=0), _stacked_params(n_agents, seed=9)]
    state = {"i": 0}

    def source():
        p = versions[state["i"] % 2]
        state["i"] += 1
        return p

    return ParamPublisher(source), versions


def test_hot_swap_consistency_across_versions():
    """A request admitted before a swap completes on the old version; one
    admitted after uses the new one; both match unbatched serving."""
    publisher, versions = _two_version_publisher()
    service = LocalizationService(
        CFG, publisher=publisher, max_batch=4, n_version_slots=2, max_staleness=1
    )
    requests = _requests(8)
    pre, post = requests[:4], requests[4:]  # one full batch each

    ids_pre = [service.submit(r) for r in pre]
    # admit + advance the pre-swap cohort one tick, then publish v1:
    # the cohort stays pinned to v0 while v1 serves later admissions
    service.tick()
    publisher.publish()
    ids_post = [service.submit(r) for r in post]
    report = service.drain()

    assert report.n_swaps == 1
    for i in ids_pre:
        assert service.results[i].version == 0
    for i in ids_post:
        assert service.results[i].version == 1
    assert report.versions_served == {0: 4, 1: 4}

    # bit-identity: each cohort matches single-request serving on the
    # params of the version it pinned
    cohorts = ((ids_pre, pre, versions[0]), (ids_post, post, versions[1]))
    for cohort, reqs, params in cohorts:
        ref = LocalizationService(CFG, params=params, max_batch=1)
        ref_ids = [ref.submit(r) for r in reqs]
        ref.drain()
        got = _final_locs(service, cohort)
        want = _final_locs(ref, ref_ids)
        for i_mix, i_ref in zip(cohort, ref_ids, strict=True):
            assert got[i_mix] == want[i_ref]


def test_swap_deferred_while_target_slot_busy():
    """With a 1-slot ring, a swap cannot land while any request is in
    flight — and the staleness bound then stalls admission."""
    publisher, _ = _two_version_publisher()
    service = LocalizationService(
        CFG, publisher=publisher, max_batch=2, n_version_slots=1, max_staleness=0
    )
    for r in _requests(6):
        service.submit(r)
    service.tick()  # two requests now in flight on v0
    publisher.publish()  # v1: can't land, slot 0 is busy
    assert service.sync_params() is False
    assert service.report.n_deferred_swaps >= 1
    assert service.current_version == 0
    report = service.drain()
    # admission paused until the in-flight pair retired, then v1 landed
    assert report.n_stall_ticks >= 1
    assert report.n_swaps == 1
    assert set(report.versions_served) == {0, 1}


def test_stale_or_duplicate_publish_rejected():
    params = _stacked_params(2)
    publisher = ParamPublisher(lambda: params)
    service = LocalizationService(CFG, publisher=publisher, warmup=False)
    pv0 = publisher.latest
    assert service.install(pv0) is False  # duplicate of the installed v0
    assert service.report.n_swaps == 0
    pv1 = publisher.publish()
    assert service.install(pv1) is True
    assert service.current_version == 1


def test_agent_mismatch_rejected():
    service = LocalizationService(CFG, params=_stacked_params(2), warmup=False)
    other = ParamPublisher(lambda: _stacked_params(3))
    with pytest.raises(ValueError, match="agents"):
        service.install(other.publish())


# ---------------------------------------------------------------------------
# publisher
# ---------------------------------------------------------------------------


def test_publisher_monotonic_versions():
    publisher = ParamPublisher(lambda: _stacked_params(2))
    assert publisher.version == -1
    assert [publisher.publish().version for _ in range(3)] == [0, 1, 2]
    assert publisher.latest.version == 2


def test_publisher_flush_on_read(rng):
    """Publishing mid-round forces the engine flush: the snapshot equals
    get_params after an explicit flush, never a stale pre-job copy."""
    import jax

    from repro.core.erb import TaskTag, erb_add, erb_init
    from repro.rl.agent import DQNAgent

    engine = FleetEngine(CFG)
    agent = DQNAgent(0, CFG, seed=0, engine=engine)
    erb = erb_init(64, CFG.box_size, task=TaskTag("t1", "axial", "HGG"))
    n = 64
    erb_add(
        erb,
        {
            "obs": rng.standard_normal((n, *CFG.box_size)).astype(np.float32),
            "loc": rng.random((n, 3)).astype(np.float32),
            "action": rng.integers(0, CFG.n_actions, n).astype(np.int32),
            "reward": rng.standard_normal(n).astype(np.float32),
            "next_obs": rng.standard_normal((n, *CFG.box_size)).astype(np.float32),
            "next_loc": rng.random((n, 3)).astype(np.float32),
            "done": np.zeros(n, np.float32),
        },
    )
    publisher = ParamPublisher(engine)
    v0 = publisher.publish()
    plans = [agent.sampler.plan(agent.rng, CFG.batch_size, erb) for _ in range(4)]
    engine.submit(agent.slot, plans)  # pending, not yet flushed
    v1 = publisher.publish()  # must flush before snapshotting
    leaves0 = jax.tree_util.tree_leaves(v0.params)
    leaves1 = jax.tree_util.tree_leaves(v1.params)
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(leaves0, leaves1, strict=True)
    )
    assert v1.train_steps == 4


# ---------------------------------------------------------------------------
# queue / report / env helpers
# ---------------------------------------------------------------------------


def test_queue_fifo_never_jumps_unarrived_head():
    q = RequestQueue()
    reqs = _requests(3)
    t = [_Ticket(i, r, CFG) for i, r in enumerate(reqs)]
    q.push(t[0], not_before=100.0)  # head not yet arrived
    q.push(t[1], not_before=0.0)
    q.push(t[2], not_before=0.0)
    assert q.pop_ready(now=1.0) is None  # FIFO: no jumping the head
    assert len(q) == 3
    assert q.pop_ready(now=200.0) is t[0]
    assert q.pop_ready(now=200.0) is t[1]
    assert q.pop_ready(now=200.0) is t[2]
    assert q.pop_ready(now=200.0) is None


def test_report_percentiles_and_summary():
    report = ServeReport(wall_time_s=2.0)
    for i, lat in enumerate((0.010, 0.020, 0.030, 0.040)):
        report.requests.append(
            RequestRecord(
                request_id=i,
                agent_id=0,
                version=0,
                n_ticks=5,
                latency_s=lat,
                queued_s=0.0,
                dist_err=float(i),
            )
        )
    assert report.percentile_ms(50) == pytest.approx(25.0)
    s = report.summary()
    assert s["n_requests"] == 4
    assert s["requests_per_sec"] == pytest.approx(2.0)
    assert s["p50_latency_ms"] == pytest.approx(25.0)
    assert s["mean_dist_err"] == pytest.approx(1.5)
    assert s["recompiles"] == 0


def test_apply_actions_matches_env_step():
    rng = np.random.default_rng(1)
    vol = rng.standard_normal((16, 16, 16)).astype(np.float32)
    env = LandmarkEnv(vol, np.array([8.0, 8.0, 8.0], np.float32), CFG)
    locs = rng.integers(0, 16, size=(9, 3)).astype(np.int32)
    actions = rng.integers(0, CFG.n_actions, size=9).astype(np.int32)
    new, _, _ = env.step(locs, actions)
    np.testing.assert_array_equal(
        new, apply_actions(locs, actions, env.n, CFG.step_size)
    )
    # per-row volume sides clip rows independently
    edge = np.array([[15, 15, 15]], np.int32)
    out = apply_actions(edge, np.array([0]), np.array([16]), 1)
    np.testing.assert_array_equal(out, edge)  # clipped at n-1


def test_oscillation_termination():
    """A ticket retires the moment the rollout revisits a voxel."""
    req = _requests(1)[0]
    ticket = _Ticket(0, req, CFG)
    start = ticket.loc.copy()
    step = np.array([0, 0, CFG.step_size], np.int32)
    assert ticket.advance(start + step) is False
    assert ticket.advance(start) is True  # revisit -> oscillation
    assert ticket.n_ticks == 2


# ---------------------------------------------------------------------------
# train-while-serve session + scenario integration
# ---------------------------------------------------------------------------


def test_run_session_serves_across_a_swap():
    traffic = TrafficSpec(n_requests=12, max_batch=4, seed=2)
    session = build_session(CFG, n_agents=2, traffic=traffic, seed=2)
    report = run_session(session, traffic, n_waves=2, train_steps=5)
    assert report.n_requests == 12
    assert report.n_swaps == 1
    assert report.recompiles == 0
    assert set(report.versions_served) == {0, 1}


def test_serve_scenario_registered():
    from repro.experiments import get_scenario, run

    spec = get_scenario("serve_localization")
    assert spec.system == "serve"
    assert spec.serve_traffic is not None
    fast = dataclasses.replace(
        spec.fast(), serve_traffic=TrafficSpec(n_requests=8, max_batch=4)
    )
    r = run(fast, fast=True)
    assert np.isfinite(r.mean_dist_err)
    assert r.extra["serve"]["recompiles"] == 0
    assert r.extra["serve"]["n_swaps"] >= 1
    assert "Serve" in r.task_errors


def test_serve_traffic_requires_serve_system():
    from repro.experiments.spec import ScenarioSpec

    with pytest.raises(ValueError, match="serve_traffic"):
        ScenarioSpec(name="x", system="adfll", serve_traffic=TrafficSpec())
