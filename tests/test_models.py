"""Per-arch smoke tests (reduced configs) + decode/forward parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ASSIGNED, get_config
from repro.models.model import (
    build_model,
    init_caches,
    init_params,
    make_prefill_step,
    make_serve_step,
)
from repro.models.rope import positions_for

B, S = 2, 64


def _batch(cfg, rng, b=B, s=S):
    labels = rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)
    if cfg.input_kind == "embeds":
        return {
            "embeds": jnp.asarray(
                rng.standard_normal((b, s, cfg.d_model)).astype(np.float32)
            ),
            "labels": jnp.asarray(labels),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(labels),
    }


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_train_step(arch, rng):
    """Reduced variant of the same family: one train step, shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    if cfg.moe.n_experts:
        assert cfg.moe.n_experts <= 4
    m = build_model(cfg)
    state = m.init_train_state(jax.random.PRNGKey(0))
    state2, metrics = jax.jit(m.train_step)(state, _batch(cfg, rng))
    for k, v in metrics.items():
        assert np.isfinite(np.asarray(v)).all(), (arch, k, v)
    # params actually changed (embeds-input models leave the unused token
    # table ~untouched, so check across all leaves)
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(
            jax.tree_util.tree_leaves(state["params"]),
            jax.tree_util.tree_leaves(state2["params"]),
            strict=True,
        )
    )
    assert changed


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_serve_step(arch, rng):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    caches = init_caches(cfg, B, 32)
    serve = jax.jit(make_serve_step(cfg))
    batch = {"pos": jnp.array([0, 3], jnp.int32)}
    if cfg.input_kind == "embeds":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((B, 1, cfg.d_model)).astype(np.float32)
        )
    else:
        batch["tokens"] = jnp.zeros((B, 1), jnp.int32)
    logits, new_caches = serve(params, caches, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structure preserved
    assert jax.tree_util.tree_structure(caches) == jax.tree_util.tree_structure(
        new_caches
    )


@pytest.mark.parametrize(
    "arch",
    ["h2o-danube-3-4b", "deepseek-v2-lite-16b", "xlstm-125m", "jamba-1.5-large-398b"],
)
def test_decode_matches_teacher_forcing(arch, rng):
    """Token-by-token decode with caches must reproduce the teacher-forced
    forward logits — catches KV-cache / recurrent-state bugs."""
    import dataclasses

    from repro.models.model import forward, logits_fn

    cfg = get_config(arch).reduced()
    if cfg.input_kind == "embeds":
        pytest.skip("token parity test is for token models")
    if cfg.moe.n_experts:
        # disable capacity dropping: teacher-forced MoE drops overflow
        # tokens while single-token decode never does (cap >= 1)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0)
        )
    params = init_params(cfg, jax.random.PRNGKey(1))
    s = 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, s)), jnp.int32)

    pos = positions_for(cfg, 1, s)
    hidden, _, _ = forward(cfg, params, toks, pos, mode="train")
    full_logits = logits_fn(cfg, params, hidden)  # [1, s, V]

    serve = jax.jit(make_serve_step(cfg))
    caches = init_caches(cfg, 1, s + 1)
    step_logits = []
    for t in range(s):
        batch = {"tokens": toks[:, t : t + 1], "pos": jnp.array([t], jnp.int32)}
        lg, caches = serve(params, caches, batch)
        step_logits.append(np.asarray(lg, np.float32))
    step_logits = np.stack(step_logits, 1)  # [1, s, V]
    np.testing.assert_allclose(
        step_logits, np.asarray(full_logits, np.float32), atol=2e-3, rtol=2e-3
    )


def test_prefill_matches_forward(rng):
    cfg = get_config("h2o-danube-3-4b").reduced()
    from repro.models.model import forward, logits_fn

    params = init_params(cfg, jax.random.PRNGKey(2))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)), jnp.int32)
    prefill = jax.jit(make_prefill_step(cfg))
    last, caches = prefill(params, {"tokens": toks})
    pos = positions_for(cfg, 1, 16)
    hidden, _, _ = forward(cfg, params, toks, pos, mode="train")
    full = logits_fn(cfg, params, hidden)
    np.testing.assert_allclose(
        np.asarray(last, np.float32),
        np.asarray(full, np.float32)[:, -1],
        atol=2e-3,
        rtol=2e-3,
    )
    assert caches is not None


def test_sliding_window_restricts_attention(rng):
    """With window w, logits at position t must not depend on tokens
    earlier than t - w."""
    import dataclasses

    from repro.models.model import forward, logits_fn

    cfg = dataclasses.replace(get_config("h2o-danube-3-4b").reduced(), sliding_window=4)
    params = init_params(cfg, jax.random.PRNGKey(3))
    s = 16
    t1 = rng.integers(0, cfg.vocab_size, (1, s)).astype(np.int32)
    t2 = t1.copy()
    t2[0, :4] = (t2[0, :4] + 7) % cfg.vocab_size  # perturb old tokens
    outs = []
    for t in (t1, t2):
        pos = positions_for(cfg, 1, s)
        h, _, _ = forward(cfg, params, jnp.asarray(t), pos, mode="train")
        outs.append(np.asarray(logits_fn(cfg, params, h), np.float32))
    # position 15 attends [12..15] only -> unaffected by tokens 0..3
    np.testing.assert_allclose(outs[0][0, -1], outs[1][0, -1], atol=1e-4)
    # position 5 attends [2..5] -> affected
    assert not np.allclose(outs[0][0, 5], outs[1][0, 5], atol=1e-4)


def test_moe_router_load_balance_aux(rng):
    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    m = build_model(cfg)
    state = m.init_train_state(jax.random.PRNGKey(0))
    _, metrics = jax.jit(m.train_step)(state, _batch(cfg, rng))
    # Switch aux loss is ~1 for a balanced router, and must be finite
    aux = float(metrics["aux"])
    assert 0.5 < aux < 4.0


def test_loss_decreases_tiny_lm(rng):
    cfg = get_config("xlstm-125m").reduced()
    m = build_model(cfg)
    state = m.init_train_state(jax.random.PRNGKey(0))
    step = jax.jit(m.train_step)
    batch = _batch(cfg, rng, b=4, s=32)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)  # same batch -> must overfit
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses
