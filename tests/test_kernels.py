"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes / dtypes / masking configs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_td.kernel import fused_td
from repro.kernels.fused_td.ops import td_loss
from repro.kernels.fused_td.ref import fused_td_ref
from repro.kernels.replay_gather.ops import replay_gather
from repro.kernels.replay_gather.ref import replay_gather_ref


def _qkv(rng, b, s, hq, hkv, d, dtype):
    q = jnp.asarray(rng.standard_normal((b, s, hq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    return q, k, v


@pytest.mark.parametrize(
    "b,s,hq,hkv,d",
    [
        (1, 128, 4, 4, 32),  # MHA
        (2, 256, 8, 2, 16),  # GQA 4:1
        (1, 512, 4, 1, 64),  # MQA
    ],
)
@pytest.mark.parametrize("window", [None, 64])
def test_flash_attention_matches_ref(rng, b, s, hq, hkv, d, window):
    q, k, v = _qkv(rng, b, s, hq, hkv, d, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(rng, dtype, atol):
    q, k, v = _qkv(rng, 1, 128, 4, 2, 32, dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol, rtol=atol
    )


def test_flash_attention_softcap(rng):
    q, k, v = _qkv(rng, 1, 128, 2, 2, 16, jnp.float32)
    out = flash_attention(q, k, v, causal=True, softcap=20.0, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_attention_uneven_blocks(rng):
    # s not a multiple of the block sizes exercises the tail masking
    q, k, v = _qkv(rng, 1, 96, 2, 2, 16, jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("cap,feat,batch", [(64, 16, 8), (256, 128, 32), (128, 33, 5)])
def test_replay_gather_matches_ref(rng, cap, feat, batch):
    buf = jnp.asarray(rng.standard_normal((cap, feat)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, cap, batch), jnp.int32)
    w = jnp.asarray(rng.random(batch), jnp.float32)
    out = replay_gather(buf, idx, w)
    ref = replay_gather_ref(buf, idx, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


@pytest.mark.parametrize("b,a,gamma", [(64, 6, 0.9), (128, 4, 0.99), (32, 6, 0.5)])
def test_fused_td_matches_ref(rng, b, a, gamma):
    q_sel = jnp.asarray(rng.standard_normal((b, 1)), jnp.float32)
    q_next = jnp.asarray(rng.standard_normal((b, a)), jnp.float32)
    r = jnp.asarray(rng.standard_normal((b, 1)), jnp.float32)
    d = jnp.asarray((rng.random((b, 1)) < 0.3), jnp.float32)
    l1, dq1 = fused_td(q_sel, q_next, r, d, gamma=gamma, block_b=32)
    l2, dq2 = fused_td_ref(q_sel, q_next, r, d, gamma=gamma)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(dq1), np.asarray(dq2), atol=1e-6)


def test_td_loss_gradient_matches_autodiff(rng):
    """custom_vjp (fused dq) must equal autodiff through the ref loss."""
    b = 64
    q_sel = jnp.asarray(rng.standard_normal((b, 1)), jnp.float32)
    q_next = jnp.asarray(rng.standard_normal((b, 6)), jnp.float32)
    r = jnp.asarray(rng.standard_normal((b, 1)), jnp.float32)
    d = jnp.zeros((b, 1), jnp.float32)

    g_fused = jax.grad(lambda q: td_loss(q, q_next, r, d, 0.9, True))(q_sel)

    def ref_loss(q):
        loss, _ = fused_td_ref(q, q_next, r, d, gamma=0.9)
        return jnp.mean(loss)

    g_ref = jax.grad(ref_loss)(q_sel)
    np.testing.assert_allclose(np.asarray(g_fused), np.asarray(g_ref), atol=1e-6)
