"""Recurrent-mixer oracles: the chunked/parallel training-mode scans must
equal a naive per-step recurrence (the mathematical definition)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import ssm

F32 = jnp.float32


def _naive_selective_scan(dt, b_seq, c_seq, xf, a):
    """Literal per-step recurrence h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t."""
    b, s, di = dt.shape
    n = a.shape[1]
    h = np.zeros((b, di, n), np.float32)
    ys = []
    dt, b_seq, c_seq, xf, a = map(np.asarray, (dt, b_seq, c_seq, xf, a))
    for t in range(s):
        da = np.exp(dt[:, t, :, None] * a[None])
        dbx = (dt[:, t] * xf[:, t])[..., None] * b_seq[:, t, None, :]
        h = da * h + dbx
        ys.append(np.einsum("bdn,bn->bd", h, c_seq[:, t]))
    return np.stack(ys, 1), h


@pytest.mark.parametrize("s,chunk", [(16, 4), (24, 8), (7, 16), (32, 32)])
def test_mamba_chunked_scan_matches_naive(rng, s, chunk):
    b, di, n = 2, 8, 4
    dt = jnp.asarray(rng.random((b, s, di)) * 0.5, F32)
    b_seq = jnp.asarray(rng.standard_normal((b, s, n)), F32)
    c_seq = jnp.asarray(rng.standard_normal((b, s, n)), F32)
    xf = jnp.asarray(rng.standard_normal((b, s, di)), F32)
    a = -jnp.asarray(rng.random((di, n)) + 0.1, F32)
    y, h_last = ssm._selective_scan_chunked(dt, b_seq, c_seq, xf, a, chunk)
    y_ref, h_ref = _naive_selective_scan(dt, b_seq, c_seq, xf, a)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last), h_ref, atol=1e-4, rtol=1e-4)


def test_mamba_train_equals_stepwise_decode(rng):
    """Running mamba_apply over a sequence must equal feeding tokens one at
    a time through the decode path (state handoff correctness)."""
    cfg = get_config("jamba-1.5-large-398b").reduced()
    key = jax.random.PRNGKey(0)
    p = ssm.mamba_init(key, cfg)
    b, s = 1, 12
    u = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), F32)
    y_train, _ = ssm.mamba_apply(cfg, p, u, mode="train")
    state = ssm.mamba_state_init(cfg, b, F32)
    outs = []
    for t in range(s):
        y_t, state = ssm.mamba_apply(
            cfg, p, u[:, t : t + 1], mode="decode", state=state
        )
        outs.append(np.asarray(y_t, np.float32))
    np.testing.assert_allclose(
        np.concatenate(outs, 1), np.asarray(y_train, np.float32), atol=2e-3, rtol=2e-3
    )


def test_mlstm_train_equals_stepwise_decode(rng):
    cfg = get_config("xlstm-125m").reduced()
    key = jax.random.PRNGKey(1)
    p = ssm.mlstm_init(key, cfg)
    b, s = 1, 10
    u = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), F32)
    y_train, _ = ssm.mlstm_apply(cfg, p, u, mode="train")
    state = ssm.mlstm_state_init(cfg, b, F32)
    outs = []
    for t in range(s):
        y_t, state = ssm.mlstm_apply(
            cfg, p, u[:, t : t + 1], mode="decode", state=state
        )
        outs.append(np.asarray(y_t, np.float32))
    np.testing.assert_allclose(
        np.concatenate(outs, 1), np.asarray(y_train, np.float32), atol=2e-3, rtol=2e-3
    )


def test_slstm_train_equals_stepwise_decode(rng):
    cfg = get_config("xlstm-125m").reduced()
    key = jax.random.PRNGKey(2)
    p = ssm.slstm_init(key, cfg)
    b, s = 2, 8
    u = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), F32)
    y_train, _ = ssm.slstm_apply(cfg, p, u, mode="train")
    state = ssm.slstm_state_init(cfg, b, F32)
    outs = []
    for t in range(s):
        y_t, state = ssm.slstm_apply(
            cfg, p, u[:, t : t + 1], mode="decode", state=state
        )
        outs.append(np.asarray(y_t, np.float32))
    np.testing.assert_allclose(
        np.concatenate(outs, 1), np.asarray(y_train, np.float32), atol=2e-3, rtol=2e-3
    )


def test_causal_conv1d_state_handoff(rng):
    b, s, c, k = 2, 12, 6, 4
    x = jnp.asarray(rng.standard_normal((b, s, c)), F32)
    w = jnp.asarray(rng.standard_normal((c, k)), F32)
    bias = jnp.asarray(rng.standard_normal((c,)), F32)
    y_full, _ = ssm.causal_conv1d(x, w, bias)
    state = jnp.zeros((b, k - 1, c), F32)
    outs = []
    for t in range(s):
        y_t, state = ssm.causal_conv1d(x[:, t : t + 1], w, bias, state)
        outs.append(np.asarray(y_t))
    np.testing.assert_allclose(np.concatenate(outs, 1), np.asarray(y_full), atol=1e-5)
