"""Weight plane: staleness weighting, hub-side dedup/retention, transport
under dropout and hub failure, and deterministic hybrid-sharing runs."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.core.federated import ADFLLSystem
from repro.core.hub import Hub, sync_hubs
from repro.core.network import Network
from repro.core.plane import (
    WeightPlane,
    WeightSnapshot,
    mix_params,
    new_snap_id,
    staleness_alphas,
    staleness_weight,
)
from repro.rl.synth import paper_eight_tasks, patient_split

FLAGS = ["constant", "hinge", "poly"]


def _snap(agent_id, round_idx, value=1.0, sim_time=0.0):
    params = {"w": np.full((3,), value, np.float32)}
    return WeightSnapshot(new_snap_id(), agent_id, round_idx, sim_time, params)


# ---------------------------------------------------------------------------
# staleness weight functions (FedAsync s(dtau) families)
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(dtau=st.floats(0.0, 50.0), flag=st.sampled_from(FLAGS))
def test_staleness_weight_in_unit_interval(dtau, flag):
    s = staleness_weight(dtau, flag)
    assert 0.0 < s <= 1.0


@settings(max_examples=30, deadline=None)
@given(flag=st.sampled_from(FLAGS))
def test_staleness_weight_fresh_is_one(flag):
    assert staleness_weight(0.0, flag) == 1.0


@settings(max_examples=30, deadline=None)
@given(d1=st.integers(0, 30), d2=st.integers(0, 30), flag=st.sampled_from(FLAGS))
def test_staleness_weight_monotone_nonincreasing(d1, d2, flag):
    lo, hi = min(d1, d2), max(d1, d2)
    assert staleness_weight(hi, flag) <= staleness_weight(lo, flag)


def test_staleness_weight_negative_lag_clamped():
    # a peer "from the future" (receiver behind sender) is just fresh
    for flag in FLAGS:
        assert staleness_weight(-3.0, flag) == 1.0


def test_staleness_weight_unknown_flag_raises():
    with pytest.raises(ValueError):
        staleness_weight(1.0, "exponential")


def test_staleness_alphas_orders_by_round():
    snaps = [_snap(0, 0), _snap(1, 4)]
    a = staleness_alphas(snaps, 4, alpha=0.5, flag="poly", poly_a=0.5)
    assert a[1] == pytest.approx(0.5)  # fresh peer: full alpha
    assert a[0] == pytest.approx(0.5 * 5**-0.5)


def test_staleness_alphas_time_clock_ignores_local_rounds():
    """Under heterogeneous speeds, a fast peer's high round count must not
    read as stale: the shared-clock mode keys on push sim_time instead."""
    fast_fresh = _snap(0, round_idx=10, sim_time=4.0)
    slow_stale = _snap(1, round_idx=1, sim_time=0.0)
    a = staleness_alphas(
        [fast_fresh, slow_stale], 4.0, alpha=0.5, flag="poly", poly_a=0.5, clock="time"
    )
    assert a[0] == pytest.approx(0.5)  # pushed just now: full alpha
    assert a[1] == pytest.approx(0.5 * 5**-0.5)
    # round clock would invert that judgement (delta 10-4<0 vs 4-1)
    b = staleness_alphas(
        [fast_fresh, slow_stale], 4, alpha=0.5, flag="poly", poly_a=0.5, clock="round"
    )
    assert b[0] > b[1]  # literal FedAsync counters: kept as an option


# ---------------------------------------------------------------------------
# mixing
# ---------------------------------------------------------------------------
def test_mix_params_convex_combination():
    params = {"w": np.zeros((3,), np.float32)}
    out = mix_params(params, [_snap(1, 0, value=2.0)], [0.25])
    np.testing.assert_allclose(out["w"], 0.5)
    # alpha=0 keeps params, alpha=1 adopts the peer wholesale
    np.testing.assert_allclose(mix_params(params, [_snap(1, 0, 2.0)], [0.0])["w"], 0.0)
    np.testing.assert_allclose(mix_params(params, [_snap(1, 0, 2.0)], [1.0])["w"], 2.0)


def test_mix_params_stalest_first_order():
    """The freshest snapshot must be applied last, whatever list order."""
    params = {"w": np.zeros((1,), np.float32)}
    stale, fresh = _snap(1, 0, value=1.0), _snap(2, 5, value=3.0)
    out_a = mix_params(params, [stale, fresh], [1.0, 1.0])
    out_b = mix_params(params, [fresh, stale], [1.0, 1.0])
    np.testing.assert_allclose(out_a["w"], 3.0)
    np.testing.assert_allclose(out_b["w"], 3.0)


def test_agent_mix_params_skips_own_snapshot():
    from repro.rl.agent import DQNAgent

    dqn = DQNConfig(
        volume_shape=(12, 12, 12),
        box_size=(4, 4, 4),
        conv_features=(2,),
        hidden=(8,),
        batch_size=4,
    )
    ag = DQNAgent(7, dqn, seed=0)
    own = WeightSnapshot(new_snap_id(), 7, 0, 0.0, ag.params)
    before = jax.tree_util.tree_leaves(ag.params)[0]
    assert ag.mix_params([own], [1.0]) == 0
    after = jax.tree_util.tree_leaves(ag.params)[0]
    np.testing.assert_array_equal(np.asarray(before), np.asarray(after))
    assert own.snap_id in ag.seen_snap_ids


# ---------------------------------------------------------------------------
# hub-side retention / dedup
# ---------------------------------------------------------------------------
def test_weight_plane_keeps_newest_versions_per_agent():
    plane = WeightPlane(max_versions=2)
    store = {}
    s0, s1, s2 = _snap(0, 0), _snap(0, 1), _snap(0, 2)
    assert plane.admit(store, s0)
    assert plane.admit(store, s1)
    assert plane.admit(store, s2)
    assert set(store) == {s1.snap_id, s2.snap_id}  # s0 evicted


def test_weight_plane_rejects_stale_reinsertion():
    """An evicted stale snapshot must not bounce back via hub-hub sync."""
    plane = WeightPlane(max_versions=1)
    store = {}
    old, new = _snap(0, 0), _snap(0, 3)
    assert plane.admit(store, old)
    assert plane.admit(store, new)
    assert not plane.admit(store, old)  # stale: refused
    assert not plane.admit(store, new)  # duplicate: refused
    assert set(store) == {new.snap_id}


def test_weight_plane_sync_replicates_across_hubs():
    plane = WeightPlane(max_versions=2)
    hubs = [Hub(0), Hub(1)]
    hubs[0].push(_snap(0, 0), plane)
    hubs[1].push(_snap(1, 0), plane)
    n = sync_hubs(hubs, np.random.default_rng(0), planes=[plane])
    assert n == 2
    assert set(hubs[0].store("weights")) == set(hubs[1].store("weights"))
    assert len(hubs[0].store("weights")) == 2


# ---------------------------------------------------------------------------
# network transport: dropout + hub failure
# ---------------------------------------------------------------------------
def _weight_net(n_hubs=2, dropout=0.0):
    net = Network(
        hubs=[Hub(i) for i in range(n_hubs)],
        dropout=dropout,
        rng=np.random.default_rng(0),
    )
    net.register_plane(WeightPlane(max_versions=2))
    return net


def test_weight_push_pull_roundtrip():
    net = _weight_net()
    net.attach_agent(0, 0)
    net.attach_agent(1, 0)
    snap = _snap(0, 0)
    assert net.agent_push(0, snap, plane="weights")
    pulled = net.agent_pull(1, set(), plane="weights")
    assert [s.snap_id for s in pulled] == [snap.snap_id]
    # seen-set filtering: nothing on the second pull
    assert net.agent_pull(1, {snap.snap_id}, plane="weights") == []


def test_weight_push_refused_for_stale_snapshot():
    """agent_push must report plane refusals instead of counting them."""
    net = _weight_net()
    net.attach_agent(0, 0)
    old, new = _snap(0, 0), _snap(0, 3)
    assert net.agent_push(0, new, plane="weights")
    assert not net.agent_push(0, old, plane="weights")  # stale: refused
    assert net.plane_pushed == {"weights": 1}
    assert net.n_pushed == 1


def test_weight_push_respects_dropout():
    net = _weight_net(dropout=1.0)
    net.attach_agent(0, 0)
    assert not net.agent_push(0, _snap(0, 0), plane="weights")
    assert net.n_dropped == 1
    assert net.all_known("weights") == set()


def test_weight_plane_survives_hub_failure_when_replicated():
    net = _weight_net(n_hubs=2)
    net.attach_agent(0, 0)
    replicated = _snap(0, 0)
    net.agent_push(0, replicated, plane="weights")
    net.sync()  # now on both hubs
    unique = _snap(0, 1)
    net.agent_push(0, unique, plane="weights")  # hub 0 only
    net.fail_hub(0)
    known = net.all_known("weights")
    assert replicated.snap_id in known  # survived
    assert unique.snap_id not in known  # lost with hub 0
    assert net.agent_hub[0] == 1  # agent re-homed


def test_erb_and_weight_planes_are_isolated():
    net = _weight_net()
    net.attach_agent(0, 0)
    net.agent_push(0, _snap(0, 0), plane="weights")
    assert net.all_known("erb") == set()
    assert len(net.all_known("weights")) == 1


# ---------------------------------------------------------------------------
# end-to-end: hybrid sharing through the scheduler, deterministic
# ---------------------------------------------------------------------------
TINY_DQN = DQNConfig(
    volume_shape=(12, 12, 12),
    box_size=(4, 4, 4),
    conv_features=(2,),
    hidden=(8,),
    batch_size=4,
    max_episode_steps=4,
    eps_decay_steps=20,
)


def _tiny_sys(planes, seed=0, n_agents=2):
    cfg = ADFLLConfig(
        n_agents=n_agents,
        n_hubs=1,
        agent_hub=(0,) * n_agents,
        agent_speed=(1.0, 2.0)[:n_agents],
        rounds=2,
        erb_capacity=128,
        erb_share_size=16,
        train_steps_per_round=3,
        hub_sync_period=0.5,
        share_planes=planes,
        mix_alpha=0.5,
        staleness_flag="poly",
    )
    tasks = paper_eight_tasks()[:2]
    train_p, _ = patient_split(8)
    return ADFLLSystem(cfg, TINY_DQN, tasks, train_p, seed=seed)


def test_hybrid_run_mixes_weights_and_shares_erbs():
    sysm = _tiny_sys(("erb", "weights"))
    sysm.run()
    assert all(a.rounds_done >= 2 for a in sysm.agents.values())
    assert any(r.n_mixed > 0 for r in sysm.history)  # weights flowed
    assert any(r.n_incoming > 0 for r in sysm.history)  # ERBs flowed
    assert len(sysm.network.all_known("weights")) > 0
    assert len(sysm.network.all_known("erb")) > 0


def test_weight_only_run_shares_no_erbs():
    sysm = _tiny_sys(("weights",))
    sysm.run()
    assert all(r.n_incoming == 0 for r in sysm.history)
    assert sysm.network.all_known("erb") == set()
    assert any(r.n_mixed > 0 for r in sysm.history)


def test_hybrid_run_deterministic_under_fixed_seed():
    def fingerprint():
        sysm = _tiny_sys(("erb", "weights"), seed=3)
        sysm.run()
        hist = [
            (r.agent_id, r.round_idx, r.task, round(r.end, 9), r.n_incoming, r.n_mixed)
            for r in sysm.history
        ]
        leaves = [
            np.asarray(x).sum()
            for a in sorted(sysm.agents)
            for x in jax.tree_util.tree_leaves(sysm.agents[a].params)
        ]
        return hist, np.asarray(leaves)

    h1, p1 = fingerprint()
    h2, p2 = fingerprint()
    assert h1 == h2
    np.testing.assert_allclose(p1, p2, rtol=0, atol=0)
