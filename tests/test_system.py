"""End-to-end behaviour of the ADFLL system + comparison systems."""

import numpy as np

from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.core.federated import (
    ADFLLSystem,
    CentralAggregationSystem,
    evaluate_on_tasks,
    train_partial,
)
from repro.core.lifelong import LifelongTrainer
from repro.rl.synth import paper_eight_tasks, patient_split

DQN = DQNConfig(
    volume_shape=(16, 16, 16),
    box_size=(6, 6, 6),
    conv_features=(4,),
    hidden=(32,),
    max_episode_steps=12,
    batch_size=16,
    eps_decay_steps=100,
)
SYS = ADFLLConfig(
    rounds=2,
    train_steps_per_round=15,
    erb_capacity=512,
    erb_share_size=64,
    hub_sync_period=0.25,
)
TASKS = paper_eight_tasks()
TRAIN_P, TEST_P = patient_split(16)


def test_adfll_deployment_runs_asynchronously():
    sysm = ADFLLSystem(SYS, DQN, TASKS, TRAIN_P, seed=0)
    sysm.run()
    # every agent finished its rounds
    assert all(a.rounds_done >= SYS.rounds for a in sysm.agents.values())
    # asynchrony: the fast (speed=2.5) agents finish round 0 earlier
    ends = {r.agent_id: r.end for r in sysm.history if r.round_idx == 0}
    assert ends[2] < ends[0] and ends[3] < ends[1]
    # experiences propagated: someone trained on incoming ERBs
    assert any(r.n_incoming > 0 for r in sysm.history)
    # hubs hold the shared database
    assert len(sysm.network.all_known("erb")) >= SYS.n_agents


def test_adfll_heterogeneous_speed_speedup():
    """Fast agents complete more rounds per sim-time: the paper's speed-up
    over synchronized training (no global barrier)."""
    sysm = ADFLLSystem(SYS, DQN, TASKS, TRAIN_P, seed=1)
    end = sysm.run().makespan
    per_agent_end = {}
    for r in sysm.history:
        per_agent_end[r.agent_id] = max(per_agent_end.get(r.agent_id, 0.0), r.end)
    # total makespan = slowest agent; fast agents idle-free finish earlier
    assert per_agent_end[2] <= per_agent_end[0]
    assert end >= max(per_agent_end.values())


def test_agent_addition_catches_up():
    """Addition ablation: a late joiner can learn from the accumulated
    hub database within its first round."""
    sysm = ADFLLSystem(SYS, DQN, TASKS, TRAIN_P, seed=2)
    sysm.run(until=0.6)  # some rounds complete
    sysm.network.sync()
    new_id = sysm.add_agent(speed=2.0)
    sysm.run()
    recs = [r for r in sysm.history if r.agent_id == new_id]
    assert recs, "new agent never trained"
    assert recs[0].n_incoming > 0  # caught up from the database


def test_evaluation_and_baselines_tiny():
    ag = train_partial(DQN, TASKS[0], TRAIN_P, steps=10)
    errs = evaluate_on_tasks(ag, TASKS[:2], TEST_P, DQN)
    assert set(errs) == {TASKS[0].name, TASKS[1].name}
    assert all(np.isfinite(v) for v in errs.values())


def test_central_aggregation_averages_weights():
    sysm = CentralAggregationSystem(2, DQN, TASKS, TRAIN_P)
    sysm.round(0, steps=5, erb_capacity=256)
    p0 = sysm.agents[0].params
    p1 = sysm.agents[1].params
    import jax

    for a, b in zip(
        jax.tree_util.tree_leaves(p0),
        jax.tree_util.tree_leaves(p1),
        strict=True,
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lifelong_trainer_is_model_agnostic():
    """ADFLL replay wraps an arbitrary train_step (LM here) — the
    architecture-agnosticism claim."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.data.pipeline import TokenStreamConfig, lm_task_erb
    from repro.launch.specs import opt_cfg_for
    from repro.models.model import init_train_state, make_train_step

    cfg = get_config("xlstm-125m").reduced()
    opt = opt_cfg_for(cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(0), opt)
    raw_step = jax.jit(make_train_step(cfg, opt))

    def np_step(state, batch):
        batch = {k: jnp.asarray(v % cfg.vocab_size) for k, v in batch.items()}
        return raw_step(state, batch)

    sc = TokenStreamConfig(cfg.vocab_size, seq_len=32, batch_size=4)
    tr = LifelongTrainer(np_step, state, batch_size=4)
    cur = lm_task_erb(sc, style=0, n_batches=4)
    inc = lm_task_erb(sc, style=1, n_batches=4)
    m = tr.steps(4, cur, incoming=[inc])
    assert np.isfinite(m["loss"])
    assert cur.meta.erb_id in tr.seen_erb_ids
    assert inc.meta.erb_id in tr.seen_erb_ids
