"""ADFLL core invariants: ERBs, selective replay, hubs, network, scheduler.
Property-based tests (hypothesis) cover the system's safety claims."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.erb import TaskTag, erb_add, erb_init, erb_sample, erb_share_slice
from repro.core.hub import Hub, sync_hubs
from repro.core.network import Network
from repro.core.replay import SelectiveReplaySampler
from repro.core.scheduler import Scheduler

TASK = TaskTag("t1", "axial", "HGG")
OBS = (4, 4, 4)


def _erb(n, cap=32, seed=0):
    rng = np.random.default_rng(seed)
    erb = erb_init(cap, OBS, task=TASK)
    batch = {
        "obs": rng.standard_normal((n, *OBS)).astype(np.float32),
        "loc": rng.standard_normal((n, 3)).astype(np.float32),
        "action": rng.integers(0, 6, n).astype(np.int32),
        "reward": rng.standard_normal(n).astype(np.float32),
        "next_obs": rng.standard_normal((n, *OBS)).astype(np.float32),
        "next_loc": rng.standard_normal((n, 3)).astype(np.float32),
        "done": np.zeros(n, np.float32),
    }
    return erb_add(erb, batch)


# ---------------------------------------------------------------------------
# ERB properties
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(
    adds=st.lists(st.integers(1, 40), min_size=1, max_size=6),
    cap=st.integers(4, 64),
)
def test_erb_ring_never_exceeds_capacity(adds, cap):
    erb = erb_init(cap, OBS, task=TASK)
    total = 0
    for n in adds:
        batch = {k: v[:n] for k, v in _erb(n, cap=max(adds)).data.items()}
        erb = erb_add(erb, batch)
        total += n
        assert erb.size == min(cap, total)
        assert erb.meta.size == erb.size


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 30), want=st.integers(1, 64))
def test_erb_sample_count_and_membership(n, want):
    erb = _erb(n)
    rng = np.random.default_rng(1)
    batch = erb_sample(erb, rng, want)
    assert batch["action"].shape[0] == want
    assert set(batch["action"].tolist()) <= set(erb.data["action"][: erb.size].tolist())


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 30), share=st.integers(1, 50))
def test_erb_share_slice_bounds(n, share):
    erb = _erb(n)
    shared = erb_share_slice(erb, share, np.random.default_rng(2))
    assert shared.size == min(n, share)
    assert shared.meta.erb_id != erb.meta.erb_id
    assert shared.meta.task == erb.meta.task


# ---------------------------------------------------------------------------
# selective replay
# ---------------------------------------------------------------------------
def test_replay_mix_uses_all_pools():
    cur, per, inc = _erb(20, seed=1), _erb(20, seed=2), _erb(20, seed=3)
    s = SelectiveReplaySampler(mix=(0.5, 0.25, 0.25))
    batch = s.sample(np.random.default_rng(0), 32, cur, [per], [inc])
    assert batch["action"].shape[0] == 32


def test_replay_renormalizes_on_empty_pools():
    cur = _erb(20)
    s = SelectiveReplaySampler(mix=(0.5, 0.25, 0.25))
    batch = s.sample(np.random.default_rng(0), 16, cur, [], [])
    assert batch["action"].shape[0] == 16
    with pytest.raises(ValueError):
        s.sample(np.random.default_rng(0), 16, None, [], [])


# ---------------------------------------------------------------------------
# hubs + network (the paper's robustness claims)
# ---------------------------------------------------------------------------
def test_hub_sync_converges_without_dropout():
    hubs = [Hub(i) for i in range(3)]
    for i, h in enumerate(hubs):
        h.push(erb_share_slice(_erb(10, seed=i), 5, np.random.default_rng(i)))
    sync_hubs(hubs, np.random.default_rng(0), dropout=0.0)
    ids = [set(h.database) for h in hubs]
    assert ids[0] == ids[1] == ids[2] and len(ids[0]) == 3


@settings(max_examples=10, deadline=None)
@given(dropout=st.floats(0.0, 0.95))
def test_hub_sync_monotone_under_dropout(dropout):
    """Dropout delays but never corrupts: databases only grow, and repeated
    syncs eventually converge."""
    rng = np.random.default_rng(3)
    hubs = [Hub(i) for i in range(3)]
    for i, h in enumerate(hubs):
        h.push(erb_share_slice(_erb(10, seed=10 + i), 5, rng))
    sizes = [len(h.database) for h in hubs]
    for _ in range(200):
        sync_hubs(hubs, rng, dropout=dropout)
        new = [len(h.database) for h in hubs]
        assert all(b >= a for a, b in zip(sizes, new, strict=True))
        sizes = new
        if all(s == 3 for s in sizes):
            break
    assert all(s == 3 for s in sizes)  # converged despite dropout


def test_knowledge_survives_agent_deletion():
    """Deletion ablation invariant: ERBs pushed before an agent leaves
    remain available to the system."""
    net = Network(hubs=[Hub(0), Hub(1)], dropout=0.0)
    net.attach_agent(0, 0)
    net.attach_agent(1, 1)
    e = erb_share_slice(_erb(10), 5, np.random.default_rng(0))
    assert net.agent_push(0, e)
    net.detach_agent(0)  # agent leaves
    net.sync()
    assert e.meta.erb_id in net.hubs[1].database
    assert net.agent_pull(1, set()) != []


def test_hub_failure_loses_only_unique_erbs():
    net = Network(hubs=[Hub(0), Hub(1)], dropout=0.0)
    net.attach_agent(0, 0)
    e1 = erb_share_slice(_erb(10, seed=1), 5, np.random.default_rng(1))
    net.agent_push(0, e1)
    net.sync()  # replicated on hub 1
    e2 = erb_share_slice(_erb(10, seed=2), 5, np.random.default_rng(2))
    net.agent_push(0, e2)  # only on hub 0
    net.fail_hub(0)
    known = net.all_known("erb")
    assert e1.meta.erb_id in known  # survived (replicated)
    assert e2.meta.erb_id not in known  # lost (unique to failed hub)
    # orphaned agent re-homed
    assert net.agent_hub[0] == 1


def test_network_linear_communication():
    """Each agent talks to exactly one hub — communication linear in n."""
    net = Network(hubs=[Hub(0), Hub(1), Hub(2)])
    for a in range(12):
        net.attach_agent(a)
    loads = {}
    for a, h in net.agent_hub.items():
        loads[h] = loads.get(h, 0) + 1
    assert set(net.agent_hub) == set(range(12))
    assert max(loads.values()) - min(loads.values()) <= 1  # balanced


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------
def test_scheduler_orders_events():
    s = Scheduler()
    seen = []
    s.at(2.0, lambda sc, t: seen.append(("b", t)))
    s.at(1.0, lambda sc, t: seen.append(("a", t)))
    s.after(0.5, lambda sc, t: seen.append(("c", t)))
    s.run()
    assert [x[0] for x in seen] == ["c", "a", "b"]
    assert s.now == 2.0


def test_scheduler_every_and_stop():
    s = Scheduler()
    ticks = []
    s.every(1.0, lambda sc, t: ticks.append(t), until=5.0)
    s.run()
    assert ticks == [1.0, 2.0, 3.0, 4.0, 5.0]


def test_scheduler_deterministic():
    def run_once():
        s = Scheduler()
        order = []
        for i in range(10):
            s.at(1.0, lambda sc, t, i=i: order.append(i))
        s.run()
        return order

    assert run_once() == run_once() == list(range(10))
