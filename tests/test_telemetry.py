"""Telemetry subsystem: registry semantics and cardinality bounds, the
observe-only contract (disabled = bit-identical runs, enabled = same
numbers plus a trace), Perfetto/JSONL export round-trips, and the
``python -m repro.telemetry`` CLI."""

import json

import pytest

from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.experiments import ScenarioSpec
from repro.experiments.runner import run
from repro.telemetry import (
    NULL,
    MetricsRegistry,
    Telemetry,
    load_trace,
    to_perfetto,
    write_trace,
)
from repro.telemetry.__main__ import main as tel_main

TINY_DQN = DQNConfig(
    volume_shape=(12, 12, 12),
    box_size=(4, 4, 4),
    conv_features=(2,),
    hidden=(8,),
    batch_size=4,
    max_episode_steps=4,
    eps_decay_steps=20,
)
TINY_SYS = ADFLLConfig(
    n_agents=2,
    n_hubs=1,
    agent_hub=(0, 0),
    agent_speed=(1.0, 2.0),
    rounds=2,
    erb_capacity=128,
    erb_share_size=16,
    train_steps_per_round=2,
    hub_sync_period=0.5,
)


def _tiny_spec(**kw):
    base = dict(
        name="tiny",
        system="adfll",
        task_set="paper8",
        n_tasks=2,
        n_patients=8,
        dqn=TINY_DQN,
        sys=TINY_SYS,
        eval_patients=2,
        eval_episodes=2,
    )
    base.update(kw)
    return ScenarioSpec(**base)


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------
def test_counters_accumulate_per_label_set():
    reg = MetricsRegistry()
    reg.count("comm.bytes", 10, plane="erb")
    reg.count("comm.bytes", 5, plane="erb")
    reg.count("comm.bytes", 7, plane="weights")
    assert reg.counter_value("comm.bytes", plane="erb") == 15
    assert reg.counter_value("comm.bytes", plane="weights") == 7
    assert reg.counters_by_label("comm.bytes", "plane") == {
        "erb": 15,
        "weights": 7,
    }


def test_gauges_overwrite_and_histograms_aggregate():
    reg = MetricsRegistry()
    reg.gauge("queue.depth", 3)
    reg.gauge("queue.depth", 9)
    assert reg.gauge_value("queue.depth") == 9
    for v in (0.5, 1.5, 200.0):
        reg.observe("round.duration", v)
    h = reg.histogram("round.duration")
    assert h["count"] == 3
    assert h["sum"] == pytest.approx(202.0)
    assert sum(h["buckets"].values()) == 3


def test_label_cardinality_is_bounded_not_fatal():
    reg = MetricsRegistry(max_series=4)
    for i in range(20):
        reg.count("requests", 1, user=f"u{i}")
    # per-metric admission: at most max_series live series, the rest
    # counted as dropped — never an exception on the hot path
    assert reg.n_series == 4
    assert reg.n_dropped_series == 16
    assert reg.counter_value("requests", user="u0") == 1
    assert reg.counter_value("requests", user="u19") == 0


def test_null_bundle_is_inert():
    assert NULL.enabled is False
    NULL.count("x", 1)
    NULL.observe("y", 2.0)
    NULL.span("s", "track", 0.0, 1.0)
    NULL.instant("i", "track", 0.0)
    assert len(NULL.tracer) == 0
    assert NULL.summary()["n_events"] == 0
    assert list(NULL.registry.rows()) == []


def test_tracer_event_cap_drops_and_counts():
    tel = Telemetry(enabled=True, max_events=8)
    for i in range(20):
        tel.instant("tick", "t", float(i))
    assert len(tel.tracer) == 8
    assert tel.tracer.n_dropped == 12


# ---------------------------------------------------------------------------
# observe-only contract
# ---------------------------------------------------------------------------
def _fingerprint(report):
    s = dict(report.summary())
    s.pop("extra", None)
    curve = [
        (p.t, p.mean_err, tuple(sorted(p.per_agent.items())))
        for p in report.eval_curve
    ]
    hist = [
        (r.agent_id, r.task, r.start, r.end, r.n_incoming, r.loss)
        for r in report.history
    ]
    return json.dumps(s, sort_keys=True, default=str), curve, hist


def test_disabled_telemetry_is_bit_identical():
    base = run(_tiny_spec())
    off = run(_tiny_spec(), telemetry=Telemetry(enabled=False))
    assert _fingerprint(base) == _fingerprint(off)


def test_enabled_telemetry_is_observe_only_and_captures_spans(tmp_path):
    base = run(_tiny_spec())
    tel = Telemetry(enabled=True)
    traced = run(_tiny_spec(), telemetry=tel)
    assert _fingerprint(base) == _fingerprint(traced)
    names = {e["name"] for e in tel.tracer.events}
    assert "round" in names
    assert traced.extra["telemetry"]["n_events"] == len(tel.tracer)
    # registry carries the same byte totals the report already reports
    erb = tel.registry.counter_value("comm.bytes", plane="erb")
    assert erb == traced.summary()["bytes_by_plane"].get("erb", 0)


# ---------------------------------------------------------------------------
# export round-trips
# ---------------------------------------------------------------------------
def _sample_bundle():
    tel = Telemetry(enabled=True)
    tel.span("round", "agent0", 0.0, 1.5, task="t1", round_idx=0)
    tel.span("round", "agent1", 0.5, 2.0, task="t2", round_idx=0)
    tel.span("fleet.flush", "fleet", 0.01, 0.02, clock="wall", jobs=2)
    tel.instant("hub_sync", "scheduler", 1.0)
    tel.count("comm.bytes", 1234, plane="erb")
    tel.observe("round.duration", 1.5)
    return tel


@pytest.mark.parametrize("suffix", [".json", ".jsonl"])
def test_trace_roundtrip(tmp_path, suffix):
    tel = _sample_bundle()
    path = tmp_path / f"trace{suffix}"
    write_trace(tel, path)
    doc = load_trace(path)
    spans = [e for e in doc["events"] if e["kind"] == "span"]
    instants = [e for e in doc["events"] if e["kind"] == "instant"]
    assert sorted(e["name"] for e in spans) == ["fleet.flush", "round", "round"]
    assert [e["name"] for e in instants] == ["hub_sync"]
    tracks = {e["track"] for e in doc["events"]}
    assert tracks == {"agent0", "agent1", "fleet", "scheduler"}
    counters = [m for m in doc["metrics"] if m["kind"] == "counter"]
    assert any(
        m["name"] == "comm.bytes" and m["value"] == 1234 for m in counters
    )


def test_perfetto_document_shape():
    doc = to_perfetto(_sample_bundle())
    events = doc["traceEvents"]
    # one metadata pair (process_name, thread_name) per track + the data
    assert {e["ph"] for e in events} <= {"X", "i", "M", "C"}
    complete = [e for e in events if e["ph"] == "X"]
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in complete)
    # sim and wall clocks land in different synthetic processes
    pids = {e["pid"] for e in complete}
    assert len(pids) == 2


def test_sim_and_wall_spans_do_not_share_a_track():
    doc = to_perfetto(_sample_bundle())
    by_key = {}
    for e in doc["traceEvents"]:
        if e["ph"] == "X":
            by_key.setdefault((e["pid"], e["tid"]), set()).add(e["name"])
    for names in by_key.values():
        assert not ({"round", "fleet.flush"} <= names)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def test_cli_summarize_export_diff(tmp_path, capsys):
    a = tmp_path / "a.json"
    b = tmp_path / "b.jsonl"
    write_trace(_sample_bundle(), a)
    assert tel_main(["summarize", str(a)]) == 0
    out = capsys.readouterr().out
    assert "round" in out and "comm.bytes" in out
    assert tel_main(["export", str(a), str(b)]) == 0
    assert b.exists()
    assert len(load_trace(b)["events"]) == len(load_trace(a)["events"])
    assert tel_main(["diff", str(a), str(b)]) == 0
    assert tel_main(["summarize", str(tmp_path / "missing.json")]) == 2
