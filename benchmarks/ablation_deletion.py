"""Fig 5 reproduction: deletion-of-agents ablation.

24 -> 12 -> 6 -> 3 -> 1 agents over 5 rounds, 75% dropout. Expected
qualitative result: average error keeps decreasing even as agents leave —
the collective knowledge lives in the hub ERB database, not in the agents.
"""
from __future__ import annotations

import numpy as np

from repro.configs.adfll_dqn import DQNConfig
from repro.core.federated import env_for, evaluate_on_tasks
from repro.core.hub import Hub
from repro.core.network import Network
from repro.rl.agent import DQNAgent
from repro.rl.synth import all_tasks, patient_split

DQN = DQNConfig(volume_shape=(16, 16, 16), box_size=(6, 6, 6),
                conv_features=(4, 8), hidden=(48,), max_episode_steps=16,
                batch_size=24, eps_decay_steps=200)


def run(seed: int = 0, fast: bool = False, dropout: float = 0.75,
        schedule=(24, 12, 6, 3, 1)):
    tasks = all_tasks()
    train_p, test_p = patient_split(40)
    steps = 12 if fast else 30
    rng = np.random.default_rng(seed)
    net = Network(hubs=[Hub(i) for i in range(3)], dropout=dropout,
                  rng=np.random.default_rng(seed + 1))
    agents = [DQNAgent(i, DQN, seed=seed + i) for i in range(schedule[0])]
    for a in agents:
        net.attach_agent(a.agent_id)

    per_round = []
    task_cursor = 0
    for rnd, n_target in enumerate(schedule):
        # delete agents down to the target (their ERBs stay on the hubs)
        while len(agents) > n_target:
            gone = agents.pop()
            net.detach_agent(gone.agent_id)
        for a in agents:
            task = tasks[task_cursor % len(tasks)]
            task_cursor += 1
            env = env_for(task, int(rng.choice(train_p)), DQN)
            incoming = net.agent_pull(a.agent_id, a.seen_erb_ids)
            shared, _ = a.train_round(env, task, incoming,
                                      erb_capacity=1024, share_size=128,
                                      train_steps=steps)
            net.agent_push(a.agent_id, shared)
        net.sync()
        errs = [np.mean(list(evaluate_on_tasks(
            a, tasks[: (4 if fast else 8)], test_p, DQN).values()))
            for a in agents]
        per_round.append(float(np.mean(errs)))
        print(f"round {rnd + 1}: agents={len(agents)} "
              f"avg_err={per_round[-1]:.2f} "
              f"erbs_in_system={len(net.all_known('erb'))}")
    print("derived,errors_per_round=" +
          ";".join(f"{e:.2f}" for e in per_round))
    return per_round


if __name__ == "__main__":
    run()
