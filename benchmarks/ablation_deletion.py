"""Fig 5 reproduction: deletion-of-agents ablation.

24 -> 12 -> 6 -> 3 -> 1 agents under 75% dropout, evaluated at every
churn boundary.  The deletions are a declarative schedule inside the
``churn_deletion_fig5`` scenario (timed ``ChurnEvent`` removals — the
newest joiners retire first, their ERBs staying on the hubs).  Expected
qualitative result: average error keeps decreasing even as agents leave —
the collective knowledge lives in the hub ERB database, not in the
agents.

    PYTHONPATH=src python -m benchmarks.ablation_deletion [--fast] \\
        [--seed N] [--json OUT] [--check BASELINE]

One ``phaseN`` row per evaluation-curve point (the final one also
reports the ERBs surviving in the system); ``--check`` gates each
phase's ``mean_err``.
"""

from __future__ import annotations

import json

from repro import experiments

SCENARIO = "churn_deletion_fig5"


def run(seed: int = 0, fast: bool = False, json_path=None):
    report = experiments.run(SCENARIO, fast=fast, seed=seed)
    results = {}
    for i, p in enumerate(report.eval_curve):
        results[f"phase{i + 1}"] = {
            "t": p.t,
            "n_agents": p.n_agents,
            "mean_err": p.mean_err,
        }
        print(
            f"phase {i + 1}: t={p.t:.2f} agents={p.n_agents} "
            f"avg_err={p.mean_err:.2f}"
        )
    erbs = report.records_known.get("erb", 0)
    results[f"phase{len(report.eval_curve)}"]["erbs_in_system"] = erbs
    errs = [p.mean_err for p in report.eval_curve]
    print(
        "derived,errors_per_phase="
        + ";".join(f"{e:.2f}" for e in errs)
        + f",erbs_in_system={erbs}"
    )
    if json_path:
        payload = {
            "benchmark": "ablation_deletion",
            "seed": seed,
            "fast": bool(fast),
            "configs": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return results


if __name__ == "__main__":
    import sys

    from benchmarks.cli import Gate, bench_main

    sys.exit(
        bench_main(
            run,
            benchmark="ablation_deletion",
            seed=True,
            gates=(Gate("mean_err", tol=0.35, abs_floor=1.0),),
        )
    )
