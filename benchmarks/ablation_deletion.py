"""Fig 5 reproduction: deletion-of-agents ablation.

24 -> 12 -> 6 -> 3 -> 1 agents under 75% dropout, evaluated at every
churn boundary.  The deletions are a declarative schedule inside the
``churn_deletion_fig5`` scenario (timed ``ChurnEvent`` removals — the
newest joiners retire first, their ERBs staying on the hubs).  Expected
qualitative result: average error keeps decreasing even as agents leave —
the collective knowledge lives in the hub ERB database, not in the
agents.
"""

from __future__ import annotations

from repro import experiments

SCENARIO = "churn_deletion_fig5"


def run(seed: int = 0, fast: bool = False):
    report = experiments.run(SCENARIO, fast=fast, seed=seed)
    for i, p in enumerate(report.eval_curve):
        print(
            f"phase {i + 1}: t={p.t:.2f} agents={p.n_agents} "
            f"avg_err={p.mean_err:.2f}"
        )
    errs = [p.mean_err for p in report.eval_curve]
    print(
        "derived,errors_per_phase="
        + ";".join(f"{e:.2f}" for e in errs)
        + f",erbs_in_system={report.records_known.get('erb', 0)}"
    )
    return errs


if __name__ == "__main__":
    run()
