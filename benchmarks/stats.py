"""Back-compat shim: the scipy-free stats were promoted into
``repro.sweeps.stats`` (paired t-test, permutation test, t-based CIs)
so the sweep aggregation layer and the classic benchmarks share one
implementation.  Import from there in new code."""

from repro.sweeps.stats import (  # noqa: F401
    mean_ci,
    paired_permutation_test,
    paired_ttest,
    t_crit,
    t_sf,
)

__all__ = ["mean_ci", "paired_permutation_test", "paired_ttest", "t_crit", "t_sf"]
