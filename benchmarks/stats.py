"""Paired t-test without scipy (regularized incomplete beta, NR betacf)."""

from __future__ import annotations

import math

import numpy as np


def _betacf(a, b, x, max_iter=200, eps=3e-12):
    qab, qap, qam = a + b, a + 1.0, a - 1.0
    c, d = 1.0, 1.0 - qab * x / qap
    if abs(d) < 1e-30:
        d = 1e-30
    d = 1.0 / d
    h = d
    for m in range(1, max_iter + 1):
        m2 = 2 * m
        aa = m * (b - m) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        if abs(d) < 1e-30:
            d = 1e-30
        c = 1.0 + aa / c
        if abs(c) < 1e-30:
            c = 1e-30
        d = 1.0 / d
        h *= d * c
        aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        if abs(d) < 1e-30:
            d = 1e-30
        c = 1.0 + aa / c
        if abs(c) < 1e-30:
            c = 1e-30
        d = 1.0 / d
        delta = d * c
        h *= delta
        if abs(delta - 1.0) < eps:
            break
    return h


def _betainc(a, b, x):
    if x <= 0.0:
        return 0.0
    if x >= 1.0:
        return 1.0
    ln_beta = (
        math.lgamma(a + b)
        - math.lgamma(a)
        - math.lgamma(b)
        + a * math.log(x)
        + b * math.log(1.0 - x)
    )
    front = math.exp(ln_beta)
    if x < (a + 1.0) / (a + b + 2.0):
        return front * _betacf(a, b, x) / a
    return 1.0 - front * _betacf(b, a, 1.0 - x) / b


def t_sf(t, df):
    """Two-sided p-value for a t statistic."""
    x = df / (df + t * t)
    return _betainc(df / 2.0, 0.5, x)


def paired_ttest(a, b):
    """Returns (t, two-sided p). a, b: paired samples."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    d = a - b
    n = len(d)
    sd = d.std(ddof=1)
    if sd == 0:
        return 0.0, 1.0
    t = d.mean() / (sd / math.sqrt(n))
    return float(t), float(t_sf(abs(t), n - 1))
