"""Topology ablation: hub vs hub-less gossip vs hybrid (+ compression).

The paper's deployment routes every share through hubs; BrainTorrent-style
gossip removes the hub from the loop entirely.  Each row is a registered
scenario (``topo_hub`` / ``topo_gossip`` / ``topo_hybrid`` /
``topo_gossip_topk``) — identical tasks, seeds, and heterogeneous agent
speeds, both sharing planes active — over a *priced* link (latency +
bytes/rate), and the report carries per configuration:

* mean terminal distance error over the task suite,
* simulated makespan (hub rounds block on agent-link transfer time,
  while gossip replication runs in background anti-entropy events off
  the training critical path — so makespan differences between rows
  reflect that architectural difference, and bytes-on-wire is the
  like-for-like transport comparison),
* bytes-on-wire per plane (hub links, hub-hub sync, and gossip
  anti-entropy all account on one meter),
* transport volume (records pushed, peer snapshots mixed, foreign ERBs
  consumed, gossip round statistics).

The ``gossip_topk`` row runs the gossip topology with the compressed
weight plane (int8 top-k deltas) to show the bytes-on-wire win.

    PYTHONPATH=src python -m benchmarks.gossip_ablation [--fast] [--json OUT]

Sized to finish in well under 5 minutes on CPU.
"""

from __future__ import annotations

import json

from benchmarks import plane_ablation
from repro import experiments

# classic row name -> registered scenario
TOPOLOGY_SCENARIOS = {
    "hub": "topo_hub",
    "gossip": "topo_gossip",
    "hybrid": "topo_hybrid",
    "gossip_topk": "topo_gossip_topk",
}


ROW_KEYS = (
    *plane_ablation.ROW_KEYS,
    "comm_time",
    "bytes_by_plane",
    "msgs_by_plane",
    "total_bytes",
)


def _row(report):
    out = plane_ablation.summary_row(report, ROW_KEYS)
    if "gossip" in report.extra:
        out["gossip"] = report.extra["gossip"]
    return out


def run(seed=0, fast=False, json_path=None, trace_path=None, dashboard_path=None):
    from benchmarks.cli import per_config_path

    results = {}
    print(
        "config,mean_dist_err,best_agent_err,sim_makespan,"
        "erb_bytes,weight_bytes,n_mixed,n_foreign_erbs"
    )
    for name, scenario in TOPOLOGY_SCENARIOS.items():
        r = _row(
            experiments.run(
                scenario,
                fast=fast,
                seed=seed,
                trace_path=per_config_path(trace_path, name),
                dashboard_path=per_config_path(dashboard_path, name),
            )
        )
        results[name] = r
        print(
            f"{name},{r['mean_dist_err']:.3f},{r['best_agent_err']:.3f},"
            f"{r['sim_makespan']:.2f},"
            f"{r['bytes_by_plane'].get('erb', 0)},"
            f"{r['bytes_by_plane'].get('weights', 0)},"
            f"{r['n_mixed']},{r['n_foreign_erbs']}"
        )
    for name, r in results.items():
        print(
            f"derived,{name},total_bytes={r['total_bytes']},"
            f"gossip={r.get('gossip')}"
        )
    if json_path:
        payload = {
            "benchmark": "gossip_ablation",
            "seed": seed,
            "fast": bool(fast),
            "configs": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return results


if __name__ == "__main__":
    import sys

    from benchmarks.cli import Gate, bench_main

    sys.exit(
        bench_main(
            run,
            benchmark="gossip_ablation",
            seed=True,
            gates=(Gate("mean_dist_err"),),
        )
    )
