"""Topology ablation: hub vs hub-less gossip vs hybrid (+ compression).

The paper's deployment routes every share through hubs; BrainTorrent-style
gossip removes the hub from the loop entirely.  This ablation runs the
deployment system once per topology — identical tasks, seeds, and
heterogeneous agent speeds, both sharing planes active — over a *priced*
link (latency + bytes/rate), and reports per configuration:

* mean terminal distance error over the task suite (mean across agents
  and across each agent's per-task mean, on held-out patients),
* simulated makespan (event-driven scheduler time; hub rounds block on
  agent-link transfer time, while gossip replication runs in background
  anti-entropy events whose deliveries land at latency + bytes/rate off
  the training critical path — so makespan differences between rows
  reflect that architectural difference, and bytes-on-wire is the
  like-for-like transport comparison),
* bytes-on-wire per plane (hub links, hub-hub sync, and gossip
  anti-entropy all account on one meter),
* transport volume (records pushed, peer snapshots mixed, foreign ERBs
  consumed, gossip round statistics).

The ``gossip_topk`` row runs the gossip topology with the compressed
weight plane (int8 top-k deltas) to show the bytes-on-wire win.

    PYTHONPATH=src python -m benchmarks.gossip_ablation [--fast] [--json OUT]

Sized to finish in well under 5 minutes on CPU.
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.core.federated import ADFLLSystem, evaluate_on_tasks
from repro.rl.synth import paper_eight_tasks, patient_split

DQN = DQNConfig(volume_shape=(16, 16, 16), box_size=(6, 6, 6),
                conv_features=(4,), hidden=(32,), max_episode_steps=12,
                batch_size=16, eps_decay_steps=100)

# every config shares both planes over a priced link; only transport differs
TOPOLOGY_CONFIGS = {
    "hub": dict(topology="hub"),
    "gossip": dict(topology="gossip", gossip_sampler="random",
                   gossip_fanout=2),
    "hybrid": dict(topology="hybrid", gossip_sampler="random",
                   gossip_fanout=2),
    "gossip_topk": dict(topology="gossip", gossip_sampler="random",
                        gossip_fanout=2, weight_compression="topk",
                        weight_topk_frac=0.05),
}

LINK = dict(link_latency=0.002, link_rate=float(2 ** 22))  # 4 MiB / sim-unit


def run_one(overrides, tasks, train_p, test_p, *, rounds, steps, seed=0):
    sys_cfg = ADFLLConfig(rounds=rounds, train_steps_per_round=steps,
                          erb_capacity=512, erb_share_size=64,
                          hub_sync_period=0.25, gossip_period=0.25,
                          share_planes=("erb", "weights"),
                          mix_alpha=0.6, staleness_flag="poly",
                          staleness_poly_a=0.5, seed=seed,
                          **LINK, **overrides)
    sysm = ADFLLSystem(sys_cfg, DQN, tasks, train_p, seed=seed)
    makespan = sysm.run()
    per_agent = [float(np.mean(list(
        evaluate_on_tasks(ag, tasks, test_p, DQN).values())))
        for _, ag in sorted(sysm.agents.items())]
    meter = sysm.network.meter
    out = {
        "mean_dist_err": float(np.mean(per_agent)),
        "best_agent_err": float(np.min(per_agent)),
        "sim_makespan": float(makespan),
        "comm_time": float(sum(r.comm_time for r in sysm.history)),
        "n_mixed": sum(r.n_mixed for r in sysm.history),
        "n_foreign_erbs": sum(r.n_incoming for r in sysm.history),
        "pushed": dict(sysm.network.plane_pushed),
        "bytes_by_plane": dict(meter.bytes_by_plane),
        "msgs_by_plane": dict(meter.msgs_by_plane),
        "total_bytes": meter.total_bytes,
    }
    if sysm.network.gossip is not None:
        st = sysm.network.gossip.stats
        out["gossip"] = {"rounds": st.n_rounds, "exchanges": st.n_exchanges,
                         "sent": st.n_sent, "delivered": st.n_delivered,
                         "dropped": st.n_dropped}
    return out


def run(seed=0, fast=False, json_path=None):
    tasks = paper_eight_tasks()[:4]
    train_p, test_p = patient_split(16)
    rounds = 2
    steps = 10 if fast else 30

    results = {}
    print("config,mean_dist_err,best_agent_err,sim_makespan,"
          "erb_bytes,weight_bytes,n_mixed,n_foreign_erbs")
    for name, overrides in TOPOLOGY_CONFIGS.items():
        r = run_one(overrides, tasks, train_p, test_p, rounds=rounds,
                    steps=steps, seed=seed)
        results[name] = r
        print(f"{name},{r['mean_dist_err']:.3f},{r['best_agent_err']:.3f},"
              f"{r['sim_makespan']:.2f},"
              f"{r['bytes_by_plane'].get('erb', 0)},"
              f"{r['bytes_by_plane'].get('weights', 0)},"
              f"{r['n_mixed']},{r['n_foreign_erbs']}")
    for name, r in results.items():
        print(f"derived,{name},total_bytes={r['total_bytes']},"
              f"gossip={r.get('gossip')}")
    if json_path:
        payload = {"benchmark": "gossip_ablation", "seed": seed,
                   "fast": bool(fast), "configs": results}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced step counts (CI sanity)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", type=str, default=None, metavar="OUT",
                    help="write results as JSON (BENCH_*.json for CI gating)")
    args = ap.parse_args()
    run(seed=args.seed, fast=args.fast, json_path=args.json)
