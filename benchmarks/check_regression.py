"""Benchmark regression gate for CI.

Compares a freshly produced ``BENCH_*.json`` (written by a benchmark's
``--json`` flag, ``python -m repro.experiments --json``, or
``python -m repro.sweeps --json``) against the baseline checked in under
``benchmarks/baselines/``.

Two input shapes are understood:

* **point runs** (classic benchmarks / experiments): ``configs`` maps a
  name to flat metrics; the run fails when any config's mean distance
  error regresses by more than ``--tol`` (relative) AND more than
  ``--abs-floor`` voxels (absolute — small baselines would otherwise
  turn float jitter into failures).
* **sweep summaries** (``"variants"`` present): each variant carries a
  multi-seed mean ± 95% CI, and the gate becomes *significance-aware* —
  on top of the tol/floor thresholds, the current lower CI bound must
  clear the baseline's upper CI bound (non-overlapping intervals).  A
  wobble the seeds cannot distinguish from noise does not fail CI.

Configs present only in the current run (newly added benchmarks) pass;
configs missing from the current run fail.

``--metric`` selects the gated metric (default ``mean_dist_err``) and
``--higher-better`` flips the direction — throughput benchmarks gate a
speedup ratio, where a *drop* is the regression:

    python -m benchmarks.check_regression BASELINE CURRENT \
        [--tol 0.2] [--abs-floor 0.75] [--metric NAME] [--higher-better]

Exit code 0 = within tolerance, 1 = regression (or malformed input).
"""

from __future__ import annotations

import argparse
import json
import math
import sys

METRIC = "mean_dist_err"


def _as_configs(data: dict, metric: str) -> dict:
    """Normalize either input shape to name -> {mean, ci95}.

    ``ci95`` is None for point runs and for single-seed sweeps (n < 2
    has no interval); the gate then falls back to thresholds alone."""
    if "variants" in data:
        out = {}
        for name, v in data["variants"].items():
            st = (v.get("metrics") or {}).get(metric) or {}
            out[name] = {"mean": st.get("mean"), "ci95": st.get("ci95")}
        return out
    return {
        name: {"mean": cfg.get(metric), "ci95": None}
        for name, cfg in data.get("configs", {}).items()
    }


def _ci(x) -> float:
    """A usable CI half-width (0.0 when absent/NaN: point comparison)."""
    if x is None or not isinstance(x, (int, float)) or not math.isfinite(x):
        return 0.0
    return float(x)


def compare(
    baseline: dict,
    current: dict,
    *,
    tol: float,
    abs_floor: float,
    metric: str = METRIC,
    higher_better: bool = False,
) -> list:
    """Returns a list of human-readable failure strings (empty = pass)."""
    failures = []
    base_cfgs = _as_configs(baseline, metric)
    cur_cfgs = _as_configs(current, metric)
    if not base_cfgs:
        return ["baseline has no configs — malformed file?"]
    for name, base in sorted(base_cfgs.items()):
        if name not in cur_cfgs:
            failures.append(f"{name}: missing from current run")
            continue
        b, c = base["mean"], cur_cfgs[name]["mean"]
        if b is None:
            # this config does not carry the gated metric (benchmarks may
            # mix metric families in one file, e.g. speedup rows next to
            # a telemetry_overhead row) — not a regression
            print(f"skip {name}: no baseline {metric}")
            continue
        if c is None:
            failures.append(f"{name}: {metric} missing")
            continue
        if higher_better:
            worse = c < b * (1.0 - tol) and c < b - abs_floor
        else:
            worse = c > b * (1.0 + tol) and c > b + abs_floor
        b_ci, c_ci = _ci(base["ci95"]), _ci(cur_cfgs[name]["ci95"])
        if higher_better:
            separated = (c + c_ci) < (b - b_ci)
        else:
            separated = (c - c_ci) > (b + b_ci)
        if worse and separated:
            direction = "below" if higher_better else "worse"
            failures.append(
                f"{name}: {metric} {c:.3f}±{c_ci:.3f} vs baseline "
                f"{b:.3f}±{b_ci:.3f} (>{tol:.0%} {direction}, "
                f">{abs_floor} absolute, CIs separated)"
            )
        else:
            note = " (within CI overlap)" if worse else ""
            print(f"ok {name}: {metric} {c:.3f} (baseline {b:.3f}){note}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="checked-in benchmarks/baselines/*.json")
    ap.add_argument("current", help="freshly written BENCH_*.json")
    ap.add_argument(
        "--tol",
        type=float,
        default=0.20,
        help="max relative regression of mean distance error",
    )
    ap.add_argument(
        "--abs-floor",
        type=float,
        default=0.75,
        help="regressions below this absolute delta never fail",
    )
    ap.add_argument(
        "--metric",
        default=METRIC,
        help="metric key to gate (default: mean_dist_err)",
    )
    ap.add_argument(
        "--higher-better",
        action="store_true",
        help="gate a metric where a drop is the regression (e.g. speedup)",
    )
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    failures = compare(
        baseline,
        current,
        tol=args.tol,
        abs_floor=args.abs_floor,
        metric=args.metric,
        higher_better=args.higher_better,
    )
    for msg in failures:
        print(f"REGRESSION {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
