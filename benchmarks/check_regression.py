"""Benchmark regression gate for CI.

Compares a freshly produced ``BENCH_*.json`` (written by a benchmark's
``--json`` flag or ``python -m repro.experiments --json``) against the
baseline checked in under ``benchmarks/baselines/``: the run fails when
any config's mean distance error regresses by more than ``--tol``
(relative) AND more than ``--abs-floor`` voxels (absolute — small
baselines would otherwise turn float jitter into failures).  Configs
present only in the current run (newly added benchmarks) pass; configs
missing from the current run fail.

    python -m benchmarks.check_regression BASELINE CURRENT \
        [--tol 0.2] [--abs-floor 0.75]

Exit code 0 = within tolerance, 1 = regression (or malformed input).
"""

from __future__ import annotations

import argparse
import json
import sys

METRIC = "mean_dist_err"


def compare(baseline: dict, current: dict, *, tol: float, abs_floor: float) -> list:
    """Returns a list of human-readable failure strings (empty = pass)."""
    failures = []
    base_cfgs = baseline.get("configs", {})
    cur_cfgs = current.get("configs", {})
    if not base_cfgs:
        return ["baseline has no configs — malformed file?"]
    for name, base in sorted(base_cfgs.items()):
        if name not in cur_cfgs:
            failures.append(f"{name}: missing from current run")
            continue
        b = base.get(METRIC)
        c = cur_cfgs[name].get(METRIC)
        if b is None or c is None:
            failures.append(f"{name}: {METRIC} missing")
            continue
        if c > b * (1.0 + tol) and c > b + abs_floor:
            failures.append(
                f"{name}: {METRIC} {c:.3f} vs baseline {b:.3f} "
                f"(>{tol:.0%} worse and >+{abs_floor} absolute)"
            )
        else:
            print(f"ok {name}: {METRIC} {c:.3f} (baseline {b:.3f})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="checked-in benchmarks/baselines/*.json")
    ap.add_argument("current", help="freshly written BENCH_*.json")
    ap.add_argument(
        "--tol",
        type=float,
        default=0.20,
        help="max relative regression of mean distance error",
    )
    ap.add_argument(
        "--abs-floor",
        type=float,
        default=0.75,
        help="regressions below this absolute delta never fail",
    )
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    failures = compare(baseline, current, tol=args.tol, abs_floor=args.abs_floor)
    for msg in failures:
        print(f"REGRESSION {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
