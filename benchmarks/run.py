"""Benchmark harness — one entry per paper table/figure + framework rows.

Prints ``name,us_per_call,derived`` CSV lines (one per benchmark) followed
by each benchmark's own detailed output.  Every system benchmark builds
its systems through the ``repro.experiments`` scenario registry.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Paper mapping:
  deployment        -> Table 1 + Fig 3 (scenario paper_fig2 + baseline_*)
  ablation_addition -> Fig 4 (scenario churn_addition_fig4)
  ablation_deletion -> Fig 5 (scenario churn_deletion_fig5)
  plane_ablation    -> beyond-paper: plane_* scenarios (ERB/weights/hybrid)
  gossip_ablation   -> beyond-paper: topo_* scenarios, bytes-on-wire per
                       plane, compressed weight plane
  population        -> beyond-paper: trace-driven fleet scenarios
                       (hospital_diurnal / flash_crowd / stragglers)
  kernels           -> framework kernel microbenches (Pallas vs oracle)
  roofline          -> EXPERIMENTS.md §Roofline source table (reads the
                       dry-run JSONs; run repro.launch.dryrun --all first)
"""

from __future__ import annotations

import time

from benchmarks.cli import build_parser


def main(argv=None) -> None:
    ap = build_parser("python -m benchmarks.run")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    if args.json or args.check:
        ap.error("the harness has no single JSON; use a benchmark's own --json")

    from benchmarks import (
        ablation_addition,
        ablation_deletion,
        deployment,
        forgetting,
        gossip_ablation,
        kernels,
        plane_ablation,
        population_dynamics,
        roofline,
    )

    benches = [
        ("deployment_table1", lambda: deployment.run(fast=args.fast)),
        ("ablation_addition_fig4", lambda: ablation_addition.run(fast=args.fast)),
        ("ablation_deletion_fig5", lambda: ablation_deletion.run(fast=args.fast)),
        ("plane_ablation", lambda: plane_ablation.run(fast=args.fast)),
        ("gossip_ablation", lambda: gossip_ablation.run(fast=args.fast)),
        ("forgetting_ablation", lambda: forgetting.run(fast=args.fast)),
        ("population_dynamics", lambda: population_dynamics.run(fast=args.fast)),
        ("kernels_micro", kernels.run),
        ("roofline_table", roofline.run),
    ]
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.perf_counter()
        fn()
        us = (time.perf_counter() - t0) * 1e6
        print(f"{name},{us:.0f},wall_us")


if __name__ == "__main__":
    main()
