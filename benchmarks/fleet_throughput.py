"""Fleet-engine throughput: one scan-fused vmapped dispatch per flush vs
the legacy per-agent path (one dispatch + one blocking host sync per
training step).

Each row sizes a fleet of N same-config agents, fills one replay buffer
per agent, and trains every agent for K steps per round:

* ``stepwise`` — the pre-fleet execution model: per-step host batch
  materialization, one ``train_fn`` dispatch per step, ``float(loss)``
  sync after every update (N x K dispatches per round).
* ``fleet`` — all N rounds submitted as jobs and flushed as one
  compiled program: host-side index *planning* only, device-resident
  ERB pools, batch materialization through the ``replay_gather`` Pallas
  kernel inside the scan (1 dispatch per round of N x K updates).

Reported per N: steps/sec of both paths, wall time per round, and the
speedup ratio (the CI-gated metric — machine-speed independent, unlike
raw steps/sec):

    PYTHONPATH=src python -m benchmarks.fleet_throughput [--fast] [--json OUT]

Gated in CI via ``check_regression --metric speedup --higher-better``
against ``benchmarks/baselines/BENCH_fleet.json``.

With ``--devices N`` the benchmark switches to **mesh mode**: each size
is run twice with identical seeds and plan streams — single-device vs
sharded across an N-device fleet mesh — and the ``mesh_n{A}`` rows
report both throughputs, ``agents_per_device``, the CI-gated
``mesh_speedup`` ratio, and ``bit_identical`` (the run *fails* if the
sharded params are not bitwise equal to single-device).  On CPU combine
with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the
``bench-smoke`` job gates this against
``benchmarks/baselines/BENCH_fleet_mesh.json``); see
``docs/scaling.md``.

A ``telemetry`` row additionally times the fleet path with an enabled
:class:`~repro.telemetry.Telemetry` bundle *plus the full observatory*
(the stats-carrying train chunk) against the default disabled path and
reports ``telemetry_overhead`` (enabled/disabled wall-time ratio, ~1.0)
— gated so instrumentation on the flush hot path stays observe-only in
cost as well as in semantics.  The *disabled* path's
cost is covered by the ``speedup`` gate itself: its baseline numbers
predate the telemetry subsystem, so any disabled-mode overhead would
show up there as a speedup regression.
"""

from __future__ import annotations

import json
import time

import numpy as np

import jax

import repro.core  # noqa: F401  (resolve the core<->rl import cycle first)
from repro.configs.adfll_dqn import DQNConfig
from repro.core.erb import ERB, TaskTag, erb_add, erb_init
from repro.models.sharding import make_fleet_mesh
from repro.rl.agent import DQNAgent
from repro.rl.fleet import FleetEngine
from repro.observatory import Observatory
from repro.telemetry import Telemetry, write_trace

# Sized so the per-step *overhead* the engine eliminates (host batch
# materialization, per-step dispatch, blocking loss sync) is not drowned
# by conv compute that both paths share — the same reason the tier-1
# tests use a reduced DQN. Batch 8 at box 6^3 keeps one train step ~1 ms
# of pure compute on CPU.
CFG = DQNConfig(
    volume_shape=(16, 16, 16),
    box_size=(6, 6, 6),
    conv_features=(4,),
    hidden=(32,),
    batch_size=8,
)
TASK = TaskTag("t1", "axial", "HGG")


def _filled_erb(rng: np.random.Generator, capacity: int) -> ERB:
    erb = erb_init(capacity, CFG.box_size, task=TASK)
    n = capacity
    batch = {
        "obs": rng.standard_normal((n, *CFG.box_size)).astype(np.float32),
        "loc": rng.random((n, 3)).astype(np.float32),
        "action": rng.integers(0, CFG.n_actions, n).astype(np.int32),
        "reward": rng.standard_normal(n).astype(np.float32),
        "next_obs": rng.standard_normal((n, *CFG.box_size)).astype(np.float32),
        "next_loc": rng.random((n, 3)).astype(np.float32),
        "done": (rng.random(n) < 0.1).astype(np.float32),
    }
    erb_add(erb, batch)
    return erb


def _bench_pair(
    n_agents: int, steps: int, repeats: int, capacity: int
) -> tuple[float, float]:
    """(stepwise, fleet) seconds per round of N x K updates.

    The two paths are timed in *interleaved* repeats and each reported as
    its minimum — a load spike on a shared CI machine then has to cover
    every window of one path to bias the ratio, instead of one
    contiguous measurement block."""
    rng = np.random.default_rng(0)
    legacy = [DQNAgent(i, CFG, seed=i, backend="stepwise") for i in range(n_agents)]
    engine = FleetEngine(CFG)
    fleet = [DQNAgent(i, CFG, seed=i, engine=engine) for i in range(n_agents)]
    erbs = [_filled_erb(rng, capacity) for _ in range(n_agents)]

    def stepwise_round():
        for a, e in zip(legacy, erbs, strict=True):
            a.train_steps(steps, e)

    def fleet_round():
        for a, e in zip(fleet, erbs, strict=True):
            plans = [a.sampler.plan(a.rng, CFG.batch_size, e) for _ in range(steps)]
            engine.submit(a.slot, plans)
        engine.flush()

    for a, e in zip(legacy, erbs, strict=True):
        a.train_steps(1, e)  # warm the per-step compile
    fleet_round()  # warm the chunk compile for this (K, N, R) shape
    t_step = t_fleet = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        stepwise_round()
        t_step = min(t_step, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fleet_round()
        t_fleet = min(t_fleet, time.perf_counter() - t0)
    return t_step, t_fleet


def _bench_telemetry(
    n_agents: int,
    steps: int,
    repeats: int,
    capacity: int,
    trace_path: str | None = None,
) -> tuple[float, float, Telemetry]:
    """(disabled, enabled) fleet-round seconds + the enabled bundle.

    Same interleaved min-of-repeats discipline as :func:`_bench_pair`:
    the two telemetry modes alternate within each repeat so shared-
    machine noise cannot bias the ratio."""
    rng = np.random.default_rng(0)
    tel = Telemetry(enabled=True)
    engine_off = FleetEngine(CFG)  # default NULL telemetry
    engine_on = FleetEngine(CFG)
    engine_on.telemetry = tel
    # the enabled path carries the full observatory too: the gate bounds
    # the cost of the stats-carrying train chunk, not just the spans
    obs = Observatory(tel)
    engine_on.observatory = obs
    fleets = {
        "off": (
            engine_off,
            [DQNAgent(i, CFG, seed=i, engine=engine_off) for i in range(n_agents)],
        ),
        "on": (
            engine_on,
            [DQNAgent(i, CFG, seed=i, engine=engine_on) for i in range(n_agents)],
        ),
    }
    for i, a in enumerate(fleets["on"][1]):
        obs.register_slot(a.slot, i)
    erbs = [_filled_erb(rng, capacity) for _ in range(n_agents)]

    def fleet_round(which: str):
        engine, fleet = fleets[which]
        for a, e in zip(fleet, erbs, strict=True):
            plans = [a.sampler.plan(a.rng, CFG.batch_size, e) for _ in range(steps)]
            engine.submit(a.slot, plans)
        engine.flush()

    fleet_round("off")  # warm the shared chunk compile
    fleet_round("on")
    t_off = t_on = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fleet_round("off")
        t_off = min(t_off, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fleet_round("on")
        t_on = min(t_on, time.perf_counter() - t0)
    if trace_path:
        write_trace(tel, trace_path)
        print(f"wrote trace {trace_path}")
    return t_off, t_on, tel


def _bench_mesh(
    n_agents: int, steps: int, repeats: int, capacity: int, mesh
) -> tuple[float, float, bool]:
    """(single-device, sharded) seconds per round of N x K updates, plus
    whether the two engines' final stacked params are *bitwise* equal.

    Both fleets are seeded identically and submit identical plan streams,
    so after equal rounds their states must match bit for bit — the
    sharded engine's per-slot math is mesh-invariant (the acceptance
    property the mesh subprocess test asserts; checked here on every
    benchmark run too). Interleaved min-of-repeats as in
    :func:`_bench_pair`."""
    rng = np.random.default_rng(0)
    single = FleetEngine(CFG)
    sharded = FleetEngine(CFG, mesh=mesh)
    flat = [DQNAgent(i, CFG, seed=i, engine=single) for i in range(n_agents)]
    shard = [DQNAgent(i, CFG, seed=i, engine=sharded) for i in range(n_agents)]
    erbs = [_filled_erb(rng, capacity) for _ in range(n_agents)]

    def round_of(engine, fleet):
        for a, e in zip(fleet, erbs, strict=True):
            plans = [a.sampler.plan(a.rng, CFG.batch_size, e) for _ in range(steps)]
            engine.submit(a.slot, plans)
        engine.flush()

    round_of(single, flat)  # warm both chunk compiles
    round_of(sharded, shard)
    t_single = t_shard = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        round_of(single, flat)
        t_single = min(t_single, time.perf_counter() - t0)
        t0 = time.perf_counter()
        round_of(sharded, shard)
        t_shard = min(t_shard, time.perf_counter() - t0)
    identical = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(
            jax.tree_util.tree_leaves(single.stacked_params()),
            jax.tree_util.tree_leaves(sharded.stacked_params()),
            strict=True,
        )
    )
    return t_single, t_shard, identical


def _run_mesh(fast: bool, devices: int) -> dict:
    """The mesh scaling rows (``--devices``): sharded vs single-device
    engine at large N — ``mesh_speedup`` is the CI-gated column, checked
    against ``BENCH_fleet_mesh.json`` (a separate baseline: the plain
    smoke's rows and these never appear in the same run)."""
    mesh = make_fleet_mesh(devices)
    if mesh is None:
        raise SystemExit(
            f"--devices {devices}: only {len(jax.devices())} device(s) "
            "visible; set XLA_FLAGS=--xla_force_host_platform_device_count=N "
            "for a CPU host-platform mesh"
        )
    sizes = (32,) if fast else (64, 256)
    steps = 20 if fast else 40
    repeats = 2 if fast else 3
    capacity = 512
    results = {}
    print(
        "config,n_agents,devices,agents_per_device,single_sps,mesh_sps,"
        "mesh_speedup,bit_identical"
    )
    for n in sizes:
        t_single, t_shard, identical = _bench_mesh(n, steps, repeats, capacity, mesh)
        total = n * steps
        row = {
            "n_agents": n,
            "train_steps": steps,
            "devices": mesh.size,
            "agents_per_device": n / mesh.size,
            "single_steps_per_sec": total / t_single,
            "mesh_steps_per_sec": total / t_shard,
            "mesh_speedup": t_single / t_shard,
            "bit_identical": identical,
        }
        results[f"mesh_n{n}"] = row
        print(
            f"mesh_n{n},{n},{mesh.size},{row['agents_per_device']:.0f},"
            f"{row['single_steps_per_sec']:.1f},{row['mesh_steps_per_sec']:.1f},"
            f"{row['mesh_speedup']:.2f},{identical}"
        )
        if not identical:
            raise SystemExit(
                f"mesh_n{n}: sharded params diverged from single-device "
                "engine (bit-identity violated)"
            )
    return results


def run(
    fast: bool = False,
    json_path: str | None = None,
    trace_path: str | None = None,
    devices: int = 0,
):
    if devices:
        results = _run_mesh(fast, devices)
        if json_path:
            payload = {
                "benchmark": "fleet_throughput",
                "fast": bool(fast),
                "configs": results,
            }
            with open(json_path, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            print(f"wrote {json_path}")
        return results
    sizes = (2, 8) if fast else (2, 8, 32)
    steps = 40 if fast else 150
    repeats = 4 if fast else 4
    capacity = 512
    results = {}
    print("config,n_agents,steps,stepwise_sps,fleet_sps,speedup")
    for n in sizes:
        t_step, t_fleet = _bench_pair(n, steps, repeats, capacity)
        total = n * steps
        row = {
            "n_agents": n,
            "train_steps": steps,
            "stepwise_steps_per_sec": total / t_step,
            "fleet_steps_per_sec": total / t_fleet,
            "stepwise_round_sec": t_step,
            "fleet_round_sec": t_fleet,
            "speedup": t_step / t_fleet,
        }
        results[f"n{n}"] = row
        print(
            f"n{n},{n},{steps},{row['stepwise_steps_per_sec']:.1f},"
            f"{row['fleet_steps_per_sec']:.1f},{row['speedup']:.2f}"
        )
    n_tel = sizes[-1] if not fast else sizes[0]
    t_off, t_on, tel = _bench_telemetry(n_tel, steps, repeats, capacity, trace_path)
    results["telemetry"] = {
        "n_agents": n_tel,
        "train_steps": steps,
        "fleet_round_sec_off": t_off,
        "fleet_round_sec_on": t_on,
        "telemetry_overhead": t_on / t_off,
        "trace_events": len(tel.tracer.events),
    }
    print(
        f"telemetry,{n_tel},{steps},off={t_off * 1e3:.1f}ms,"
        f"on={t_on * 1e3:.1f}ms,"
        f"overhead={results['telemetry']['telemetry_overhead']:.3f}"
    )
    if json_path:
        payload = {
            "benchmark": "fleet_throughput",
            "fast": bool(fast),
            "configs": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return results


if __name__ == "__main__":
    import sys

    from benchmarks.cli import Gate, bench_main

    sys.exit(
        bench_main(
            run,
            benchmark="fleet_throughput",
            gates=(
                Gate("speedup", higher_better=True, tol=0.50, abs_floor=0.5),
                # enabled-telemetry wall cost must stay near the disabled
                # path's (ratio ~1.0); generous bounds absorb CI noise
                Gate("telemetry_overhead", tol=0.30, abs_floor=0.25),
                # --devices rows: agents-per-device scaling must not rot.
                # The baseline is generated on a 1-core host (virtual
                # devices share it, speedup ~1x), so the generous bound
                # only catches sharding-path slowdowns; real multi-core
                # runners land well above it.
                Gate("mesh_speedup", higher_better=True, tol=0.50, abs_floor=0.4),
            ),
        )
    )
