"""Fig 4 reproduction: addition-of-agents ablation.

4 -> 8 -> 12 -> 16 agents under 75% communication dropout, evaluated on
the task suite at every churn boundary.  The churn is a declarative
schedule inside the ``churn_addition_fig4`` scenario (timed
``ChurnEvent`` additions on the asynchronous scheduler), so this module
only runs the scenario and prints its evaluation curve.  Expected
qualitative result: average error decreases phase over phase, and newly
added agents catch up via the hub database.

    PYTHONPATH=src python -m benchmarks.ablation_addition [--fast] \\
        [--seed N] [--json OUT] [--check BASELINE]

One ``phaseN`` row per evaluation-curve point; ``--check`` gates each
phase's ``mean_err``.
"""

from __future__ import annotations

import json

from repro import experiments

SCENARIO = "churn_addition_fig4"


def run(seed: int = 0, fast: bool = False, json_path=None):
    report = experiments.run(SCENARIO, fast=fast, seed=seed)
    results = {}
    for i, p in enumerate(report.eval_curve):
        results[f"phase{i + 1}"] = {
            "t": p.t,
            "n_agents": p.n_agents,
            "mean_err": p.mean_err,
        }
        print(
            f"phase {i + 1}: t={p.t:.2f} agents={p.n_agents} "
            f"avg_err={p.mean_err:.2f}"
        )
    errs = [p.mean_err for p in report.eval_curve]
    print("derived,errors_per_phase=" + ";".join(f"{e:.2f}" for e in errs))
    if json_path:
        payload = {
            "benchmark": "ablation_addition",
            "seed": seed,
            "fast": bool(fast),
            "configs": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return results


if __name__ == "__main__":
    import sys

    from benchmarks.cli import Gate, bench_main

    sys.exit(
        bench_main(
            run,
            benchmark="ablation_addition",
            seed=True,
            gates=(Gate("mean_err", tol=0.35, abs_floor=1.0),),
        )
    )
