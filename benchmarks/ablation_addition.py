"""Fig 4 reproduction: addition-of-agents ablation.

4 -> 8 -> 12 -> 16 agents under 75% communication dropout, evaluated on
the task suite at every churn boundary.  The churn is a declarative
schedule inside the ``churn_addition_fig4`` scenario (timed
``ChurnEvent`` additions on the asynchronous scheduler), so this module
only runs the scenario and prints its evaluation curve.  Expected
qualitative result: average error decreases phase over phase, and newly
added agents catch up via the hub database.
"""

from __future__ import annotations

from repro import experiments

SCENARIO = "churn_addition_fig4"


def run(seed: int = 0, fast: bool = False):
    report = experiments.run(SCENARIO, fast=fast, seed=seed)
    for i, p in enumerate(report.eval_curve):
        print(
            f"phase {i + 1}: t={p.t:.2f} agents={p.n_agents} "
            f"avg_err={p.mean_err:.2f}"
        )
    errs = [p.mean_err for p in report.eval_curve]
    print("derived,errors_per_phase=" + ";".join(f"{e:.2f}" for e in errs))
    return errs


if __name__ == "__main__":
    run()
