"""Table 1 + Fig 3 reproduction: the 4-agent / 3-hub deployment experiment.

Columns: Agent X (all-knowing, 1 round), Agent Y (partially-knowing,
1 round), Agent M (sequential lifelong, 8 rounds), Agents 1-4 (ADFLL,
3 rounds, asynchronous, heterogeneous speeds). Metric: mean terminal
Euclidean distance (voxels, synthetic volumes) on held-out patients over
the 8 task-environments; paired t-tests as in the paper.

Every system is constructed through the declarative scenario registry
(``repro.experiments``): the ADFLL deployment is ``paper_fig2`` and the
Table-1 baseline rows are the ``baseline_*`` scenarios, so this module
is scenario selection + reporting only.

Validation target (DESIGN.md §6): the *orderings* —
best-ADFLL <= AgentX < AgentM << AgentY — and significance vs Agent Y.

    PYTHONPATH=src python -m benchmarks.deployment [--fast] [--seed N] \\
        [--json OUT] [--check BASELINE]

One row per table column (``AgentX`` ... ``Agent4``); ``--check`` gates
each column's ``mean_dist_err``.
"""

from __future__ import annotations

import json

import numpy as np

from repro import experiments
from repro.sweeps.stats import paired_ttest

# label -> (registered scenario, seed offset kept from the classic script)
BASELINES = {
    "AgentX": ("baseline_all_knowing", 100),
    "AgentY": ("baseline_partial", 200),
    "AgentM": ("baseline_sequential", 300),
}


def run(seed: int = 0, fast: bool = False, json_path=None):
    adfll = experiments.run("paper_fig2", fast=fast, seed=seed)

    table = {}
    for scenario, offset in BASELINES.values():
        report = experiments.run(scenario, fast=fast, seed=seed + offset)
        table.update(report.task_errors)
    table.update(adfll.task_errors)  # Agent1..Agent4

    # ---- print Table 1 ----
    names = [*BASELINES, *sorted(adfll.task_errors)]
    task_names = list(next(iter(table.values())))
    print("task," + ",".join(names))
    for t in task_names:
        print(t + "," + ",".join(f"{table[n][t]:.2f}" for n in names))
    means = {n: float(np.mean(list(table[n].values()))) for n in names}
    print("mean," + ",".join(f"{means[n]:.2f}" for n in names))

    per_task = {n: [table[n][t] for t in task_names] for n in names}
    best_adfll = min(adfll.task_errors, key=lambda n: means[n])
    for ref in ("AgentX", "AgentM", "AgentY"):
        t_stat, p = paired_ttest(per_task[ref], per_task[best_adfll])
        print(f"ttest,{best_adfll}_vs_{ref},t={t_stat:.2f},p={p:.3f}")
    print(
        f"derived,makespan_sim={adfll.makespan:.2f},"
        f"rounds={adfll.n_rounds},"
        f"erbs_in_system={adfll.records_known.get('erb', 0)}"
    )
    results = {n: {"mean_dist_err": means[n]} for n in names}
    if json_path:
        payload = {
            "benchmark": "deployment",
            "seed": seed,
            "fast": bool(fast),
            "configs": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return results


if __name__ == "__main__":
    import sys

    from benchmarks.cli import Gate, bench_main

    sys.exit(
        bench_main(
            run,
            benchmark="deployment",
            seed=True,
            gates=(Gate("mean_dist_err", tol=0.35, abs_floor=1.0),),
        )
    )
