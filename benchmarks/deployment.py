"""Table 1 + Fig 3 reproduction: the 4-agent / 3-hub deployment experiment.

Columns: Agent X (all-knowing, 1 round), Agent Y (partially-knowing,
1 round), Agent M (sequential lifelong, 8 rounds), Agents 1-4 (ADFLL,
3 rounds, asynchronous, heterogeneous speeds). Metric: mean terminal
Euclidean distance (voxels, synthetic volumes) on held-out patients over
the 8 task-environments; paired t-tests as in the paper.

Validation target (DESIGN.md §6): the *orderings* —
best-ADFLL <= AgentX < AgentM << AgentY — and significance vs Agent Y.
"""
from __future__ import annotations

import numpy as np

from benchmarks.stats import paired_ttest
from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.core.federated import (
    ADFLLSystem,
    evaluate_on_tasks,
    train_all_knowing,
    train_partial,
    train_sequential_ll,
)
from repro.rl.synth import paper_eight_tasks, patient_split

DQN = DQNConfig(volume_shape=(20, 20, 20), box_size=(8, 8, 8),
                conv_features=(4, 8), hidden=(64,), max_episode_steps=24,
                batch_size=32, eps_decay_steps=300, target_update=40)
SYS = ADFLLConfig(rounds=3, train_steps_per_round=80, erb_capacity=2048,
                  erb_share_size=256, hub_sync_period=0.2)


def run(seed: int = 0, fast: bool = False):
    tasks = paper_eight_tasks()
    train_p, test_p = patient_split(40)
    steps = 20 if fast else SYS.train_steps_per_round
    sys_cfg = ADFLLConfig(rounds=SYS.rounds, train_steps_per_round=steps,
                          erb_capacity=SYS.erb_capacity,
                          erb_share_size=SYS.erb_share_size,
                          hub_sync_period=SYS.hub_sync_period)

    sysm = ADFLLSystem(sys_cfg, DQN, tasks, train_p, seed=seed)
    makespan = sysm.run()

    agent_x = train_all_knowing(DQN, tasks, train_p,
                                steps_per_task=steps, seed=seed + 100)
    agent_y = train_partial(DQN, tasks[0], train_p, steps=steps,
                            seed=seed + 200)
    agent_m = train_sequential_ll(DQN, tasks, train_p,
                                  steps_per_round=steps, seed=seed + 300)

    cols = {"AgentX": agent_x, "AgentY": agent_y, "AgentM": agent_m}
    for aid, ag in sorted(sysm.agents.items()):
        cols[f"Agent{aid + 1}"] = ag

    table = {}
    for name, ag in cols.items():
        table[name] = evaluate_on_tasks(ag, tasks, test_p, DQN)

    # ---- print Table 1 ----
    names = list(cols)
    print("task," + ",".join(names))
    for t in tasks:
        print(t.name + "," + ",".join(f"{table[n][t.name]:.2f}"
                                      for n in names))
    means = {n: float(np.mean(list(table[n].values()))) for n in names}
    print("mean," + ",".join(f"{means[n]:.2f}" for n in names))

    per_task = {n: [table[n][t.name] for t in tasks] for n in names}
    best_adfll = min((n for n in names if n.startswith("Agent") and
                      n[-1].isdigit()), key=lambda n: means[n])
    for ref in ("AgentX", "AgentM", "AgentY"):
        t_stat, p = paired_ttest(per_task[ref], per_task[best_adfll])
        print(f"ttest,{best_adfll}_vs_{ref},t={t_stat:.2f},p={p:.3f}")
    print(f"derived,makespan_sim={makespan:.2f},"
          f"rounds={len(sysm.history)},"
          f"erbs_in_system={len(sysm.network.all_known('erb'))}")
    return means, best_adfll


if __name__ == "__main__":
    run()
