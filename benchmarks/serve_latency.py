"""Online inference plane: continuous-batching serving latency/throughput.

Two rows over identical synthetic traffic (same seeds, same volumes,
same fleet), both spanning a mid-session train+publish hot swap:

* ``single``  — ``max_batch=1``: one request in flight at a time, the
  unbatched reference the hot-swap consistency tests compare against.
* ``batched`` — ``max_batch=8``: continuous batching over the pow2
  bucket ladder; new requests join mid-flight, finished ones retire
  without recompiling.

Reported per row: requests/sec, p50/p99 latency, ticks per request,
hot-swap count, recompiles after warmup (must be 0 — the acceptance
trace counter), and served accuracy.  The ``batched`` row adds
``batch_speedup`` (batched / single requests-per-sec) — the CI-gated
ratio alongside throughput, machine-speed independent like the fleet
benchmark's ``speedup``.

``open_{0.5,1,1.5}x`` rows sweep *open-loop* arrival rates (requests
spaced on the wall clock at a fraction of the measured closed-loop
capacity): the latency-under-load curve — flat queue-free latency below
saturation, backlog growth above it:

    PYTHONPATH=src python -m benchmarks.serve_latency [--fast] [--seed N] \
        [--json OUT] [--check benchmarks/baselines/BENCH_serve.json]

Gated in CI against ``benchmarks/baselines/BENCH_serve.json`` on
``requests_per_sec`` (higher better) and ``p99_latency_ms``.
"""

from __future__ import annotations

import json

import repro.core  # noqa: F401  (resolve the core<->rl import cycle first)
from repro.configs.adfll_dqn import DQNConfig
from repro.serve import TrafficSpec, build_session, run_session
from repro.telemetry import Telemetry, write_trace

CFG = DQNConfig(
    volume_shape=(16, 16, 16),
    box_size=(6, 6, 6),
    conv_features=(4,),
    hidden=(32,),
    max_episode_steps=16,
    batch_size=16,
    eps_decay_steps=100,
)

ROW_KEYS = (
    "n_requests",
    "requests_per_sec",
    "p50_latency_ms",
    "p99_latency_ms",
    "ticks_per_request",
    "n_swaps",
    "recompiles",
    "mean_dist_err",
)


def _serve_row(
    max_batch: int,
    seed: int,
    fast: bool,
    telemetry: Telemetry | None = None,
    rate: float | None = None,
) -> dict:
    traffic = TrafficSpec(
        n_requests=24 if fast else 96,
        max_batch=max_batch,
        n_version_slots=2,
        max_staleness=1,
        rate=rate,
        seed=seed,
    )
    session = build_session(
        CFG, n_agents=2, traffic=traffic, seed=seed, telemetry=telemetry
    )
    report = run_session(
        session, traffic, n_waves=2, train_steps=10 if fast else 30
    )
    s = report.summary()
    return {k: s[k] for k in ROW_KEYS}


def run(seed: int = 0, fast: bool = False, json_path=None, trace_path=None):
    results = {}
    telemetry = Telemetry(enabled=True) if trace_path else None
    print("config,req_per_sec,p50_ms,p99_ms,ticks_per_req,swaps,recompiles")
    for name, max_batch in (("single", 1), ("batched", 8)):
        # trace only the batched row: the single row is the latency
        # reference and should not carry even enabled-telemetry noise
        row = _serve_row(
            max_batch, seed, fast, telemetry if max_batch > 1 else None
        )
        results[name] = row
        print(
            f"{name},{row['requests_per_sec']:.1f},{row['p50_latency_ms']:.2f},"
            f"{row['p99_latency_ms']:.2f},{row['ticks_per_request']:.1f},"
            f"{row['n_swaps']},{row['recompiles']}"
        )
    results["batched"]["batch_speedup"] = (
        results["batched"]["requests_per_sec"]
        / results["single"]["requests_per_sec"]
    )
    print(f"derived,batch_speedup={results['batched']['batch_speedup']:.2f}")
    # open-loop arrival-rate sweep: requests spaced on the wall clock at
    # a fraction of the *measured* closed-loop capacity, so the offered
    # load (and the shape of the latency-under-load curve) adapts to the
    # machine instead of hard-coding req/s. Sub-saturation rows show
    # queue-free latency; the 1.5x row shows saturation backlog growth.
    capacity = results["batched"]["requests_per_sec"]
    for frac in (0.5, 1.0, 1.5):
        rate = max(1.0, capacity * frac)
        row = _serve_row(8, seed, fast, None, rate=rate)
        row["offered_rate"] = rate
        row["offered_frac"] = frac
        name = f"open_{frac:g}x"
        results[name] = row
        print(
            f"{name},{row['requests_per_sec']:.1f},{row['p50_latency_ms']:.2f},"
            f"{row['p99_latency_ms']:.2f},{row['ticks_per_request']:.1f},"
            f"{row['n_swaps']},{row['recompiles']}"
        )
    # telemetry+observatory overhead on the batched row: rerun it with an
    # enabled bundle (build_session auto-attaches the observatory) and
    # compare requests/sec.  >1.0 means the observed run was slower; the
    # CI gate bounds the ratio (enabled observability must stay cheap).
    row_on = _serve_row(8, seed, fast, Telemetry(enabled=True))
    overhead = (
        results["batched"]["requests_per_sec"] / row_on["requests_per_sec"]
    )
    results["telemetry"] = {
        "requests_per_sec_observed": row_on["requests_per_sec"],
        "telemetry_overhead": overhead,
    }
    print(f"derived,telemetry_overhead={overhead:.3f}")
    if trace_path:
        write_trace(telemetry, trace_path)
        print(f"wrote trace {trace_path}")
    if json_path:
        payload = {
            "benchmark": "serve_latency",
            "seed": seed,
            "fast": bool(fast),
            "configs": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return results


if __name__ == "__main__":
    import sys

    from benchmarks.cli import Gate, bench_main

    sys.exit(
        bench_main(
            run,
            benchmark="serve_latency",
            seed=True,
            gates=(
                # generous bounds: CI machines vary widely in speed
                Gate("requests_per_sec", higher_better=True, tol=0.60, abs_floor=5.0),
                Gate("p99_latency_ms", tol=1.50, abs_floor=20.0),
                # enabled telemetry+observatory must stay cheap on the
                # serve path (ratio vs the plain batched row, baseline 1.0)
                Gate("telemetry_overhead", tol=0.30, abs_floor=0.25),
            ),
        )
    )
