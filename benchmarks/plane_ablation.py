"""Sharing-plane ablation: ERB-only vs weight-only vs hybrid federation.

The paper federates experience (ERBs) only; the weight plane adds
FedAsync-style staleness-weighted parameter mixing over the same hub
topology.  Each row is a registered scenario (``plane_erb_only`` /
``plane_weight_only`` / ``plane_hybrid``) — identical tasks, seeds,
topology, and heterogeneous agent speeds — and the report carries, per
configuration:

* mean terminal distance error over the task suite (mean across agents
  and across each agent's per-task mean, on held-out patients),
* simulated makespan (event-driven scheduler time),
* transport volume per plane (records pushed, peer snapshots mixed,
  foreign ERBs consumed).

    PYTHONPATH=src python -m benchmarks.plane_ablation [--fast] [--json OUT]

Sized to finish in well under 5 minutes on CPU.
"""

from __future__ import annotations

import json

from repro import experiments

# classic row name -> registered scenario
PLANE_SCENARIOS = {
    "erb_only": "plane_erb_only",
    "weight_only": "plane_weight_only",
    "hybrid": "plane_hybrid",
}


ROW_KEYS = (
    "mean_dist_err",
    "best_agent_err",
    "sim_makespan",
    "n_mixed",
    "n_foreign_erbs",
    "pushed",
)


def summary_row(report, keys=ROW_KEYS):
    """One benchmark row: the named subset of ``Report.summary()``
    (shared with gossip_ablation so the BENCH_*.json shapes can't
    drift apart)."""
    summary = report.summary()
    return {k: summary[k] for k in keys}


def run(seed: int = 0, fast: bool = False, json_path=None, trace_path=None,
        dashboard_path=None):
    from benchmarks.cli import per_config_path

    results = {}
    print("config,mean_dist_err,best_agent_err,sim_makespan,n_mixed,n_foreign_erbs")
    for name, scenario in PLANE_SCENARIOS.items():
        r = summary_row(
            experiments.run(
                scenario,
                fast=fast,
                seed=seed,
                trace_path=per_config_path(trace_path, name),
                dashboard_path=per_config_path(dashboard_path, name),
            )
        )
        results[name] = r
        print(
            f"{name},{r['mean_dist_err']:.3f},{r['best_agent_err']:.3f},"
            f"{r['sim_makespan']:.2f},{r['n_mixed']},{r['n_foreign_erbs']}"
        )
    for name, r in results.items():
        print(f"derived,{name},pushed={r['pushed']}")
    if json_path:
        payload = {
            "benchmark": "plane_ablation",
            "seed": seed,
            "fast": bool(fast),
            "configs": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return results


if __name__ == "__main__":
    import sys

    from benchmarks.cli import Gate, bench_main

    sys.exit(
        bench_main(
            run,
            benchmark="plane_ablation",
            seed=True,
            gates=(Gate("mean_dist_err"),),
        )
    )
