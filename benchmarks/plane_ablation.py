"""Sharing-plane ablation: ERB-only vs weight-only vs hybrid federation.

The paper federates experience (ERBs) only; the weight plane adds
FedAsync-style staleness-weighted parameter mixing over the same hub
topology.  This ablation runs the deployment system once per plane
configuration — identical tasks, seeds, topology, and heterogeneous
agent speeds — and reports, per configuration:

* mean terminal distance error over the task suite (mean across agents
  and across each agent's per-task mean, on held-out patients),
* simulated makespan (event-driven scheduler time),
* transport volume per plane (records pushed, peer snapshots mixed,
  foreign ERBs consumed).

    PYTHONPATH=src python -m benchmarks.plane_ablation [--fast] [--json OUT]

Sized to finish in well under 5 minutes on CPU.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.core.federated import ADFLLSystem, evaluate_on_tasks
from repro.rl.synth import paper_eight_tasks, patient_split

DQN = DQNConfig(volume_shape=(16, 16, 16), box_size=(6, 6, 6),
                conv_features=(4,), hidden=(32,), max_episode_steps=12,
                batch_size=16, eps_decay_steps=100)

PLANE_CONFIGS = {
    "erb_only": ("erb",),
    "weight_only": ("weights",),
    "hybrid": ("erb", "weights"),
}


def run_one(planes, tasks, train_p, test_p, *, rounds, steps,
            seed: int = 0):
    sys_cfg = ADFLLConfig(rounds=rounds, train_steps_per_round=steps,
                          erb_capacity=512, erb_share_size=64,
                          hub_sync_period=0.25, share_planes=planes,
                          mix_alpha=0.6, staleness_flag="poly",
                          staleness_poly_a=0.5, seed=seed)
    sysm = ADFLLSystem(sys_cfg, DQN, tasks, train_p, seed=seed)
    makespan = sysm.run()
    per_agent = [float(np.mean(list(
        evaluate_on_tasks(ag, tasks, test_p, DQN).values())))
        for _, ag in sorted(sysm.agents.items())]
    return {
        "mean_dist_err": float(np.mean(per_agent)),
        "best_agent_err": float(np.min(per_agent)),
        "sim_makespan": float(makespan),
        "n_mixed": sum(r.n_mixed for r in sysm.history),
        "n_foreign_erbs": sum(r.n_incoming for r in sysm.history),
        "pushed": dict(sysm.network.plane_pushed),
    }


def run(seed: int = 0, fast: bool = False, json_path=None):
    tasks = paper_eight_tasks()[:4]
    train_p, test_p = patient_split(16)
    rounds = 2
    steps = 10 if fast else 30

    results = {}
    print("config,mean_dist_err,best_agent_err,sim_makespan,"
          "n_mixed,n_foreign_erbs")
    for name, planes in PLANE_CONFIGS.items():
        r = run_one(planes, tasks, train_p, test_p, rounds=rounds,
                    steps=steps, seed=seed)
        results[name] = r
        print(f"{name},{r['mean_dist_err']:.3f},{r['best_agent_err']:.3f},"
              f"{r['sim_makespan']:.2f},{r['n_mixed']},"
              f"{r['n_foreign_erbs']}")
    for name, r in results.items():
        print(f"derived,{name},pushed={r['pushed']}")
    if json_path:
        payload = {"benchmark": "plane_ablation", "seed": seed,
                   "fast": bool(fast), "configs": results}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced step counts (CI sanity)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", type=str, default=None, metavar="OUT",
                    help="write results as JSON (BENCH_*.json for CI gating)")
    args = ap.parse_args()
    run(seed=args.seed, fast=args.fast, json_path=args.json)
