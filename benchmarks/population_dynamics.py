"""Population dynamics: the trace-driven fleet scenarios end to end.

One row per registry population scenario — ``hospital_diurnal`` (two
sites on opposite day/night shifts, availability-aware gossip),
``flash_crowd`` (hundreds of agents joining over a staggered mid-run
wave), ``long_tail_stragglers`` (lognormal step-time tail plus
heavy-tailed connectivity sessions).  Reported per row: mean distance
error under churn, simulated makespan, rounds, fleet availability (the
fraction of agent-time spent online), availability-weighted rounds/sec
(rounds per unit of *online* agent-time — pacing that does not reward
simply keeping agents offline), and the availability-timeline digest
(bit-reproducibility at a glance):

    PYTHONPATH=src python -m benchmarks.population_dynamics [--fast] \\
        [--seed N] [--json OUT] \\
        [--check benchmarks/baselines/BENCH_population.json]

Gated in CI against ``benchmarks/baselines/BENCH_population.json`` on
``mean_dist_err`` and ``makespan``.
"""

from __future__ import annotations

import json

import repro.core  # noqa: F401  (resolve the core<->rl import cycle first)
from repro import experiments

SCENARIOS = ("hospital_diurnal", "flash_crowd", "long_tail_stragglers")


def _row(
    name: str, seed: int, fast: bool, trace_path=None, dashboard_path=None
) -> dict:
    report = experiments.run(
        name,
        fast=fast,
        seed=seed,
        trace_path=trace_path,
        dashboard_path=dashboard_path,
    )
    pop = report.extra["population"]
    online_time = float(pop["online_time"])
    return {
        "mean_dist_err": report.mean_dist_err,
        "makespan": report.makespan,
        "n_rounds": report.n_rounds,
        "n_agents": pop["n_agents"],
        "n_departed": pop["n_departed"],
        "n_toggles": pop["n_toggles"],
        "availability": pop["availability"],
        "aw_rounds_per_time": (
            report.n_rounds / online_time if online_time > 0 else 0.0
        ),
        "timeline_digest": pop["timeline_digest"],
    }


def run(seed: int = 0, fast: bool = False, json_path=None, trace_path=None,
        dashboard_path=None):
    from benchmarks.cli import per_config_path

    results = {}
    print("config,mean_dist_err,makespan,rounds,agents,avail,aw_rounds_per_time")
    for name in SCENARIOS:
        row = _row(
            name,
            seed,
            fast,
            trace_path=per_config_path(trace_path, name),
            dashboard_path=per_config_path(dashboard_path, name),
        )
        results[name] = row
        print(
            f"{name},{row['mean_dist_err']:.3f},{row['makespan']:.2f},"
            f"{row['n_rounds']},{row['n_agents']},{row['availability']:.3f},"
            f"{row['aw_rounds_per_time']:.3f}"
        )
    if json_path:
        payload = {
            "benchmark": "population_dynamics",
            "seed": seed,
            "fast": bool(fast),
            "configs": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return results


if __name__ == "__main__":
    import sys

    from benchmarks.cli import Gate, bench_main

    sys.exit(
        bench_main(
            run,
            benchmark="population_dynamics",
            seed=True,
            gates=(
                Gate("mean_dist_err", tol=0.35, abs_floor=1.0),
                # simulated time: deterministic given the seed, so a tight
                # relative bound catches scheduling regressions
                Gate("makespan", tol=0.15, abs_floor=0.5),
            ),
        )
    )
