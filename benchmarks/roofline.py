"""Roofline table: read the dry-run JSONs and print per (arch x shape x
mesh) the three terms + bottleneck (EXPERIMENTS.md §Roofline source)."""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_all(dirpath=DRYRUN_DIR):
    out = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def run():
    rows = load_all()
    if not rows:
        print("roofline,-,no dry-run results (run repro.launch.dryrun --all)")
        return
    hdr = (
        "arch,shape,mesh,compute_s,memory_s,collective_s,bottleneck,"
        "useful_flops_ratio,peak_GB_per_dev"
    )
    print(hdr)
    for r in rows:
        tag = "2x16x16" if r.get("multi_pod") else "16x16"
        if r.get("status") == "skipped":
            print(f"{r['arch']},{r['shape']},{tag},-,-,-,SKIP({r['reason']}),-,-")
            continue
        if r.get("status") != "ok":
            print(f"{r['arch']},{r['shape']},{tag},-,-,-,ERROR,-,-")
            continue
        t = r["roofline"]
        peak = r["memory_analysis"]["peak_bytes"] / 1e9
        ratio = r.get("useful_flops_ratio", 0) or 0
        print(
            f"{r['arch']},{r['shape']},{tag},"
            f"{t['compute_s']:.4g},{t['memory_s']:.4g},"
            f"{t['collective_s']:.4g},{t['bottleneck'][:-2]},"
            f"{ratio:.3f},{peak:.2f}"
        )


if __name__ == "__main__":
    run()
