"""Shared benchmark CLI plumbing.

Every benchmark main had grown the same argparse block — ``--fast``,
``--seed``, ``--json OUT`` — each with its own drift (some missing
``--seed``, none wired to the regression gate).  :func:`bench_main` is
that shape once: parse the standard flags, call the benchmark's
``run()``, and — with ``--check BASELINE`` — gate the fresh results
against a checked-in ``benchmarks/baselines/BENCH_*.json`` using the
benchmark's own declared :class:`Gate` rows (the same ``compare`` the
standalone ``check_regression`` entrypoint uses, so CI can do either).

    PYTHONPATH=src python -m benchmarks.<name> [--fast] [--seed N] \
        [--json OUT | --out OUT] [--check benchmarks/baselines/BENCH_<x>.json]
"""

from __future__ import annotations

import argparse
import inspect
import json
import sys
from collections.abc import Callable, Sequence
from dataclasses import dataclass

from benchmarks.check_regression import compare


@dataclass(frozen=True)
class Gate:
    """One CI-gated metric of a benchmark's ``configs`` rows."""

    metric: str
    higher_better: bool = False
    tol: float = 0.20  # max relative regression
    abs_floor: float = 0.75  # smaller absolute deltas never fail


def build_parser(
    prog: str | None = None, *, seed: bool = False
) -> argparse.ArgumentParser:
    """The standard benchmark flag set (callers may add their own)."""
    ap = argparse.ArgumentParser(prog=prog)
    ap.add_argument(
        "--fast", action="store_true", help="reduced sizes/steps (CI sanity)"
    )
    if seed:
        ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--json",
        "--out",
        dest="json",
        type=str,
        default=None,
        metavar="OUT",
        help="write results as JSON (BENCH_*.json for CI gating)",
    )
    ap.add_argument(
        "--check",
        type=str,
        default=None,
        metavar="BASELINE",
        help="gate the fresh results against a checked-in BENCH_*.json "
        "using the benchmark's declared metrics",
    )
    ap.add_argument(
        "--devices",
        type=int,
        default=0,
        metavar="N",
        help="shard the benchmark's fleet axis across a device mesh of "
        "up to N local devices (-1 = all; 0 = single-device); ignored by "
        "benchmarks without a mesh mode. On CPU combine with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=N",
    )
    ap.add_argument(
        "--trace",
        type=str,
        default=None,
        metavar="PATH",
        help="capture a telemetry trace of the benchmark run (Perfetto "
        "JSON; .jsonl for the flat format); ignored by benchmarks that "
        "do not support tracing",
    )
    ap.add_argument(
        "--dashboard",
        type=str,
        default=None,
        metavar="PATH",
        help="render the run's observatory dashboard (self-contained "
        "HTML); ignored by benchmarks that do not support it",
    )
    return ap


def per_config_path(path: str | None, name: str) -> str | None:
    """``out.jsonl`` + ``hub`` -> ``out.hub.jsonl`` — one artifact per
    benchmark row (mirrors the experiments CLI's multi-scenario rule)."""
    if path is None:
        return None
    stem, dot, ext = path.rpartition(".")
    return f"{stem}.{name}.{ext}" if dot else f"{path}.{name}"


def check_gates(
    baseline_path: str, current: dict, gates: Sequence[Gate]
) -> int:
    """Run every declared gate; returns a process exit code."""
    if not gates:
        print("--check given but this benchmark declares no gates", file=sys.stderr)
        return 1
    with open(baseline_path) as f:
        baseline = json.load(f)
    failures = []
    for g in gates:
        failures += compare(
            baseline,
            current,
            tol=g.tol,
            abs_floor=g.abs_floor,
            metric=g.metric,
            higher_better=g.higher_better,
        )
    for msg in failures:
        print(f"REGRESSION {msg}", file=sys.stderr)
    return 1 if failures else 0


def bench_main(
    run: Callable[..., dict],
    *,
    benchmark: str,
    seed: bool = False,
    gates: Sequence[Gate] = (),
    argv: Sequence[str] | None = None,
) -> int:
    """The whole benchmark ``__main__``: flags -> run() -> gate.

    ``run`` is the benchmark's existing entrypoint; it receives
    ``fast``/``json_path`` (and ``seed`` when enabled) and returns the
    ``configs`` dict its JSON payload carries.
    """
    args = build_parser(f"python -m benchmarks.{benchmark}", seed=seed).parse_args(
        argv
    )
    kwargs = dict(fast=args.fast, json_path=args.json)
    if seed:
        kwargs["seed"] = args.seed
    params = inspect.signature(run).parameters
    if "devices" in params:
        kwargs["devices"] = args.devices
    elif args.devices:
        print(f"--devices ignored: {benchmark} has no mesh mode")
    if "trace_path" in params:
        kwargs["trace_path"] = args.trace
    elif args.trace:
        print(f"--trace ignored: {benchmark} does not capture traces")
    if "dashboard_path" in params:
        kwargs["dashboard_path"] = args.dashboard
    elif args.dashboard:
        print(f"--dashboard ignored: {benchmark} does not render dashboards")
    results = run(**kwargs)
    if args.check:
        current = {
            "benchmark": benchmark,
            "fast": bool(args.fast),
            "configs": results,
        }
        return check_gates(args.check, current, gates)
    return 0


__all__ = ["Gate", "bench_main", "build_parser", "check_gates", "per_config_path"]
