"""Kernel microbenchmarks: Pallas (interpret) vs pure-jnp oracle.

On CPU the interpret-mode timings are NOT TPU performance — the value here
is (a) correctness at benchmark shapes and (b) the harness a TPU run would
use unchanged (interpret=False).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=3):
    out = fn(*args)  # warmup / compile
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def _row(name, us, ref_us, err):
    return (name, us, f"ref_us={ref_us:.0f};max_err={err:.2e}")


def run():
    rng = np.random.default_rng(0)
    rows = []

    # flash attention (modest shape; interpret mode is a python loop)
    b, s, hq, hkv, d = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref

    t_pl = _time(
        lambda a, b_, c: flash_attention(a, b_, c, block_q=128, block_k=128), q, k, v
    )
    t_ref = _time(jax.jit(attention_ref), q, k, v)
    out_pl = flash_attention(q, k, v, block_q=128, block_k=128)
    err = float(jnp.max(jnp.abs(out_pl - attention_ref(q, k, v))))
    rows.append(_row("flash_attention_interp", t_pl, t_ref, err))

    # replay gather
    from repro.kernels.replay_gather.ops import replay_gather
    from repro.kernels.replay_gather.ref import replay_gather_ref

    buf = jnp.asarray(rng.standard_normal((4096, 512)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 4096, 256), jnp.int32)
    w = jnp.ones((256,), jnp.float32)
    t_pl = _time(replay_gather, buf, idx, w)
    t_ref = _time(jax.jit(replay_gather_ref), buf, idx, w)
    diff = replay_gather(buf, idx, w) - replay_gather_ref(buf, idx, w)
    err = float(jnp.max(jnp.abs(diff)))
    rows.append(_row("replay_gather_interp", t_pl, t_ref, err))

    # fused td
    from repro.kernels.fused_td.kernel import fused_td
    from repro.kernels.fused_td.ref import fused_td_ref

    qs = jnp.asarray(rng.standard_normal((1024, 1)), jnp.float32)
    qn = jnp.asarray(rng.standard_normal((1024, 6)), jnp.float32)
    r = jnp.asarray(rng.standard_normal((1024, 1)), jnp.float32)
    dn = jnp.zeros((1024, 1), jnp.float32)
    f_pl = jax.jit(lambda *a: fused_td(*a, gamma=0.9)[0])
    f_ref = jax.jit(lambda *a: fused_td_ref(*a, gamma=0.9)[0])
    t_pl = _time(f_pl, qs, qn, r, dn)
    t_ref = _time(f_ref, qs, qn, r, dn)
    err = float(jnp.max(jnp.abs(f_pl(qs, qn, r, dn) - f_ref(qs, qn, r, dn))))
    rows.append(_row("fused_td_interp", t_pl, t_ref, err))

    # fused rmsnorm
    from repro.kernels.rmsnorm.ops import rmsnorm
    from repro.kernels.rmsnorm.ref import rmsnorm_ref

    x = jnp.asarray(rng.standard_normal((2048, 768)), jnp.float32)
    sc = jnp.asarray(rng.standard_normal((768,)), jnp.float32)
    t_pl = _time(rmsnorm, x, sc)
    t_ref = _time(jax.jit(rmsnorm_ref), x, sc)
    err = float(jnp.max(jnp.abs(rmsnorm(x, sc) - rmsnorm_ref(x, sc))))
    rows.append(_row("rmsnorm_interp", t_pl, t_ref, err))
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    run()
