"""Beyond-paper ablation: quantify catastrophic forgetting directly.

The paper claims selective experience replay prevents catastrophic
forgetting but never measures forgetting itself. We do: train one agent
on task A, then on task B — once WITH personal-ERB replay (Agent-M style
lifelong) and once WITHOUT (plain fine-tuning) — and report the error
regression on task A.

    forgetting = err_A(after B) - err_A(after A)

    PYTHONPATH=src python -m benchmarks.forgetting [--fast] [--seed N] \\
        [--json OUT] [--check BASELINE]

Two rows (``no_replay`` / ``with_replay``), each averaging the drift
over ``seed`` and ``seed + 1``; ``--check`` gates ``forgetting``.
"""

from __future__ import annotations

import json

import numpy as np

from repro.configs.adfll_dqn import DQNConfig
from repro.core.erb import erb_init
from repro.core.federated import env_for
from repro.rl.agent import DQNAgent
from repro.rl.synth import paper_eight_tasks, patient_split

DQN = DQNConfig(
    volume_shape=(16, 16, 16),
    box_size=(6, 6, 6),
    conv_features=(4, 8),
    hidden=(48,),
    max_episode_steps=16,
    batch_size=24,
    eps_decay_steps=200,
)


def _train_task_chain(replay: bool, steps: int, seed: int = 0, n_tasks: int = 4):
    """Train sequentially over n_tasks; return task-0 error after task 0
    and after the final task (drift accumulates over the chain)."""
    tasks = paper_eight_tasks()[:n_tasks]
    train_p, test_p = patient_split(30)
    rng = np.random.default_rng(seed)
    agent = DQNAgent(0, DQN, seed=seed)
    eval_env_0 = env_for(tasks[0], int(test_p[0]), DQN)

    err_0_after_first = None
    for i, task in enumerate(tasks):
        env = env_for(task, int(rng.choice(train_p)), DQN)
        erb = erb_init(1024, DQN.box_size, task=task)
        agent.collect(env, erb, n_episodes=24)
        agent.train_steps(steps, erb)  # personal replay iff enabled
        if replay:
            agent.personal_erbs.append(erb)
        if i == 0:
            err_0_after_first = agent.evaluate(eval_env_0, n_episodes=16)
    err_0_final = agent.evaluate(eval_env_0, n_episodes=16)
    return err_0_after_first, err_0_final


def run(seed: int = 0, fast: bool = False, json_path=None):
    steps = 20 if fast else 80
    n_tasks = 2 if fast else 4
    seeds = (seed, seed + 1)
    results = {}
    for replay in (False, True):
        f = []
        for s in seeds:
            before, after = _train_task_chain(replay, steps, seed=s, n_tasks=n_tasks)
            f.append(after - before)
        tag = "with_replay" if replay else "no_replay"
        drift = float(np.mean(f))
        results[tag] = {"forgetting": drift}
        per_seed = [round(x, 2) for x in f]
        print(
            f"{tag}: task-0 error drift after {n_tasks}-task chain = "
            f"{drift:+.2f} (per-seed: {per_seed})"
        )
    print(
        f"derived,forgetting_no_replay={results['no_replay']['forgetting']:.2f},"
        f"forgetting_with_replay={results['with_replay']['forgetting']:.2f}"
    )
    if json_path:
        payload = {
            "benchmark": "forgetting",
            "seed": seed,
            "fast": bool(fast),
            "configs": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return results


if __name__ == "__main__":
    import sys

    from benchmarks.cli import Gate, bench_main

    sys.exit(
        bench_main(
            run,
            benchmark="forgetting",
            seed=True,
            gates=(Gate("forgetting", tol=0.50, abs_floor=1.0),),
        )
    )
