"""Fleet observatory: learning dynamics, knowledge propagation, health.

The PR-8 telemetry substrate observes *mechanics* — spans, bytes, flush
counts.  The observatory observes *what the fleet is learning and how
knowledge spreads*: per-agent loss / TD-error / grad-norm / max-|Q|
accumulated device-side inside the scan-fused fleet chunk, version
vectors and staleness distributions over the sharing planes, gossip
epidemic coverage, and NaN / divergence / straggler health detection.

One :class:`Observatory` bundles the three pillars and is attached by
the owning system (``ADFLLSystem`` auto-creates one whenever its
telemetry bundle is enabled; ``repro.serve`` sessions do the same):

* ``engine.observatory = obs`` switches the fleet engine onto the
  stats-carrying train chunk and routes the flush-boundary drain into
  :meth:`Observatory.on_flush`;
* the federated round path calls the ``propagation`` note-hooks and
  stamps version vectors onto outgoing records;
* ``GossipTopology.on_deliver`` feeds anti-entropy deliveries.

The contract matches telemetry's: **observe-only**.  No randomness is
consumed, no training numbers change, and the only device-side cost is
the stats pytree riding the existing flush (bit-identity with the
observatory disabled *and* enabled is asserted by the fingerprint
tests; cost is CI-gated in ``fleet_throughput``).
"""

from __future__ import annotations

from typing import Any

import numpy as np

from .health import HealthMonitor
from .learning import AgentDynamics, LearningDynamics
from .propagation import PropagationTracker


class Observatory:
    """The three pillars behind one facade (see module docstring)."""

    def __init__(self, telemetry, *, max_tracked: int = 4096):
        self.telemetry = telemetry
        self.learning = LearningDynamics(telemetry)
        self.propagation = PropagationTracker(telemetry, max_tracked=max_tracked)
        self.health = HealthMonitor(telemetry, self.learning)

    # -- fleet side ----------------------------------------------------------
    def register_slot(self, slot: int, agent_id: int) -> None:
        """Map an engine slot to its agent id for ``agent=`` labels."""
        self.learning.register_slot(slot, agent_id)

    def on_flush(
        self,
        slots: list[int],
        stats: dict[str, np.ndarray],
        n_real: int,
        sim_time: float,
    ) -> None:
        """FleetEngine drain point — called once per flush group with the
        stats pytree already on host (the flush's existing sync)."""
        self.learning.on_flush(slots, stats, n_real, sim_time)
        self.health.on_flush(slots, stats, n_real, sim_time)

    # -- report side ---------------------------------------------------------
    def report_extra(self, *, makespan: float) -> dict[str, Any]:
        """The observatory's contribution to ``Report.extra``."""
        return {
            "learning": self.learning.summary(),
            "propagation": self.propagation.summary(),
            "health": self.health.verdict(makespan=makespan),
        }


__all__ = [
    "AgentDynamics",
    "HealthMonitor",
    "LearningDynamics",
    "Observatory",
    "PropagationTracker",
]
