"""Fleet health detection: NaN/Inf params, loss divergence, stragglers.

Three detectors, all reading state the other observatory pillars
already collect (no extra device work):

* **Non-finite params** — the stats chunk's per-slot ``params_finite``
  flag, checked at every flush boundary.  Any hit is an ``alert``.
* **Loss divergence** — a per-agent chunk-mean loss that climbs past
  ``divergence_factor`` x its running minimum (after a warmup of
  ``min_samples`` chunks) is flagged once per agent, as a ``warn``.
* **Stragglers / stalls** — decided at report time against the run's
  makespan: an agent whose last training activity predates
  ``straggler_frac`` of the makespan stalled early, a ``warn``.

Each incident is also emitted as a telemetry instant on the ``health``
track (sim clock), so traces show *when* the fleet went bad.
"""

from __future__ import annotations

import math
from typing import Any

from .learning import LearningDynamics

STATUS_ORDER = {"ok": 0, "warn": 1, "alert": 2}


class HealthMonitor:
    """Incident collection + final verdict over the learning state."""

    def __init__(
        self,
        telemetry,
        learning: LearningDynamics,
        *,
        divergence_factor: float = 10.0,
        min_samples: int = 3,
        straggler_frac: float = 0.5,
        max_incidents: int = 256,
    ):
        self.telemetry = telemetry
        self.learning = learning
        self.divergence_factor = float(divergence_factor)
        self.min_samples = int(min_samples)
        self.straggler_frac = float(straggler_frac)
        self.max_incidents = int(max_incidents)
        self.incidents: list[dict[str, Any]] = []
        self.n_dropped_incidents = 0
        self._nonfinite_agents: set[int] = set()
        self._diverged_agents: set[int] = set()

    def _incident(self, kind: str, severity: str, sim_time: float, **detail) -> None:
        if len(self.incidents) >= self.max_incidents:
            self.n_dropped_incidents += 1
            return
        self.incidents.append(
            {"kind": kind, "severity": severity, "sim_time": float(sim_time), **detail}
        )
        self.telemetry.instant(f"health.{kind}", "health", sim_time, **detail)
        self.telemetry.count("health.incidents", 1, kind=kind)

    def on_flush(
        self, slots: list[int], stats: dict, n_real: int, sim_time: float
    ) -> None:
        """Flush-boundary detectors (after LearningDynamics.on_flush has
        folded the same drain, so running minima are current)."""
        finite = stats["params_finite"]
        loss = stats["loss"]
        for j, slot in enumerate(slots[:n_real]):
            agent_id = self.learning.slot_to_agent.get(slot, slot)
            if not bool(finite[j]) and agent_id not in self._nonfinite_agents:
                self._nonfinite_agents.add(agent_id)
                self._incident("nonfinite_params", "alert", sim_time, agent=agent_id)
            a = self.learning.agents.get(agent_id)
            if a is None or agent_id in self._diverged_agents:
                continue
            mean_loss = float(loss[:, j].mean())
            if not math.isfinite(mean_loss):
                if agent_id not in self._nonfinite_agents:
                    self._nonfinite_agents.add(agent_id)
                    self._incident("nonfinite_loss", "alert", sim_time, agent=agent_id)
                continue
            if (
                a.n_chunks >= self.min_samples
                and math.isfinite(a.min_loss)
                and a.min_loss > 0.0
                and mean_loss > self.divergence_factor * a.min_loss
            ):
                self._diverged_agents.add(agent_id)
                self._incident(
                    "loss_divergence",
                    "warn",
                    sim_time,
                    agent=agent_id,
                    loss=mean_loss,
                    min_loss=a.min_loss,
                )

    def verdict(self, *, makespan: float) -> dict[str, Any]:
        """The ``Report.extra["health"]`` document (straggler detection
        runs here — it needs the final makespan)."""
        stragglers: list[int] = []
        if makespan > 0.0:
            cutoff = self.straggler_frac * makespan
            for aid in sorted(self.learning.agents):
                a = self.learning.agents[aid]
                if a.n_chunks > 0 and a.last_sim_time < cutoff:
                    stragglers.append(aid)
                    self._incident(
                        "straggler",
                        "warn",
                        makespan,
                        agent=aid,
                        last_activity=a.last_sim_time,
                    )
        status = "ok"
        for inc in self.incidents:
            if STATUS_ORDER[inc["severity"]] > STATUS_ORDER[status]:
                status = inc["severity"]
        counts: dict[str, int] = {}
        for inc in self.incidents:
            counts[inc["kind"]] = counts.get(inc["kind"], 0) + 1
        return {
            "status": status,
            "incidents": list(self.incidents),
            "counts": counts,
            "stragglers": stragglers,
            "n_dropped_incidents": self.n_dropped_incidents,
        }


__all__ = ["HealthMonitor"]
