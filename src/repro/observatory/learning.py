"""Per-agent learning-dynamics metrics drained from fleet flushes.

The fleet engine's stats chunk (``FleetSteps.train_chunk_stats``)
accumulates per-step per-slot scalars *device-side* through the scan —
loss, mean |TD error|, max |Q|, gradient global-norm — plus a per-slot
params-finite flag, and the engine drains them at the existing flush
boundary (the same host sync that already carries the losses).  This
module turns that drain into registry series with ``agent=`` labels and
keeps the small per-agent histories the health detectors read.

Everything here is observational: it consumes no randomness and touches
no training state.
"""

from __future__ import annotations

import math
from typing import Any

import numpy as np


class AgentDynamics:
    """Rolling learning-dynamics state of one agent."""

    __slots__ = (
        "agent_id",
        "n_chunks",
        "n_steps",
        "last_loss",
        "min_loss",
        "max_grad_norm",
        "max_q",
        "last_sim_time",
        "nonfinite_flushes",
        "loss_curve",
    )

    def __init__(self, agent_id: int):
        self.agent_id = agent_id
        self.n_chunks = 0
        self.n_steps = 0
        self.last_loss = math.nan
        self.min_loss = math.inf
        self.max_grad_norm = 0.0
        self.max_q = 0.0
        self.last_sim_time = 0.0
        self.nonfinite_flushes = 0
        self.loss_curve: list[tuple[float, float]] = []  # (sim_time, mean loss)


class LearningDynamics:
    """Registry emission + per-agent history for the fleet stats drain.

    ``max_curve_points`` bounds the per-agent loss curve kept for the
    dashboard (the registry histograms are already bounded by series
    cardinality); past the cap every other point is dropped, preserving
    the curve's shape at half resolution.
    """

    def __init__(self, telemetry, *, max_curve_points: int = 512):
        self.telemetry = telemetry
        self.max_curve_points = int(max_curve_points)
        self.slot_to_agent: dict[int, int] = {}
        self.agents: dict[int, AgentDynamics] = {}

    def register_slot(self, slot: int, agent_id: int) -> None:
        self.slot_to_agent[slot] = agent_id

    def _agent(self, agent_id: int) -> AgentDynamics:
        a = self.agents.get(agent_id)
        if a is None:
            a = self.agents[agent_id] = AgentDynamics(agent_id)
        return a

    def on_flush(
        self,
        slots: list[int],
        stats: dict[str, np.ndarray],
        n_real: int,
        sim_time: float,
    ) -> None:
        """Fold one flush's drained stats ([K, N_pad] arrays) into the
        registry and the per-agent histories.  Only the first ``n_real``
        columns are real jobs (the rest are inert pow2 padding)."""
        tel = self.telemetry
        loss = stats["loss"]
        td = stats["td_abs"]
        qm = stats["q_max"]
        gn = stats["grad_norm"]
        finite = stats["params_finite"]
        for j, slot in enumerate(slots[:n_real]):
            agent_id = self.slot_to_agent.get(slot, slot)
            a = self._agent(agent_id)
            col = loss[:, j]
            mean_loss = float(col.mean())
            last_loss = float(col[-1])
            mean_td = float(td[:, j].mean())
            max_q = float(qm[:, j].max())
            mean_gn = float(gn[:, j].mean())
            label = str(agent_id)
            if math.isfinite(mean_loss):
                tel.observe("agent.loss", mean_loss, agent=label)
                # counter *event* too: the trace (and dashboard rendered
                # from it) gets the loss as a per-agent timeline
                tel.counter("agent.loss", f"agent{label}", sim_time, mean_loss)
            if math.isfinite(mean_td):
                tel.observe("agent.td_abs", mean_td, agent=label)
            if math.isfinite(mean_gn):
                tel.observe("agent.grad_norm", mean_gn, agent=label)
            tel.gauge("agent.loss.last", last_loss, agent=label)
            tel.gauge("agent.q_max", max_q, agent=label)
            tel.count("agent.steps_trained", int(col.shape[0]), agent=label)

            a.n_chunks += 1
            a.n_steps += int(col.shape[0])
            a.last_loss = last_loss
            if math.isfinite(mean_loss):
                a.min_loss = min(a.min_loss, mean_loss)
            a.max_grad_norm = max(a.max_grad_norm, float(gn[:, j].max()))
            a.max_q = max(a.max_q, max_q)
            a.last_sim_time = float(sim_time)
            if not bool(finite[j]):
                a.nonfinite_flushes += 1
            a.loss_curve.append((float(sim_time), mean_loss))
            if len(a.loss_curve) > self.max_curve_points:
                a.loss_curve = a.loss_curve[::2]

    def summary(self) -> dict[str, Any]:
        """Per-agent digest for ``Report.extra`` and the dashboard."""
        out: dict[str, Any] = {}
        for aid in sorted(self.agents):
            a = self.agents[aid]
            out[str(aid)] = {
                "n_chunks": a.n_chunks,
                "n_steps": a.n_steps,
                "last_loss": a.last_loss if math.isfinite(a.last_loss) else None,
                "min_loss": a.min_loss if math.isfinite(a.min_loss) else None,
                "max_grad_norm": a.max_grad_norm,
                "max_q": a.max_q,
                "last_sim_time": a.last_sim_time,
                "nonfinite_flushes": a.nonfinite_flushes,
                "loss_curve": [[t, v] for t, v in a.loss_curve],
            }
        return out


__all__ = ["AgentDynamics", "LearningDynamics"]
