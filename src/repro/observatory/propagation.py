"""Knowledge-propagation tracking: version vectors, staleness, coverage.

BrainTorrent-style bookkeeping over the two sharing planes:

* **Version vectors** — the tracker maintains every agent's last known
  round (updated on each push) and exposes the sorted
  ``(agent_id, round_idx)`` tuple the system stamps onto outgoing
  :class:`~repro.core.plane.WeightSnapshot` and
  :class:`~repro.core.erb.ERBMeta` records when the observatory is on.
* **Staleness / influence** — every ``mix_params`` records the staleness
  distribution of the folded snapshots (on the run's configured clock)
  and accumulates per-source mixing influence from the
  ``staleness_alphas`` the mix actually used.
* **Propagation latency** — ERB records are timed from creation (push)
  to first remote consumption on the *sim* clock; gossip deliveries are
  timed per record, yielding epidemic coverage curves (fraction of
  deliveries landed within t seconds of the record's birth).

All tables are bounded (``max_tracked`` records per kind); overflow is
counted, never fatal.  Purely observational — no randomness, no
training-state access.
"""

from __future__ import annotations

from typing import Any

import numpy as np


def _dist_summary(values: list[float]) -> dict[str, Any] | None:
    if not values:
        return None
    x = np.asarray(values, np.float64)
    return {
        "n": int(x.size),
        "mean": float(x.mean()),
        "p50": float(np.percentile(x, 50)),
        "p90": float(np.percentile(x, 90)),
        "max": float(x.max()),
    }


def _ecdf_points(values: list[float], max_points: int = 32) -> list[list[float]]:
    """Downsampled ECDF of latency samples: [[t, fraction <= t], ...]."""
    if not values:
        return []
    x = np.sort(np.asarray(values, np.float64))
    n = x.size
    take = min(max_points, n)
    pick = np.unique(np.linspace(0, n - 1, take).round().astype(int))
    return [[float(x[i]), float((i + 1) / n)] for i in pick]


class PropagationTracker:
    """One run's propagation bookkeeping (see module docstring)."""

    def __init__(self, telemetry, *, max_tracked: int = 4096):
        self.telemetry = telemetry
        self.max_tracked = int(max_tracked)
        self.n_dropped_tracked = 0
        #: agent_id -> last known round (the global version vector)
        self.progress: dict[int, int] = {}
        #: erb_id -> (source_agent, push sim_time)
        self._erb_born: dict[str, tuple[int, float]] = {}
        self._erb_consumed: set[str] = set()
        #: snap_id -> (source_agent, push sim_time)
        self._snap_born: dict[str, tuple[int, float]] = {}
        self.erb_latencies: list[float] = []
        self.staleness_samples: list[float] = []
        self.gossip_latencies: list[float] = []
        self.influence_by_source: dict[int, float] = {}
        self.n_erb_pushes = 0
        self.n_snap_pushes = 0
        self.n_mixes = 0
        self.n_mixed_snaps = 0
        self.n_gossip_deliveries = 0

    # -- version vector ------------------------------------------------------
    def note_round(self, agent_id: int, round_idx: int) -> None:
        prev = self.progress.get(agent_id, -1)
        if round_idx > prev:
            self.progress[agent_id] = round_idx

    def version_vector(self) -> tuple:
        """Sorted (agent_id, round_idx) pairs — the stamp for outgoing
        records."""
        return tuple(sorted(self.progress.items()))

    # -- bounded tables ------------------------------------------------------
    def _track(self, table: dict, key: str, value) -> None:
        if len(table) >= self.max_tracked:
            self.n_dropped_tracked += 1
            return
        table[key] = value

    def _sample(self, samples: list[float], value: float) -> None:
        if len(samples) >= self.max_tracked:
            self.n_dropped_tracked += 1
            return
        samples.append(value)

    # -- pushes --------------------------------------------------------------
    def note_erb_push(self, agent_id: int, erb, t: float) -> None:
        self.n_erb_pushes += 1
        self.note_round(agent_id, erb.meta.round_idx)
        if erb.meta.erb_id not in self._erb_born:
            self._track(self._erb_born, erb.meta.erb_id, (agent_id, float(t)))

    def note_snapshot_push(self, agent_id: int, snap, t: float) -> None:
        self.n_snap_pushes += 1
        self.note_round(agent_id, snap.round_idx)
        if snap.snap_id not in self._snap_born:
            self._track(self._snap_born, snap.snap_id, (agent_id, float(t)))

    # -- consumption ---------------------------------------------------------
    def note_erb_consumed(self, agent_id: int, records, t: float) -> None:
        """Incoming ERBs at round start: first *remote* consumption of a
        tracked record yields one creation->consumption latency sample."""
        tel = self.telemetry
        for erb in records:
            born = self._erb_born.get(erb.meta.erb_id)
            if born is None or erb.meta.erb_id in self._erb_consumed:
                continue
            src, t0 = born
            if src == agent_id:
                continue
            self._erb_consumed.add(erb.meta.erb_id)
            lat = max(0.0, float(t) - t0)
            self._sample(self.erb_latencies, lat)
            tel.observe("propagation.erb_latency_s", lat)

    def note_mix(
        self, agent_id: int, snaps, alphas, now: float, clock: str
    ) -> None:
        """One ``mix_params`` call: staleness distribution + per-source
        influence, exactly as the mix weighted them."""
        if not snaps:
            return
        tel = self.telemetry
        self.n_mixes += 1
        label = str(agent_id)
        for snap, alpha in zip(snaps, alphas, strict=True):
            self.n_mixed_snaps += 1
            tau = snap.round_idx if clock == "round" else snap.sim_time
            stale = max(0.0, float(now) - float(tau))
            self._sample(self.staleness_samples, stale)
            tel.observe("mix.staleness", stale, agent=label)
            src = int(snap.agent_id)
            self.influence_by_source[src] = self.influence_by_source.get(
                src, 0.0
            ) + float(alpha)

    # -- gossip --------------------------------------------------------------
    def on_gossip_deliver(self, dst: int, rec, plane_name: str, t: float) -> None:
        """Hook for ``GossipTopology.on_deliver`` — one successful
        anti-entropy delivery; tracked records yield a coverage sample."""
        tel = self.telemetry
        self.n_gossip_deliveries += 1
        tel.count("propagation.gossip_deliveries", 1, plane=plane_name)
        rid = getattr(rec, "record_id", None)
        if rid is None:
            rid = rec.meta.erb_id
        born = self._snap_born.get(rid) or self._erb_born.get(rid)
        if born is not None:
            lat = max(0.0, float(t) - born[1])
            self._sample(self.gossip_latencies, lat)
            tel.observe("propagation.gossip_latency_s", lat)

    # -- report --------------------------------------------------------------
    def summary(self) -> dict[str, Any]:
        """The ``Report.extra["propagation"]`` document."""
        return {
            "version_vector": {str(a): r for a, r in sorted(self.progress.items())},
            "erb": {
                "n_pushed": self.n_erb_pushes,
                "n_tracked": len(self._erb_born),
                "n_consumed_remote": len(self._erb_consumed),
                "latency": _dist_summary(self.erb_latencies),
                "latency_ecdf": _ecdf_points(self.erb_latencies),
            },
            "mix": {
                "n_mixes": self.n_mixes,
                "n_snapshots": self.n_mixed_snaps,
                "staleness": _dist_summary(self.staleness_samples),
                "influence_by_source": {
                    str(a): v for a, v in sorted(self.influence_by_source.items())
                },
            },
            "gossip": {
                "n_deliveries": self.n_gossip_deliveries,
                "coverage": _dist_summary(self.gossip_latencies),
                "coverage_ecdf": _ecdf_points(self.gossip_latencies),
            },
            "n_dropped_tracked": self.n_dropped_tracked,
        }


__all__ = ["PropagationTracker"]
