"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """[head_dim/2] inverse frequencies (f32)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions [...]: int32 -> cos/sin of shape [..., head_dim/2] (f32)."""
    freqs = rope_freqs(head_dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions3, head_dim: int, theta: float, sections):
    """Qwen2-VL multimodal RoPE.

    positions3: [3, ...] (t, h, w) position ids. ``sections`` splits the
    head_dim/2 frequency bands among (t, h, w); each band rotates by its own
    coordinate. Returns cos/sin [..., head_dim/2].
    """
    freqs = rope_freqs(head_dim, theta)  # [half]
    # angles per coordinate: [3, ..., half]
    ang = positions3.astype(jnp.float32)[..., None] * freqs
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    # [half] in {0,1,2}
    idx = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=half)
    sel = jnp.take_along_axis(
        jnp.moveaxis(ang, 0, -1),  # [..., half, 3]
        idx[(None,) * (ang.ndim - 2) + (slice(None), None)].astype(jnp.int32),
        axis=-1,
    )[..., 0]  # [..., half]
    return jnp.cos(sel), jnp.sin(sel)


def apply_rope(x, cos, sin):
    """x [..., S, H, D]; cos/sin broadcastable [..., S, 1, D/2].

    Rotate-half convention (llama): pairs are (x[:D/2], x[D/2:]).
    """
    dtype = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


def positions_for(cfg: ModelConfig, batch: int, seq: int, offset=0):
    """Default position ids. For mrope, text-only default: all three
    coordinates equal (matches Qwen2-VL for pure-text segments)."""
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset  # [1, S]
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.rope == "mrope":
        return jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos


def cos_sin_for(cfg: ModelConfig, positions, head_dim=None):
    """positions: [B,S] (rope) or [3,B,S] (mrope) -> cos,sin [B,S,1,D/2]."""
    hd = head_dim if head_dim is not None else cfg.resolved_head_dim
    if cfg.rope == "none":
        return None
    if cfg.rope == "mrope":
        cos, sin = mrope_cos_sin(positions, hd, cfg.rope_theta, cfg.mrope_sections)
    else:
        cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
    return cos[..., None, :], sin[..., None, :]
