"""Attention mixers: GQA/MHA (chunked online-softmax), sliding window, MLA.

Training / prefill use a chunked online-softmax formulation (lax.scan over
query and key chunks) so the S x S score matrix is never materialized —
this is the pure-jnp twin of the Pallas flash_attention kernel and keeps
the dry-run memory analysis honest at 32k/500k sequence lengths.

Decode consumes a KV cache: ring-buffer of size ``sliding_window`` for SWA
models, full-length otherwise. MLA caches the compressed latent (c_kv,
k_rope) and uses the absorbed-matmul decode path from DeepSeek-V2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import rope as rope_lib
from repro.models.layers import dense_apply, dense_init

F32 = jnp.float32
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# chunked online-softmax attention core (shared by GQA and MLA prefill)
# ---------------------------------------------------------------------------
def _chunk(x, n):
    """[B, S, ...] -> [n, B, S/n, ...]"""
    b, s = x.shape[:2]
    return jnp.moveaxis(x.reshape(b, n, s // n, *x.shape[2:]), 1, 0)


def chunked_attention(
    q,
    k,
    v,
    *,
    causal: bool,
    window: int | None,
    scale: float,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    softcap: float = 0.0,
    wsc=None,
):
    """q [B,S,Hq,D], k/v [B,S,Hkv,Dk]/[B,S,Hkv,Dv] -> [B,S,Hq,Dv].

    GQA kv heads are repeated to Hq *with a head-sharding constraint*
    (``wsc``): per-device the repeated kv is no bigger than the original,
    and every intermediate — including the online-softmax scan carries —
    shards cleanly over the model axis. Memory is
    O(B * Hq * q_chunk * kv_chunk / model_parallel).

    wsc(x, kind): sharding-constraint hook; kind in {"bshd", "bhqx"}.
    """
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    def _id_wsc(x, kind):
        return x

    if wsc is None:
        wsc = _id_wsc
    import os

    inner_wsc = (lambda x, kind: x) if os.environ.get("REPRO_NO_INNER_WSC") else wsc
    qc = min(q_chunk, s)
    kc = min(kv_chunk, s)
    nq, nk = s // qc, s // kc
    assert nq * qc == s and nk * kc == s, (s, qc, kc)

    if g > 1:
        k = wsc(jnp.repeat(k, g, axis=2), "bshd")  # [B,S,Hq,d]
        v = wsc(jnp.repeat(v, g, axis=2), "bshd")
    else:
        k = wsc(k, "bshd")
        v = wsc(v, "bshd")
    q = wsc(q, "bshd")
    qs = _chunk(q, nq)  # [nq,B,qc,hq,d]
    ks = _chunk(k, nk)
    vs = _chunk(v, nk)

    def q_step(_, qi_blk):
        qi, qblk = qi_blk
        qpos = qi * qc + jnp.arange(qc)

        @jax.checkpoint
        def kv_step(carry, kj_blk):
            m, l, acc = carry
            kj, kblk, vblk = kj_blk
            kpos = kj * kc + jnp.arange(kc)
            s_blk = (
                jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk, preferred_element_type=F32)
                * scale
            )
            if softcap > 0.0:
                s_blk = softcap * jnp.tanh(s_blk / softcap)
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            s_blk = inner_wsc(jnp.where(mask[None, None], s_blk, NEG_INF), "bhqx")
            m_new = jnp.maximum(m, s_blk.max(-1))
            p = jnp.exp(s_blk - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = inner_wsc(l * corr + p.sum(-1), "bhqx")
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd",
                p.astype(vblk.dtype),
                vblk,
                preferred_element_type=F32,
            )
            return (m_new, l_new, inner_wsc(acc_new, "bhqx")), None

        m0 = wsc(jnp.full((b, hq, qc), NEG_INF, F32), "bhqx")
        l0 = wsc(jnp.zeros((b, hq, qc), F32), "bhqx")
        a0 = wsc(jnp.zeros((b, hq, qc, dv), F32), "bhqx")
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # [B,hq,qc,dv]
        out = jnp.moveaxis(out, 1, 2)  # [B,qc,hq,dv]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(jax.checkpoint(q_step), None, (jnp.arange(nq), qs))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, hq, dv)


def decode_attention(
    q, k, v, *, scale: float, kpos, pos, window: int | None, softcap: float = 0.0
):
    """Single-token attention against a cache.

    q [B,1,Hq,D], k/v [B,S,Hkv,D*]; kpos [B,S] absolute positions of cache
    entries (ring buffers are unordered); pos [B] current position.
    """
    b, _, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    s_ = jnp.einsum("bhgd,bkhd->bhgk", qg, k, preferred_element_type=F32) * scale
    if softcap > 0.0:
        s_ = softcap * jnp.tanh(s_ / softcap)
    valid = (kpos >= 0) & (kpos <= pos[:, None])  # -1 marks empty slots
    if window is not None:
        valid &= (pos[:, None] - kpos) < window
    s_ = jnp.where(valid[:, None, None, :], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum(
        "bhgk,bkhd->bhgd", p.astype(v.dtype), v, preferred_element_type=F32
    )
    return out.reshape(b, 1, hq, v.shape[-1]).astype(q.dtype)


def make_wsc(mesh, batch_axes, n_heads, model_axis="model", q_chunk=512, tp=True):
    """Sharding-constraint hook for attention internals.

    Two strategies: when the head count divides the model axis, internals
    shard over heads (Megatron TP). Otherwise (e.g. 40 heads on 16-way TP)
    they shard over the q-position dim of each chunk — context-parallel
    attention with replicated kv.
    """
    if mesh is None or model_axis not in mesh.axis_names or not tp:
        return lambda x, kind: x
    from jax.sharding import PartitionSpec as P

    from repro.models.sharding import constrain as cst

    msize = mesh.shape[model_axis]
    heads_ok = n_heads % msize == 0 and msize > 1
    batch = tuple(a for a in batch_axes if a in mesh.axis_names)
    b_ax = batch if len(batch) > 1 else (batch[0] if batch else None)

    def wsc(x, kind):
        if kind == "bshd":  # [B,S,H,D]
            spec = P(b_ax, None, model_axis if heads_ok else None, None)
        else:  # "bhqx": [B, H, qc, ...] accumulators / score blocks
            if heads_ok:
                spec = P(*((b_ax, model_axis) + (None,) * (x.ndim - 2)))
            elif x.shape[2] % msize == 0:
                spec = P(*((b_ax, None, model_axis) + (None,) * (x.ndim - 3)))
            else:
                spec = P(*((b_ax,) + (None,) * (x.ndim - 1)))
        return cst(x, mesh, spec)

    return wsc


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------
def gqa_init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dtype, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dtype),
    }


def gqa_cache_init(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    """cache_len already accounts for sliding windows (ring buffer)."""
    hd = cfg.resolved_head_dim
    shape = (batch, cache_len, cfg.n_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        # absolute positions held in each slot (-1 = empty)
        "kpos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def gqa_apply(
    cfg: ModelConfig,
    p,
    x,
    *,
    mode: str,
    positions=None,
    cache=None,
    attn_impl: str = "xla",
    mesh=None,
    batch_axes=("data",),
    tp: bool = True,
):
    """x [B,S,D] (train/prefill) or [B,1,D] (decode). Returns (y, cache)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense_apply(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = dense_apply(p["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = dense_apply(p["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    scale = hd**-0.5
    wsc = make_wsc(mesh, batch_axes, cfg.n_heads, tp=tp)

    cs = rope_lib.cos_sin_for(cfg, positions) if cfg.rope != "none" else None
    if cs is not None:
        q = rope_lib.apply_rope(q, *cs)
        k = rope_lib.apply_rope(k, *cs)

    if mode in ("train", "prefill"):
        if attn_impl == "pallas":
            from repro.kernels.flash_attention import ops as fa_ops

            y = fa_ops.flash_attention(
                q,
                k,
                v,
                causal=True,
                window=cfg.sliding_window,
                scale=scale,
                softcap=cfg.attn_logit_softcap,
            )
        else:
            y = chunked_attention(
                q,
                k,
                v,
                causal=True,
                window=cfg.sliding_window,
                scale=scale,
                softcap=cfg.attn_logit_softcap,
                wsc=wsc,
            )
        new_cache = None
        if mode == "prefill":
            # hand off the KV cache (ring-truncated to the window for SWA)
            w = cfg.sliding_window
            kp = positions if positions.ndim == 2 else positions[0]
            if w is not None and s > w:
                new_cache = {"k": k[:, -w:], "v": v[:, -w:], "kpos": kp[:, -w:]}
            else:
                new_cache = {"k": k, "v": v, "kpos": kp}
    else:  # decode
        # positions: [B,1] (rope/none) or [3,B,1] (mrope) -> pos [B]
        pos = positions[:, 0] if positions.ndim == 2 else positions[0, :, 0]
        slot = pos if cfg.sliding_window is None else pos % cache["k"].shape[1]
        bidx = jnp.arange(b)
        knew = cache["k"].at[bidx, slot].set(k[:, 0])
        vnew = cache["v"].at[bidx, slot].set(v[:, 0])
        kposn = cache["kpos"].at[bidx, slot].set(pos)
        y = decode_attention(
            q,
            knew,
            vnew,
            scale=scale,
            kpos=kposn,
            pos=pos,
            window=cfg.sliding_window,
            softcap=cfg.attn_logit_softcap,
        )
        new_cache = {"k": knew, "v": vnew, "kpos": kposn}
    y = y.reshape(b, s, cfg.n_heads * hd)
    return dense_apply(p["wo"], y), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------
def mla_init(key, cfg: ModelConfig):
    a = cfg.mla
    dtype = jnp.dtype(cfg.dtype)
    d, h = cfg.d_model, cfg.n_heads
    qd = a.nope_head_dim + a.rope_head_dim
    ks = jax.random.split(key, 7)
    p = {
        "w_dkv": dense_init(ks[0], d, a.kv_lora_rank, dtype),
        "w_krope": dense_init(ks[1], d, a.rope_head_dim, dtype),
        "w_uk": dense_init(ks[2], a.kv_lora_rank, h * a.nope_head_dim, dtype),
        "w_uv": dense_init(ks[3], a.kv_lora_rank, h * a.v_head_dim, dtype),
        "wo": dense_init(ks[4], h * a.v_head_dim, d, dtype),
    }
    if a.q_lora_rank:
        p["w_dq"] = dense_init(ks[5], d, a.q_lora_rank, dtype)
        p["w_uq"] = dense_init(ks[6], a.q_lora_rank, h * qd, dtype)
    else:
        p["wq"] = dense_init(ks[5], d, h * qd, dtype)
    return p


def mla_cache_init(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    a = cfg.mla
    return {
        "ckv": jnp.zeros((batch, cache_len, a.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, cache_len, a.rope_head_dim), dtype),
        "kpos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def _mla_q(cfg, p, x, positions):
    a = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qd = a.nope_head_dim + a.rope_head_dim
    if a.q_lora_rank:
        q = dense_apply(p["w_uq"], dense_apply(p["w_dq"], x))
    else:
        q = dense_apply(p["wq"], x)
    q = q.reshape(b, s, h, qd)
    nd = a.nope_head_dim
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    cs = rope_lib.cos_sin_for(cfg, positions, head_dim=a.rope_head_dim)
    q_rope = rope_lib.apply_rope(q_rope, *cs)
    return q_nope, q_rope, cs


def mla_apply(
    cfg: ModelConfig,
    p,
    x,
    *,
    mode: str,
    positions=None,
    cache=None,
    attn_impl: str = "xla",
    mesh=None,
    batch_axes=("data",),
    tp: bool = True,
):
    a = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    scale = (a.nope_head_dim + a.rope_head_dim) ** -0.5
    q_nope, q_rope, cs = _mla_q(cfg, p, x, positions)

    ckv = dense_apply(p["w_dkv"], x)  # [B,S,r]
    krope = dense_apply(p["w_krope"], x)[:, :, None, :]
    krope = rope_lib.apply_rope(krope, *cs)[:, :, 0]

    if mode in ("train", "prefill"):
        # expanded path: materialize per-head k/v (cheap at train time)
        k_nope = dense_apply(p["w_uk"], ckv).reshape(b, s, h, a.nope_head_dim)
        v = dense_apply(p["w_uv"], ckv).reshape(b, s, h, a.v_head_dim)
        kr = jnp.broadcast_to(krope[:, :, None, :], (b, s, h, a.rope_head_dim))
        k = jnp.concatenate([k_nope, kr], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        y = chunked_attention(
            q,
            k,
            v,
            causal=True,
            window=None,
            scale=scale,
            wsc=make_wsc(mesh, batch_axes, h, tp=tp),
        )
        new_cache = None
        if mode == "prefill":
            kp = positions if positions.ndim == 2 else positions[0]
            new_cache = {"ckv": ckv, "krope": krope, "kpos": kp}
    else:
        # absorbed decode: score/combine directly in the latent space
        pos = positions[:, 0] if positions.ndim == 2 else positions[0, :, 0]
        bidx = jnp.arange(b)
        ckv_c = cache["ckv"].at[bidx, pos].set(ckv[:, 0])
        kr_c = cache["krope"].at[bidx, pos].set(krope[:, 0])
        kpos = cache["kpos"].at[bidx, pos].set(pos)
        w_uk = p["w_uk"]["w"].reshape(a.kv_lora_rank, h, a.nope_head_dim)
        # absorb W_uk into q: q_lat [B,h,r]
        q_lat = jnp.einsum(
            "bhd,rhd->bhr", q_nope[:, 0], w_uk, preferred_element_type=F32
        ).astype(x.dtype)
        s_lat = jnp.einsum("bhr,bkr->bhk", q_lat, ckv_c, preferred_element_type=F32)
        s_rope = jnp.einsum(
            "bhd,bkd->bhk", q_rope[:, 0], kr_c, preferred_element_type=F32
        )
        s_all = (s_lat + s_rope) * scale
        valid = (kpos >= 0) & (kpos <= pos[:, None])
        s_all = jnp.where(valid[:, None, :], s_all, NEG_INF)
        pr = jax.nn.softmax(s_all, axis=-1)
        o_lat = jnp.einsum(
            "bhk,bkr->bhr", pr.astype(x.dtype), ckv_c, preferred_element_type=F32
        ).astype(x.dtype)
        w_uv = p["w_uv"]["w"].reshape(a.kv_lora_rank, h, a.v_head_dim)
        y = jnp.einsum(
            "bhr,rhd->bhd", o_lat, w_uv, preferred_element_type=F32
        ).astype(x.dtype)
        y = y[:, None]
        new_cache = {"ckv": ckv_c, "krope": kr_c, "kpos": kpos}
    y = y.reshape(b, s, h * a.v_head_dim)
    return dense_apply(p["wo"], y), new_cache
