"""Mixture-of-Experts FFN with expert parallelism.

Design (TPU-native, DeepSeek/GShard lineage):

* Router is replicated; routed experts are sharded over the ``model`` mesh
  axis (expert parallelism).
* train/prefill: activations arrive **sequence-sharded** over ``model``
  (Megatron-style sequence parallelism), so dispatch needs an
  ``all_to_all`` pair — tokens travel to their experts' shards and back.
  This is implemented with ``shard_map`` so the collective is explicit in
  the lowered HLO (the roofline reads it).
* decode: a single token step is too small to sequence-shard; activations
  are replicated over ``model``, every shard serves its local experts and a
  ``psum`` combines.
* Shared experts (DeepSeek style) are a plain dense MLP computed outside
  the shard_map (tensor-parallel via GSPMD constraints).
* Capacity-based dispatch: per (expert, source-shard) capacity
  ``C = ceil(top_k * T_loc / E * capacity_factor)``; overflow tokens are
  dropped (contribute only via shared experts), standard for TPU MoE.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import mlp_apply, mlp_init, truncated_normal

F32 = jnp.float32


def moe_init(key, cfg: ModelConfig):
    m = cfg.moe
    dtype = jnp.dtype(cfg.dtype)
    d, e, f = cfg.d_model, m.n_experts, m.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": truncated_normal(ks[0], (d, e), d**-0.5, F32),
        "w1": truncated_normal(ks[1], (e, d, f), d**-0.5, dtype),
        "w3": truncated_normal(ks[2], (e, d, f), d**-0.5, dtype),
        "w2": truncated_normal(ks[3], (e, f, d), f**-0.5, dtype),
    }
    if m.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, d, m.n_shared_experts * f)
    return p


# ---------------------------------------------------------------------------
# local dispatch/combine machinery (runs per shard inside shard_map)
# ---------------------------------------------------------------------------
def _route(cfg: ModelConfig, router_w, x_flat):
    """x_flat [T, D] -> gates [T,k], eidx [T,k], aux (scalar)."""
    m = cfg.moe
    logits = x_flat.astype(F32) @ router_w  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    pe = probs.mean(0)  # [E]
    fe = jnp.zeros((m.n_experts,), F32).at[eidx.reshape(-1)].add(
        1.0 / (x_flat.shape[0] * m.top_k)
    )
    aux = m.n_experts * jnp.sum(fe * pe)
    return gates.astype(x_flat.dtype), eidx, aux


def _dispatch_indices(eidx, n_experts: int, capacity: int):
    """Flattened pair -> (expert, slot, keep). Slots unique per expert."""
    tk = eidx.size
    e_flat = eidx.reshape(-1)  # [TK]
    order = jnp.argsort(e_flat, stable=True)
    sorted_e = e_flat[order]
    counts = jnp.zeros((n_experts,), jnp.int32).at[sorted_e].add(1)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(tk, dtype=jnp.int32) - starts[sorted_e]
    keep_sorted = rank < capacity
    # invert the permutation back to pair order
    inv = jnp.zeros((tk,), jnp.int32).at[order].set(jnp.arange(tk, dtype=jnp.int32))
    slot = rank[inv]
    keep = keep_sorted[inv]
    return e_flat, slot, keep


def _expert_ffn(w1, w3, w2, buf):
    """buf [E_loc, C*, D] -> [E_loc, C*, D] (grouped swiglu)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w1)) * jnp.einsum(
        "ecd,edf->ecf", buf, w3
    )
    return jnp.einsum("ecf,efd->ecd", h, w2)


def _moe_local(
    cfg: ModelConfig,
    model_axis: str | None,
    n_shards: int,
    x_flat,
    router_w,
    w1,
    w3,
    w2,
    *,
    seq_sharded: bool,
):
    """Per-shard MoE body. x_flat [T_loc, D]; w* hold E_loc local experts.

    seq_sharded=True: tokens differ per shard -> all_to_all dispatch.
    seq_sharded=False: tokens replicated -> local experts + psum combine.
    """
    m = cfg.moe
    t_loc, d = x_flat.shape
    e = m.n_experts
    e_loc = w1.shape[0]
    # FSDP: expert weights arrive data-sharded; gather them here so the
    # full-size copies live only inside this (rematerialized) layer body.
    if w1.shape[1] != d:
        w1 = jax.lax.all_gather(w1, "data", axis=1, tiled=True)
        w3 = jax.lax.all_gather(w3, "data", axis=1, tiled=True)
        w2 = jax.lax.all_gather(w2, "data", axis=2, tiled=True)
    cap = max(1, int(-(-m.top_k * t_loc * m.capacity_factor // e)))

    gates, eidx, aux = _route(cfg, router_w, x_flat)
    e_flat, slot, keep = _dispatch_indices(eidx, e, cap)
    tok = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), m.top_k)

    if seq_sharded and model_axis is not None and n_shards > 1:
        # scatter into the full [E, cap, D] send buffer
        buf = jnp.zeros((e, cap, d), x_flat.dtype)
        contrib = x_flat[tok] * keep[:, None].astype(x_flat.dtype)
        buf = buf.at[e_flat, slot].add(contrib)
        # all_to_all: split experts over shards, gather source shards
        # [E, cap, D] -> [S, E_loc, cap, D] -> [E_loc, n_shards * cap, D]
        buf = jax.lax.all_to_all(
            buf.reshape(n_shards, e_loc, cap, d), model_axis, 0, 0, tiled=False
        )
        buf = jnp.moveaxis(buf, 0, 1).reshape(e_loc, n_shards * cap, d)
        out = _expert_ffn(w1, w3, w2, buf)
        out = jnp.moveaxis(out.reshape(e_loc, n_shards, cap, d), 1, 0)
        out = jax.lax.all_to_all(out, model_axis, 0, 0, tiled=False)
        out = out.reshape(e, cap, d)  # back on source
        y_pairs = out[e_flat, slot] * (
            gates.reshape(-1, 1) * keep[:, None].astype(gates.dtype)
        )
        y = jnp.zeros_like(x_flat).at[tok].add(y_pairs)
        aux = jax.lax.pmean(aux, model_axis)
    else:
        # replicated-token path (decode, or single-shard)
        first = 0
        if model_axis is not None and n_shards > 1:
            first = jax.lax.axis_index(model_axis) * e_loc
        e_rel = e_flat - first
        local = (e_rel >= 0) & (e_rel < e_loc) & keep
        e_rel_c = jnp.clip(e_rel, 0, e_loc - 1)
        buf = jnp.zeros((e_loc, cap, d), x_flat.dtype)
        contrib = x_flat[tok] * local[:, None].astype(x_flat.dtype)
        buf = buf.at[e_rel_c, slot].add(contrib)
        out = _expert_ffn(w1, w3, w2, buf)
        y_pairs = out[e_rel_c, slot] * (
            gates.reshape(-1, 1) * local[:, None].astype(gates.dtype)
        )
        y = jnp.zeros_like(x_flat).at[tok].add(y_pairs)
        if model_axis is not None and n_shards > 1:
            y = jax.lax.psum(y, model_axis)
    return y, aux


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------
def moe_apply(
    cfg: ModelConfig,
    p,
    x,
    *,
    mesh=None,
    batch_axes=("data",),
    mode: str = "train",
    tp: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, D] -> (y [B, S, D], aux loss scalar).

    With a mesh: expert-parallel over the "model" axis via shard_map.
    Without: single-shard local path (CPU smoke tests).
    """
    import math

    m = cfg.moe
    b, s, d = x.shape
    seq_sharded = mode in ("train", "prefill")
    bt = None
    if mesh is not None:
        bt = tuple(a for a in batch_axes if a in mesh.axis_names)
        if not bt or b % math.prod(mesh.shape[a] for a in bt) != 0:
            bt = None  # degenerate batch (e.g. 1-token decode): local path

    if (
        mesh is None
        or not tp
        or bt is None
        or "model" not in mesh.axis_names
        or mesh.shape["model"] == 1
        or m.n_experts % mesh.shape["model"]
    ):
        xf = x.reshape(-1, d)
        y, aux = _moe_local(
            cfg, None, 1, xf, p["router"], p["w1"], p["w3"], p["w2"], seq_sharded=False
        )
        y = y.reshape(b, s, d)
    else:
        n_shards = mesh.shape["model"]
        if seq_sharded and s % n_shards == 0:
            x_spec = P(bt, "model", None)
        else:
            x_spec = P(bt, None, None)

        def body(xs, rw, w1, w3, w2):
            xf = xs.reshape(-1, d)
            y, aux = _moe_local(
                cfg, "model", n_shards, xf, rw, w1, w3, w2, seq_sharded=seq_sharded
            )
            for ax in mesh.axis_names:  # out_specs P() => replicate proof
                aux = jax.lax.pmean(aux, ax)
            return y.reshape(xs.shape), aux[None]

        nd = mesh.shape.get("data", 1)
        fsdp = "data" if nd > 1 and cfg.d_model % nd == 0 else None
        y, aux = jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(
                x_spec,
                P(),
                P("model", fsdp, None),
                P("model", fsdp, None),
                P("model", None, fsdp),
            ),
            out_specs=(x_spec, P()),
        )(x, p["router"], p["w1"], p["w3"], p["w2"])
        aux = aux[0]

    if m.n_shared_experts:
        y = y + mlp_apply(cfg, p["shared"], x)
    return y, aux.astype(F32)
