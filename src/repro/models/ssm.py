"""Recurrent mixers: Mamba-1 selective SSM, xLSTM (mLSTM + sLSTM).

TPU adaptation notes (vs the CUDA reference kernels):
* Mamba's selective scan runs as an outer ``lax.scan`` over sequence chunks
  (carrying the [B, d_inner, d_state] state) with a parallel
  ``associative_scan`` inside each chunk — the chunk boundary states are the
  only cross-chunk dependency, mirroring the SSD/chunked formulation that
  maps onto the MXU, and the chunk body is rematerialized in the backward
  pass instead of storing per-step states.
* mLSTM uses the same chunked-recurrent structure (matrix memory C carried
  across chunks); sLSTM is strictly sequential (recurrent gate weights) and
  scans step-by-step — that is inherent to the architecture, not a port
  artifact.
* All recurrences are computed per-channel / per-head, so the ``model`` mesh
  axis shards d_inner / heads with zero cross-device communication inside
  the scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_apply, dense_init, truncated_normal

F32 = jnp.float32


def _make_wsc_ch(mesh, batch_axes, n_ch, model_axis="model", tp=True):
    """Channel-sharding hook: constrain [..., n_ch(, trailing)] tensors so
    recurrent-scan internals shard over the model axis per channel."""
    if mesh is None or model_axis not in mesh.axis_names or not tp:
        return lambda x, ch_dim=-1: x
    from jax.sharding import PartitionSpec as P

    from repro.models.sharding import constrain as cst

    msize = mesh.shape[model_axis]
    c_ax = model_axis if (n_ch % msize == 0 and msize > 1) else None
    batch = tuple(a for a in batch_axes if a in mesh.axis_names)
    b_ax = batch if len(batch) > 1 else (batch[0] if batch else None)

    def wsc(x, ch_dim=-1):
        dims = [None] * x.ndim
        dims[0] = b_ax
        dims[ch_dim if ch_dim >= 0 else x.ndim + ch_dim] = c_ax
        return cst(x, mesh, P(*dims))

    return wsc


# ---------------------------------------------------------------------------
# causal depthwise conv1d (used by mamba and mLSTM)
# ---------------------------------------------------------------------------
def causal_conv1d(x, w, b, conv_state=None):
    """x [B,S,C], w [C,K], b [C]. Returns (y [B,S,C], new_state [B,K-1,C])."""
    k = w.shape[1]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, S+K-1, C]
    y = sum(xp[:, i : i + x.shape[1], :] * w[:, i] for i in range(k))
    y = y + b
    new_state = xp[:, x.shape[1] :, :] if k > 1 else pad
    return y, new_state


def _best_chunk(s_len: int, chunk: int) -> int:
    """Largest divisor of s_len that is <= chunk."""
    chunk = min(chunk, s_len)
    while s_len % chunk:
        chunk -= 1
    return max(chunk, 1)


# ---------------------------------------------------------------------------
# Mamba-1 selective SSM
# ---------------------------------------------------------------------------
def mamba_init(key, cfg: ModelConfig):
    s = cfg.ssm
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    di = s.expand * d
    dtr = s.dt_rank or -(-d // 16)
    ks = jax.random.split(key, 6)
    a = jnp.broadcast_to(jnp.arange(1, s.d_state + 1, dtype=F32), (di, s.d_state))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": truncated_normal(ks[1], (di, s.d_conv), s.d_conv**-0.5, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * s.d_state, dtype),
        "dt_proj": dense_init(ks[3], dtr, di, dtype, bias=True, scale=dtr**-0.5),
        "a_log": jnp.log(a),  # f32 [di, N]
        "d_skip": jnp.ones((di,), F32),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def mamba_state_init(cfg: ModelConfig, batch: int, dtype):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, s.d_state), F32),
    }


def _selective_scan_chunked(dt, b_seq, c_seq, xf, a, chunk: int, wsc=None):
    """h_t = exp(dt_t A) h_{t-1} + (dt_t x_t) B_t;  y_t = h_t . C_t.

    dt/xf [B,S,di], b_seq/c_seq [B,S,N] (all f32), a [di,N]. The [.,.,di,N]
    discretized tensors are formed *inside* the rematerialized chunk body so
    they never exist at full sequence length (the CUDA kernel's fusion,
    expressed as remat).
    """
    b, s_len, di = dt.shape
    n = a.shape[1]
    chunk = _best_chunk(s_len, chunk)
    nc = s_len // chunk
    import os

    def _id_wsc(x, ch_dim=-1):
        return x

    if wsc is None or os.environ.get("REPRO_NO_SCAN_WSC"):
        wsc = _id_wsc

    def to_c(t):
        return jnp.moveaxis(t.reshape(b, nc, chunk, *t.shape[2:]), 1, 0)

    @jax.checkpoint
    def chunk_body(h0, inp):
        dt_k, b_k, c_k, x_k = inp  # [B,chunk,...]
        da_k = wsc(jnp.exp(dt_k[..., None] * a), 2)  # [B,chunk,di,N]
        dbx_k = wsc((dt_k * x_k)[..., None] * b_k[:, :, None, :], 2)

        def op(left, right):
            al, bl = left
            ar, br = right
            return al * ar, bl * ar + br

        a_cum, b_cum = jax.lax.associative_scan(op, (da_k, dbx_k), axis=1)
        h = wsc(a_cum * h0[:, None] + b_cum, 2)  # [B,chunk,di,N]
        y = wsc(jnp.einsum("bsdn,bsn->bsd", h, c_k))
        return wsc(h[:, -1], 1), y

    h0 = jnp.zeros((b, di, n), F32)
    h_last, ys = jax.lax.scan(
        chunk_body, h0, (to_c(dt), to_c(b_seq), to_c(c_seq), to_c(xf))
    )
    return jnp.moveaxis(ys, 0, 1).reshape(b, s_len, di), h_last


def mamba_apply(
    cfg: ModelConfig,
    p,
    u,
    *,
    mode: str,
    state=None,
    mesh=None,
    batch_axes=("data",),
    tp: bool = True,
):
    """u [B,S,D] -> (y [B,S,D], new_state or None)."""
    s_cfg = cfg.ssm
    b, s_len, d = u.shape
    di = s_cfg.expand * d
    dtr = s_cfg.dt_rank or -(-d // 16)
    wsc = _make_wsc_ch(mesh, batch_axes, di, tp=tp)

    xz = dense_apply(p["in_proj"], u)
    x, z = wsc(xz[..., :di]), wsc(xz[..., di:])
    conv_state = state["conv"] if state is not None else None
    x, new_conv = causal_conv1d(x, p["conv_w"], p["conv_b"], conv_state)
    x = wsc(jax.nn.silu(x))

    xdb = dense_apply(p["x_proj"], x)
    # dt [B,S,di]
    dt = wsc(jax.nn.softplus(dense_apply(p["dt_proj"], xdb[..., :dtr]).astype(F32)))
    b_ssm = xdb[..., dtr : dtr + s_cfg.d_state].astype(F32)
    c_ssm = xdb[..., dtr + s_cfg.d_state :].astype(F32)
    a = -jnp.exp(p["a_log"])  # [di, N]
    xf = x.astype(F32)

    if mode in ("train", "prefill"):
        y, h_last = _selective_scan_chunked(
            dt, b_ssm, c_ssm, xf, a, s_cfg.chunk, wsc=wsc
        )
        new_state = {"conv": new_conv, "ssm": h_last} if mode == "prefill" else None
    else:
        h = state["ssm"]  # [B,di,N]
        da1 = jnp.exp(dt[:, 0, :, None] * a)  # [B,di,N]
        dbx1 = (dt[:, 0] * xf[:, 0])[..., None] * b_ssm[:, 0, None, :]
        h = da1 * h + dbx1
        y = jnp.einsum("bdn,bn->bd", h, c_ssm[:, 0])[:, None]
        new_state = {"conv": new_conv, "ssm": h}
    y = y + xf * p["d_skip"]
    y = y.astype(u.dtype) * jax.nn.silu(z)
    return dense_apply(p["out_proj"], y), new_state


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block)
# ---------------------------------------------------------------------------
def mlstm_init(key, cfg: ModelConfig):
    x_cfg = cfg.xlstm
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    di = int(x_cfg.mlstm_proj_factor * d)
    h = cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": truncated_normal(
            ks[1], (di, x_cfg.conv_width), x_cfg.conv_width**-0.5, dtype
        ),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": dense_init(ks[2], di, di, dtype),
        "wk": dense_init(ks[3], di, di, dtype),
        "wv": dense_init(ks[4], di, di, dtype),
        "w_if": dense_init(ks[5], di, 2 * h, dtype),  # i and f pre-acts
        "out_proj": dense_init(ks[6], di, d, dtype),
    }


def mlstm_state_init(cfg: ModelConfig, batch: int, dtype):
    x_cfg = cfg.xlstm
    di = int(x_cfg.mlstm_proj_factor * cfg.d_model)
    h = cfg.n_heads
    dh = di // h
    return {
        "conv": jnp.zeros((batch, x_cfg.conv_width - 1, di), dtype),
        "c": jnp.zeros((batch, h, dh, dh), F32),
        "n": jnp.zeros((batch, h, dh), F32),
        "m": jnp.full((batch, h), -1e30, F32),
    }


def _mlstm_step(carry, inp):
    c, n, m = carry
    q, k, v, log_i, log_f = inp  # q/k/v [B,H,dh]
    m_new = jnp.maximum(log_f + m, log_i)
    i_p = jnp.exp(log_i - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    # c [B,H,dk,dv]
    c = f_p[..., None, None] * c + i_p[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n = f_p[..., None] * n + i_p[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    hout = num / den[..., None]
    return (c, n, m_new), hout


def mlstm_apply(
    cfg: ModelConfig,
    p,
    u,
    *,
    mode: str,
    state=None,
    mesh=None,
    batch_axes=("data",),
    tp: bool = True,
):
    x_cfg = cfg.xlstm
    b, s_len, d = u.shape
    di = int(x_cfg.mlstm_proj_factor * d)
    h = cfg.n_heads
    dh = di // h
    wsc = _make_wsc_ch(mesh, batch_axes, di, tp=tp)

    xz = dense_apply(p["in_proj"], u)
    x, z = wsc(xz[..., :di]), wsc(xz[..., di:])
    conv_state = state["conv"] if state is not None else None
    xc, new_conv = causal_conv1d(x, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)

    q = dense_apply(p["wq"], xc).reshape(b, s_len, h, dh).astype(F32)
    k = dense_apply(p["wk"], xc).reshape(b, s_len, h, dh).astype(F32) * dh**-0.5
    v = dense_apply(p["wv"], x).reshape(b, s_len, h, dh).astype(F32)
    if_pre = dense_apply(p["w_if"], xc).astype(F32)  # [B,S,2H]
    log_i = if_pre[..., :h]
    log_f = jax.nn.log_sigmoid(if_pre[..., h:])

    if mode in ("train", "prefill"):
        chunk = _best_chunk(s_len, x_cfg.chunk)
        nc = s_len // chunk

        def to_chunks(t):  # [B,S,...] -> [nc,chunk,B,...]
            t = jnp.moveaxis(t, 1, 0).reshape(nc, chunk, *t.shape[:1], *t.shape[2:])
            return t

        seq = tuple(to_chunks(t) for t in (q, k, v, log_i, log_f))

        @jax.checkpoint
        def chunk_body(carry, inp):
            carry, ys = jax.lax.scan(_mlstm_step, carry, inp)
            return carry, ys  # ys [chunk,B,H,dh]

        c0 = (
            jnp.zeros((b, h, dh, dh), F32),
            jnp.zeros((b, h, dh), F32),
            jnp.full((b, h), -1e30, F32),
        )
        (cf, nf, mf), ys = jax.lax.scan(chunk_body, c0, seq)
        y = jnp.moveaxis(ys.reshape(s_len, b, h, dh), 0, 1)
        new_state = None
        if mode == "prefill":
            new_state = {"conv": new_conv, "c": cf, "n": nf, "m": mf}
    else:
        carry = (state["c"], state["n"], state["m"])
        inp = (q[:, 0], k[:, 0], v[:, 0], log_i[:, 0], log_f[:, 0])
        (c, n, m), y = _mlstm_step(carry, inp)
        y = y[:, None]
        new_state = {"conv": new_conv, "c": c, "n": n, "m": m}
    y = y.reshape(b, s_len, di).astype(u.dtype) * jax.nn.silu(z)
    return dense_apply(p["out_proj"], y), new_state


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory block, recurrent gates => sequential)
# ---------------------------------------------------------------------------
def slstm_init(key, cfg: ModelConfig):
    x_cfg = cfg.xlstm
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    dff = int(x_cfg.slstm_ffn_factor * d)
    ks = jax.random.split(key, 4)
    return {
        # input weights for (z, i, f, o) stacked: [D, 4D]
        "w_x": dense_init(ks[0], d, 4 * d, dtype, bias=True),
        # block-diagonal recurrent weights per head: [4, H, dh, dh]
        "r_h": truncated_normal(ks[1], (4, h, dh, dh), dh**-0.5, dtype),
        "ffn_up": dense_init(ks[2], d, 2 * dff, dtype),
        "ffn_down": dense_init(ks[3], dff, d, dtype),
    }


def slstm_state_init(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    return {
        "h": jnp.zeros((batch, h, dh), F32),
        "c": jnp.zeros((batch, h, dh), F32),
        "n": jnp.ones((batch, h, dh), F32),
        "m": jnp.zeros((batch, h, dh), F32),
    }


def _slstm_step(p, carry, x_pre):
    """x_pre [B,4,H,dh] (input pre-activations); carry (h,c,n,m)."""
    hprev, c, n, m = carry
    rh = p["r_h"].astype(F32)
    rec = jnp.einsum("bhd,ghde->bghe", hprev, rh)  # [B,4,H,dh]
    pre = x_pre + rec
    z = jnp.tanh(pre[:, 0])
    log_i = pre[:, 1]
    log_f = jax.nn.log_sigmoid(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(log_f + m, log_i)
    i_p = jnp.exp(log_i - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c_new = f_p * c + i_p * z
    n_new = f_p * n + i_p
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new), h_new


def slstm_apply(
    cfg: ModelConfig,
    p,
    u,
    *,
    mode: str,
    state=None,
    mesh=None,
    batch_axes=("data",),
    tp: bool = True,
):
    b, s_len, d = u.shape
    h = cfg.n_heads
    dh = d // h
    x_pre = dense_apply(p["w_x"], u).astype(F32).reshape(b, s_len, 4, h, dh)

    if state is None:
        carry = (
            jnp.zeros((b, h, dh), F32),
            jnp.zeros((b, h, dh), F32),
            jnp.ones((b, h, dh), F32),
            jnp.zeros((b, h, dh), F32),
        )
    else:
        carry = (state["h"], state["c"], state["n"], state["m"])

    def step(c, xp):
        return _slstm_step(p, c, xp)

    if mode in ("train", "prefill"):
        carry, ys = jax.lax.scan(step, carry, jnp.moveaxis(x_pre, 1, 0))
        y = jnp.moveaxis(ys, 0, 1).reshape(b, s_len, d)
        new_state = None
        if mode == "prefill":
            new_state = {"h": carry[0], "c": carry[1], "n": carry[2], "m": carry[3]}
    else:
        carry, y = _slstm_step(p, carry, x_pre[:, 0])
        y = y.reshape(b, 1, d)
        new_state = {"h": carry[0], "c": carry[1], "n": carry[2], "m": carry[3]}
    y = y.astype(u.dtype)
    # post gated FFN (xLSTM block structure)
    up = dense_apply(p["ffn_up"], y)
    dff = up.shape[-1] // 2
    y = dense_apply(p["ffn_down"], jax.nn.silu(up[..., :dff]) * up[..., dff:])
    return y, new_state
