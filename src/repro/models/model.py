"""Model assembly: init / forward / train_step / prefill_step / serve_step.

All entry points are pure functions of (cfg, mesh, policy); the returned
closures are jit-compatible and carry explicit sharding constraints so the
512-device dry-run and the 1-device smoke test share one code path.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ATTN, FFN_DENSE, ModelConfig
from repro.models import rope as rope_lib
from repro.models.blocks import block_apply, block_cache_init, block_init
from repro.models.layers import embed_apply, embed_init, norm_apply, norm_init
from repro.models.sharding import ShardingPolicy, act_spec, constrain
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

F32 = jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key) -> dict[str, Any]:
    keys = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": embed_init(keys[0], cfg),
        "final_norm": norm_init(cfg, cfg.d_model),
    }
    if cfg.first_k_dense:
        prefix = {}
        pks = jax.random.split(keys[1], cfg.first_k_dense)
        for i in range(cfg.first_k_dense):
            prefix[f"l{i}"] = block_init(
                pks[i], cfg, ATTN, FFN_DENSE, d_ff=cfg.first_k_dense_d_ff or cfg.d_ff
            )
        params["prefix"] = prefix

    def group_init(gkey):
        bks = jax.random.split(gkey, len(cfg.pattern))
        return {
            f"b{j}": block_init(bks[j], cfg, mixer, ffn)
            for j, (mixer, ffn) in enumerate(cfg.pattern)
        }

    gkeys = jax.random.split(keys[2], cfg.n_groups)
    params["groups"] = jax.vmap(group_init)(gkeys)
    return params


def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_caches(cfg: ModelConfig, batch: int, seq_len: int):
    """Decode caches (ring-buffer length for SWA models)."""
    dtype = jnp.dtype(cfg.dtype)
    clen = cache_len_for(cfg, seq_len)
    caches: dict[str, Any] = {}
    if cfg.first_k_dense:
        caches["prefix"] = {
            f"l{i}": block_cache_init(cfg, ATTN, batch, clen, dtype)
            for i in range(cfg.first_k_dense)
        }
    one = {
        f"b{j}": block_cache_init(cfg, mixer, batch, clen, dtype)
        for j, (mixer, _) in enumerate(cfg.pattern)
    }
    caches["groups"] = jax.tree_util.tree_map(
        lambda a: jnp.tile(a[None], (cfg.n_groups,) + (1,) * a.ndim), one
    )
    return caches


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def forward(
    cfg: ModelConfig,
    params,
    inputs,
    positions,
    *,
    mode: str,
    caches=None,
    mesh=None,
    policy: ShardingPolicy = ShardingPolicy(),
    attn_impl: str = "xla",
):
    """inputs: tokens [B,S] int32 or embeds [B,S,D]. Returns
    (hidden [B,S,D], aux scalar, new_caches-or-None)."""
    if inputs.dtype in (jnp.int32, jnp.int64):
        x = embed_apply(params["embed"], inputs)
    else:
        x = inputs.astype(jnp.dtype(cfg.dtype))
    b, s = x.shape[:2]
    bspec = act_spec(policy, mesh, seq_len=s, mode=mode)
    x = constrain(x, mesh, bspec)
    batch_axes = policy.batch_axes
    aux = jnp.zeros((), F32)
    new_caches: dict[str, Any] = {}

    blk = partial(
        block_apply,
        cfg,
        mode=mode,
        positions=positions,
        mesh=mesh,
        batch_axes=batch_axes,
        attn_impl=attn_impl,
        tp=policy.tensor_parallel,
    )

    if cfg.first_k_dense:
        new_caches["prefix"] = {}
        for i in range(cfg.first_k_dense):
            c = caches["prefix"][f"l{i}"] if caches is not None else None
            x, nc, a = blk(
                params["prefix"][f"l{i}"], x, mixer=ATTN, ffn=FFN_DENSE, cache=c
            )
            x = constrain(x, mesh, bspec)
            new_caches["prefix"][f"l{i}"] = nc
            aux = aux + a

    have_cache = caches is not None

    remat = policy.remat and mode == "train"

    def group_body(carry, xs):
        x, aux = carry
        gp, gcache = xs if have_cache else (xs, None)
        x = constrain(x, mesh, bspec)
        new_gc = {}
        for j, (mixer, ffn) in enumerate(cfg.pattern):
            c = gcache[f"b{j}"] if gcache is not None else None
            f = partial(blk, mixer=mixer, ffn=ffn, cache=c)
            if remat:
                f = jax.checkpoint(f)  # per-layer remat
            x, nc, a = f(gp[f"b{j}"], x)
            # keep the saved residual stream sequence-sharded
            x = constrain(x, mesh, bspec)
            new_gc[f"b{j}"] = nc
            aux = aux + a
        return (x, aux), new_gc

    body = jax.checkpoint(group_body) if remat else group_body
    xs = (params["groups"], caches["groups"]) if have_cache else params["groups"]
    (x, aux), group_caches = jax.lax.scan(body, (x, aux), xs)
    new_caches["groups"] = group_caches

    x = norm_apply(cfg, params["final_norm"], x)
    x = constrain(x, mesh, bspec)
    ret_caches = new_caches if (have_cache or mode == "prefill") else None
    return x, aux, ret_caches


def logits_fn(
    cfg: ModelConfig,
    params,
    hidden,
    *,
    mesh=None,
    policy: ShardingPolicy = ShardingPolicy(),
):
    from repro.models.layers import unembed_apply

    logits = unembed_apply(cfg, params["embed"], hidden)
    if mesh is not None:
        batch = tuple(a for a in policy.batch_axes if a in mesh.axis_names)
        b_ax = batch if len(batch) > 1 else (batch[0] if batch else None)
        v_ax = (
            policy.model_axis
            if (
                policy.tensor_parallel
                and policy.model_axis not in batch
                and cfg.vocab_size % mesh.shape[policy.model_axis] == 0
            )
            else None
        )
        logits = constrain(logits, mesh, P(b_ax, None, v_ax))
    return logits


def chunked_xent(
    cfg: ModelConfig,
    params,
    hidden,
    labels,
    *,
    mesh=None,
    policy: ShardingPolicy = ShardingPolicy(),
):
    """Cross entropy over S chunks — never materializes [B, S, V].

    labels < 0 are masked out. Returns (sum_nll, n_valid).
    """
    b, s, d = hidden.shape
    c = min(policy.loss_chunk, s)
    nc = s // c
    h_c = jnp.moveaxis(hidden.reshape(b, nc, c, d), 1, 0)
    l_c = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)

    @jax.checkpoint
    def chunk(carry, xs):
        nll, n = carry
        h, lab = xs
        logits = logits_fn(cfg, params, h, mesh=mesh, policy=policy)
        logits = logits.astype(F32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(lab, 0)[..., None], axis=-1)
        gold = gold[..., 0]
        mask = (lab >= 0).astype(F32)
        nll = nll + jnp.sum((lse - gold) * mask)
        n = n + jnp.sum(mask)
        return (nll, n), None

    (nll, n), _ = jax.lax.scan(
        chunk, (jnp.zeros((), F32), jnp.zeros((), F32)), (h_c, l_c)
    )
    return nll, n


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------
def make_loss_fn(
    cfg: ModelConfig,
    *,
    mesh=None,
    policy: ShardingPolicy = ShardingPolicy(),
    attn_impl: str = "xla",
):
    def loss_fn(params, batch):
        inputs = batch.get("tokens", batch.get("embeds"))
        b, s = inputs.shape[:2]
        positions = batch.get("positions")
        if positions is None:
            positions = rope_lib.positions_for(cfg, b, s)
        hidden, aux, _ = forward(
            cfg,
            params,
            inputs,
            positions,
            mode="train",
            mesh=mesh,
            policy=policy,
            attn_impl=attn_impl,
        )
        nll, n = chunked_xent(
            cfg, params, hidden, batch["labels"], mesh=mesh, policy=policy
        )
        loss = nll / jnp.maximum(n, 1.0)
        total = loss + cfg.moe.router_aux_weight * aux
        return total, {"loss": loss, "aux": aux, "n_tokens": n}

    return loss_fn


def init_train_state(cfg: ModelConfig, key, opt_cfg: AdamWConfig):
    params = init_params(cfg, key)
    return {"params": params, "opt": adamw_init(opt_cfg, params)}


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    *,
    mesh=None,
    policy: ShardingPolicy = ShardingPolicy(),
    attn_impl: str = "xla",
):
    loss_fn = make_loss_fn(cfg, mesh=mesh, policy=policy, attn_impl=attn_impl)
    k = policy.microbatches

    def constrain_grads(g):
        """Pin gradient (accumulator) sharding to the param sharding —
        without this the scan-transpose materializes full f32 grads."""
        if mesh is None:
            return g
        from repro.models.sharding import tree_shardings

        shardings = tree_shardings(g, mesh, policy, cfg)
        return jax.lax.with_sharding_constraint(g, shardings)

    def train_step(state, batch):
        params_use = state["params"]
        if policy.hoist_dense_gathers and mesh is not None:
            from repro.models.sharding import hoist_constrain

            params_use = hoist_constrain(params_use, mesh, policy, cfg)
        if k == 1:
            (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params_use, batch
            )
            grads = constrain_grads(grads)
        else:
            # gradient accumulation over k microbatches (forward-only scan;
            # each microbatch's backward is local to its iteration)
            lead = next(iter(jax.tree_util.tree_leaves(batch))).shape[0]

            def to_microbatches(x):
                if x.ndim >= 1 and x.shape[0] == lead:
                    x = x.reshape((x.shape[0] // k, k) + x.shape[1:])
                    return jnp.moveaxis(x, 1, 0)
                return x

            mbs = jax.tree_util.tree_map(to_microbatches, batch)
            # note: all batch leaves share the leading global-batch dim
            # except mrope positions [3, B, S] — handle that axis.
            if "positions" in batch:
                p = batch["positions"]
                mbs["positions"] = jnp.moveaxis(
                    p.reshape(p.shape[0], p.shape[1] // k, k, *p.shape[2:]), 2, 0
                )

            hoisted = policy.hoist_dense_gathers and mesh is not None

            def cg(g):
                # hoisted mode: accumulate in the gathered (TP-only)
                # layout inside the scan; one reduce-scatter at the end
                if hoisted:
                    from repro.models.sharding import hoist_constrain

                    return hoist_constrain(constrain_grads(g), mesh, policy, cfg)
                return constrain_grads(g)

            def mb_body(carry, mb):
                g_acc, t_acc, m_acc = carry
                (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params_use, mb
                )
                g_acc = cg(jax.tree_util.tree_map(jnp.add, g_acc, grads))
                m_acc = jax.tree_util.tree_map(jnp.add, m_acc, metrics)
                return (g_acc, t_acc + total, m_acc), None

            def zeros_like(p):
                return jnp.zeros(p.shape, p.dtype)

            g0 = cg(jax.tree_util.tree_map(zeros_like, state["params"]))
            m0 = {
                "loss": jnp.zeros((), jnp.float32),
                "aux": jnp.zeros((), jnp.float32),
                "n_tokens": jnp.zeros((), jnp.float32),
            }
            (grads, total, metrics), _ = jax.lax.scan(
                mb_body, (g0, jnp.zeros((), jnp.float32), m0), mbs
            )
            grads = constrain_grads(jax.tree_util.tree_map(lambda g: g / k, grads))
            total = total / k
            metrics = {
                "loss": metrics["loss"] / k,
                "aux": metrics["aux"] / k,
                "n_tokens": metrics["n_tokens"],
            }
        new_params, new_opt, gnorm = adamw_update(
            opt_cfg, state["params"], grads, state["opt"]
        )
        metrics = dict(metrics, total=total, grad_norm=gnorm)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(
    cfg: ModelConfig,
    *,
    mesh=None,
    policy: ShardingPolicy = ShardingPolicy(),
    attn_impl: str = "xla",
):
    def prefill_step(params, batch):
        inputs = batch.get("tokens", batch.get("embeds"))
        b, s = inputs.shape[:2]
        positions = batch.get("positions")
        if positions is None:
            positions = rope_lib.positions_for(cfg, b, s)
        hidden, _, caches = forward(
            cfg,
            params,
            inputs,
            positions,
            mode="prefill",
            mesh=mesh,
            policy=policy,
            attn_impl=attn_impl,
        )
        last = logits_fn(cfg, params, hidden[:, -1:], mesh=mesh, policy=policy)
        return last[:, 0], caches

    return prefill_step


class Model:
    """Convenience bundle over the functional API."""

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        mesh=None,
        policy: ShardingPolicy = ShardingPolicy(),
        opt_cfg: AdamWConfig = AdamWConfig(),
        attn_impl: str = "xla",
    ):
        self.cfg = cfg
        self.mesh = mesh
        self.policy = policy
        self.opt_cfg = opt_cfg
        self.init_params = partial(init_params, cfg)
        self.init_caches = partial(init_caches, cfg)
        self.init_train_state = lambda key: init_train_state(cfg, key, opt_cfg)
        self.loss_fn = make_loss_fn(cfg, mesh=mesh, policy=policy, attn_impl=attn_impl)
        self.train_step = make_train_step(
            cfg, opt_cfg, mesh=mesh, policy=policy, attn_impl=attn_impl
        )
        self.prefill_step = make_prefill_step(
            cfg, mesh=mesh, policy=policy, attn_impl=attn_impl
        )
        self.serve_step = make_serve_step(cfg, mesh=mesh, policy=policy)


def build_model(cfg: ModelConfig, **kw) -> Model:
    return Model(cfg, **kw)


def make_serve_step(
    cfg: ModelConfig, *, mesh=None, policy: ShardingPolicy = ShardingPolicy()
):
    """One decode step: (params, caches, batch{tokens|embeds, pos}) ->
    (logits [B, V], new_caches)."""

    def serve_step(params, caches, batch):
        inputs = batch.get("tokens", batch.get("embeds"))
        pos = batch["pos"]  # [B]
        positions = pos[:, None]
        if cfg.rope == "mrope":
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        hidden, _, new_caches = forward(
            cfg,
            params,
            inputs,
            positions,
            mode="decode",
            caches=caches,
            mesh=mesh,
            policy=policy,
        )
        logits = logits_fn(cfg, params, hidden, mesh=mesh, policy=policy)
        return logits[:, 0], new_caches

    return serve_step
