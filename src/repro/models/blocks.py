"""Composable decoder block: (mixer, ffn) pairs from the config pattern."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ATTN,
    FFN_DENSE,
    FFN_MOE,
    MAMBA,
    MLA,
    MLSTM,
    SLSTM,
    ModelConfig,
)
from repro.models import attention, ssm
from repro.models.layers import mlp_apply, mlp_init, norm_apply, norm_init
from repro.models.moe import moe_apply, moe_init

_MIXER_INIT = {
    ATTN: attention.gqa_init,
    MLA: attention.mla_init,
    MAMBA: ssm.mamba_init,
    MLSTM: ssm.mlstm_init,
    SLSTM: ssm.slstm_init,
}


def block_init(key, cfg: ModelConfig, mixer: str, ffn: str, d_ff: int | None = None):
    ks = jax.random.split(key, 4)
    p = {
        "norm1": norm_init(cfg, cfg.d_model),
        "mixer": _MIXER_INIT[mixer](ks[0], cfg),
    }
    if ffn == FFN_DENSE:
        p["norm2"] = norm_init(cfg, cfg.d_model)
        p["ffn"] = mlp_init(ks[1], cfg, cfg.d_model, d_ff or cfg.d_ff)
    elif ffn == FFN_MOE:
        p["norm2"] = norm_init(cfg, cfg.d_model)
        p["ffn"] = moe_init(ks[1], cfg)
    return p


def block_cache_init(cfg: ModelConfig, mixer: str, batch: int, cache_len: int, dtype):
    if mixer == ATTN:
        return attention.gqa_cache_init(cfg, batch, cache_len, dtype)
    if mixer == MLA:
        return attention.mla_cache_init(cfg, batch, cache_len, dtype)
    if mixer == MAMBA:
        return ssm.mamba_state_init(cfg, batch, dtype)
    if mixer == MLSTM:
        return ssm.mlstm_state_init(cfg, batch, dtype)
    if mixer == SLSTM:
        return ssm.slstm_state_init(cfg, batch, dtype)
    raise ValueError(mixer)


def _full_s(x, mesh, batch_axes):
    """All-gather the sequence dim at mixer/FFN entry (Megatron-SP)."""
    if mesh is None:
        return x
    from jax.sharding import PartitionSpec as P

    from repro.models.sharding import constrain

    batch = tuple(a for a in batch_axes if a in mesh.axis_names)
    b_ax = batch if len(batch) > 1 else (batch[0] if batch else None)
    return constrain(x, mesh, P(b_ax, None, None))


def block_apply(
    cfg: ModelConfig,
    p,
    x,
    *,
    mixer: str,
    ffn: str,
    mode: str,
    positions=None,
    cache=None,
    mesh=None,
    batch_axes=("data",),
    attn_impl: str = "xla",
    tp: bool = True,
):
    """Returns (x, new_cache, aux)."""
    h = _full_s(norm_apply(cfg, p["norm1"], x), mesh, batch_axes)
    if mixer == ATTN:
        y, new_cache = attention.gqa_apply(
            cfg,
            p["mixer"],
            h,
            mode=mode,
            positions=positions,
            cache=cache,
            attn_impl=attn_impl,
            mesh=mesh,
            batch_axes=batch_axes,
            tp=tp,
        )
    elif mixer == MLA:
        y, new_cache = attention.mla_apply(
            cfg,
            p["mixer"],
            h,
            mode=mode,
            positions=positions,
            cache=cache,
            attn_impl=attn_impl,
            mesh=mesh,
            batch_axes=batch_axes,
            tp=tp,
        )
    elif mixer == MAMBA:
        y, new_cache = ssm.mamba_apply(
            cfg,
            p["mixer"],
            h,
            mode=mode,
            state=cache,
            mesh=mesh,
            batch_axes=batch_axes,
            tp=tp,
        )
    elif mixer == MLSTM:
        y, new_cache = ssm.mlstm_apply(
            cfg,
            p["mixer"],
            h,
            mode=mode,
            state=cache,
            mesh=mesh,
            batch_axes=batch_axes,
            tp=tp,
        )
    elif mixer == SLSTM:
        y, new_cache = ssm.slstm_apply(
            cfg,
            p["mixer"],
            h,
            mode=mode,
            state=cache,
            mesh=mesh,
            batch_axes=batch_axes,
            tp=tp,
        )
    else:
        raise ValueError(mixer)
    x = x + y

    aux = jnp.zeros((), jnp.float32)
    if ffn == FFN_DENSE:
        h2 = _full_s(norm_apply(cfg, p["norm2"], x), mesh, batch_axes)
        x = x + mlp_apply(cfg, p["ffn"], h2)
    elif ffn == FFN_MOE:
        # MoE consumes the sequence-sharded stream directly (EP dispatch)
        y, aux = moe_apply(
            cfg,
            p["ffn"],
            norm_apply(cfg, p["norm2"], x),
            mesh=mesh,
            batch_axes=batch_axes,
            mode=mode,
            tp=tp,
        )
        x = x + y
    return x, new_cache, aux
