"""Sharding policy: logical->mesh rules for params, optimizer state, acts.

2-D param sharding: tensor-parallel over ``model`` + FSDP over ``fsdp_axes``
(default: the ``data`` axis). Any dim that does not divide the assigned
axis size falls back to replication — this keeps small archs (xlstm-125m)
lowering on a 256-chip mesh without bespoke configs.

The fleet engine uses a second, much simpler family defined at the
bottom: a 1-D mesh whose single axis carries the *leading agent axis* of
every stacked pytree (params / target / optimizer / PRNG / counters),
with the replay pool replicated — pure population parallelism, where the
per-slot program is identical on every device and no collective ever
crosses slots (:class:`FleetSharding`, :func:`make_fleet_mesh`).
"""

from __future__ import annotations

import fnmatch
import math
from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

FSDP = "__fsdp__"  # placeholder resolved to policy.fsdp_axes
MODEL = "model"
HEADQ = "__headq__"  # model axis iff cfg.n_heads divides it (else replicate)
HEADKV = "__headkv__"  # model axis iff cfg.n_kv_heads divides it
FLEET = "fleet"  # the stacked agent axis of the fleet engine


@dataclass(frozen=True)
class ShardingPolicy:
    batch_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    fsdp_axes: tuple[str, ...] = ("data",)
    seq_shard: bool = True  # sequence-parallel activations at boundaries
    remat: bool = True  # per-layer-group activation checkpointing
    tensor_parallel: bool = True  # False: model axis carries batch (pure DP)
    # perf knobs (hillclimbing)
    q_chunk: int = 512
    kv_chunk: int = 1024
    loss_chunk: int = 2048
    microbatches: int = 1  # gradient accumulation (memory knob)
    # hoist dense-FFN FSDP weight gathers out of the microbatch scan:
    # those weights are kept TP-only-sharded for the whole step, so the
    # ZeRO-3 gather is paid once per step instead of once per microbatch.
    hoist_dense_gathers: bool = False


# (pattern, per-dim template). First match wins. Templates use mesh-axis
# names or FSDP; dims beyond the template are replicated.
_RULES = [
    ("embed/tok", (MODEL, FSDP)),
    ("embed/head/w", (FSDP, MODEL)),
    ("*/wq/w", (FSDP, HEADQ)),
    ("*/wk/w", (FSDP, HEADKV)),
    ("*/wv/w", (FSDP, HEADKV)),
    ("*/wq/b", (HEADQ,)),
    ("*/wk/b", (HEADKV,)),
    ("*/wv/b", (HEADKV,)),
    ("*/wo/w", (HEADQ, FSDP)),
    ("*/wo/b", (None,)),
    ("*/wi/w", (FSDP, MODEL)),
    ("*/wg/w", (FSDP, MODEL)),
    ("*/wi/b", (MODEL,)),
    ("*/router", (None, None)),
    ("*/w1", (MODEL, FSDP, None)),
    ("*/w3", (MODEL, FSDP, None)),
    ("*/w2", (MODEL, None, FSDP)),
    ("*/in_proj/w", (FSDP, MODEL)),
    ("*/conv_w", (MODEL, None)),
    ("*/conv_b", (MODEL,)),
    ("*/x_proj/w", (MODEL, None)),
    ("*/dt_proj/w", (None, MODEL)),
    ("*/dt_proj/b", (MODEL,)),
    ("*/a_log", (MODEL, None)),
    ("*/d_skip", (MODEL,)),
    ("*/out_proj/w", (MODEL, FSDP)),
    ("*/w_if/w", (MODEL, None)),
    ("*/w_x/w", (FSDP, MODEL)),
    ("*/w_x/b", (MODEL,)),
    ("*/r_h", (None, MODEL, None, None)),
    ("*/ffn_up/w", (FSDP, MODEL)),
    ("*/ffn_down/w", (MODEL, FSDP)),
    ("*/w_dkv/w", (FSDP, None)),
    ("*/w_krope/w", (FSDP, None)),
    ("*/w_uk/w", (None, HEADQ)),
    ("*/w_uv/w", (None, HEADQ)),
    ("*/w_dq/w", (FSDP, None)),
    ("*/w_uq/w", (None, HEADQ)),
]


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def leaf_pspec(path: str, shape, mesh: Mesh, policy: ShardingPolicy, cfg=None) -> P:
    """path: 'groups/b0/mixer/wq/w'. Leading 'groups/*' gets a stacked dim."""
    stacked = path.startswith("groups/")
    core_shape = shape[1:] if stacked else shape
    template = None
    for pat, tpl in _RULES:
        if fnmatch.fnmatch(path, pat):
            template = tpl
            break
    msize = mesh.shape[policy.model_axis]
    dims = []
    used: set = set()
    for i, size in enumerate(core_shape):
        ax = template[i] if template and i < len(template) else None
        if not policy.tensor_parallel and ax in (MODEL, HEADQ, HEADKV):
            ax = FSDP  # pure-DP: weights FSDP-shard, never TP
        if ax == FSDP:
            ax = (
                policy.fsdp_axes
                if len(policy.fsdp_axes) > 1
                else (policy.fsdp_axes[0] if policy.fsdp_axes else None)
            )
        elif ax == HEADQ:
            ok = cfg is None or cfg.n_heads % msize == 0
            ax = policy.model_axis if ok else None
        elif ax == HEADKV:
            ok = cfg is None or cfg.n_kv_heads % msize == 0
            ax = policy.model_axis if ok else None
        if ax is not None and size % _axis_size(mesh, ax) != 0:
            ax = None
        # a mesh axis may appear at most once per spec
        flat = (ax,) if (ax is None or isinstance(ax, str)) else tuple(ax)
        if ax is not None and any(a in used for a in flat):
            ax = None
        else:
            used.update(a for a in flat if a)
        dims.append(ax)
    if stacked:
        dims = [None] + dims
    return P(*dims)


def tree_pspecs(tree, mesh: Mesh, policy: ShardingPolicy, cfg=None):
    """Pytree of PartitionSpecs mirroring ``tree`` (of arrays/structs)."""

    def visit(path, leaf):
        names = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        pstr = "/".join(str(n) for n in names)
        return leaf_pspec(pstr, leaf.shape, mesh, policy, cfg)

    return jax.tree_util.tree_map_with_path(visit, tree)


def tree_shardings(tree, mesh: Mesh, policy: ShardingPolicy, cfg=None):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        tree_pspecs(tree, mesh, policy, cfg),
        is_leaf=lambda x: isinstance(x, P),
    )


_HOIST_PATTERNS = ("/ffn/wi/", "/ffn/wg/", "/ffn/wo/")


def hoist_constrain(params, mesh: Mesh, policy: ShardingPolicy, cfg=None):
    """Re-constrain dense-FFN weights to their TP-only sharding (FSDP axes
    dropped) so the data-axis all-gather happens once, outside any
    microbatch scan. Other leaves pass through untouched."""
    import dataclasses

    nofsdp = dataclasses.replace(policy, fsdp_axes=())

    def visit(path, leaf):
        names = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        pstr = "/".join(str(n) for n in names)
        if any(pat in "/" + pstr + "/" for pat in _HOIST_PATTERNS):
            spec = leaf_pspec(pstr, leaf.shape, mesh, nofsdp, cfg)
            return jax.lax.with_sharding_constraint(leaf, NamedSharding(mesh, spec))
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)


def act_spec(
    policy: ShardingPolicy, mesh: Mesh | None, *, seq_len: int, mode: str
) -> P:
    """Boundary activation spec [B, S, D]."""
    if mesh is None:
        return P()
    batch = tuple(a for a in policy.batch_axes if a in mesh.axis_names)
    b_ax = batch if len(batch) > 1 else (batch[0] if batch else None)
    s_ax = None
    if (
        policy.seq_shard
        and policy.tensor_parallel
        and mode in ("train", "prefill")
        and seq_len % mesh.shape[policy.model_axis] == 0
    ):
        s_ax = policy.model_axis
    return P(b_ax, s_ax, None)


def constrain(x, mesh: Mesh | None, spec: P):
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def cache_pspec(path: str, shape, mesh: Mesh, policy: ShardingPolicy) -> P:
    """KV-cache / recurrent-state sharding.

    [B, S, H, D] k/v: batch->data; heads->model if divisible, else seq->model.
    latent/state tensors: batch->data, widest divisible dim->model.
    """
    batch = tuple(a for a in policy.batch_axes if a in mesh.axis_names)
    b_ax = batch if len(batch) > 1 else (batch[0] if batch else None)
    msize = mesh.shape[policy.model_axis]
    name = path.rsplit("/", 1)[-1]
    stacked = path.startswith("groups/")
    core = shape[1:] if stacked else shape
    if shape and core and core[0] % _axis_size(mesh, b_ax or ()) != 0:
        b_ax = None
    dims = [b_ax] + [None] * (len(core) - 1)
    if name in ("k", "v"):
        if core[2] % msize == 0:
            dims[2] = policy.model_axis
        elif core[1] % msize == 0:
            dims[1] = policy.model_axis
    elif name in ("ckv", "krope", "kpos"):
        if name != "kpos" and len(core) > 2 and core[1] % msize == 0:
            dims[1] = policy.model_axis
    elif name in ("ssm", "conv", "c", "n", "m", "h"):
        # shard the channel/head dim over model when divisible
        for i in range(1, len(core)):
            if core[i] % msize == 0 and core[i] >= msize:
                dims[i] = policy.model_axis
                break
    if stacked:
        dims = [None] + dims
    return P(*dims)


def cache_shardings(tree, mesh: Mesh, policy: ShardingPolicy):
    def visit(path, leaf):
        names = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        pstr = "/".join(str(n) for n in names)
        return NamedSharding(mesh, cache_pspec(pstr, leaf.shape, mesh, policy))

    return jax.tree_util.tree_map_with_path(visit, tree)


# ---------------------------------------------------------------------------
# Fleet-axis sharding (population parallelism for the fleet engine)
# ---------------------------------------------------------------------------
def make_fleet_mesh(n_devices: int | None = None, *, axis: str = FLEET) -> Mesh | None:
    """A 1-D device mesh for the fleet's stacked agent axis.

    ``n_devices`` caps how many local devices join (``None``/``-1`` = all
    of them); the count is rounded *down* to a power of two so the
    engine's pow2 slot buckets always divide the mesh. Returns ``None``
    when at most one device would participate — callers treat that as
    "stay on the single-device path", so ``make_fleet_mesh()`` is safe to
    call unconditionally on CPU CI.
    """
    devices = jax.devices()
    n = len(devices) if n_devices is None or n_devices < 0 else n_devices
    n = min(n, len(devices))
    if n <= 1:
        return None
    n = 1 << (n.bit_length() - 1)  # pow2 floor
    return Mesh(np.array(devices[:n]), (axis,))


@dataclass(frozen=True)
class FleetSharding:
    """Shardings of the fleet chunk's operands on a 1-D agent-axis mesh.

    The per-agent math is embarrassingly parallel, so the whole policy is
    one rule: shard the leading (agent) axis, replicate everything else.
    ``stacked`` covers every :class:`~repro.rl.fleet.FleetState` leaf and
    any ``[N, ...]`` act operand; ``indices`` is the ``[K, N, B]`` replay
    index tensor (agent axis second); ``replicated`` is the shared replay
    pool (every device reads all rows its slots may sample).
    """

    mesh: Mesh
    axis: str = FLEET

    @property
    def stacked(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axis))

    @property
    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    @property
    def indices(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(None, self.axis))

    @property
    def n_devices(self) -> int:
        return self.mesh.size

    def place(self, tree):
        """Commit a stacked pytree (leading agent axis) onto the mesh."""
        return jax.tree_util.tree_map(
            lambda x: jax.device_put(x, self.stacked), tree
        )
