"""Norms, MLPs, embeddings — shared building blocks for the zoo."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def truncated_normal(key, shape, scale, dtype):
    return (
        scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    ).astype(dtype)


def dense_init(key, d_in, d_out, dtype, *, bias=False, scale=None):
    w = truncated_normal(key, (d_in, d_out), scale or d_in**-0.5, dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# Norms (computed in f32, cast back)
# ---------------------------------------------------------------------------
def norm_init(cfg: ModelConfig, d: int):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(cfg: ModelConfig, p, x):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(x, -1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), -1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(x), -1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# MLP (swiglu or gelu variant)
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig, d_model: int, d_ff: int):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    if cfg.mlp_variant == "swiglu":
        return {
            "wi": dense_init(ks[0], d_model, d_ff, dtype),
            "wg": dense_init(ks[1], d_model, d_ff, dtype),
            "wo": dense_init(ks[2], d_ff, d_model, dtype),
        }
    return {
        "wi": dense_init(ks[0], d_model, d_ff, dtype, bias=True),
        "wo": dense_init(ks[2], d_ff, d_model, dtype, bias=True),
    }


def mlp_apply(cfg: ModelConfig, p, x):
    if cfg.mlp_variant == "swiglu":
        h = jax.nn.silu(dense_apply(p["wg"], x)) * dense_apply(p["wi"], x)
    else:
        h = jax.nn.gelu(dense_apply(p["wi"], x))
    return dense_apply(p["wo"], h)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed_init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    p = {"tok": truncated_normal(k1, (cfg.vocab_size, cfg.d_model), 0.02, dtype)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(
            k2, cfg.d_model, cfg.vocab_size, dtype, scale=cfg.d_model**-0.5
        )
    return p


def embed_apply(p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed_apply(cfg: ModelConfig, p, x):
    if cfg.tie_embeddings:
        return x @ p["tok"].T
    return dense_apply(p["head"], x)
