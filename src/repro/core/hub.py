"""Hub nodes: the homogeneous distributed experience database (Fig. 6/7).

Every agent talks only to its hub (bidirectional push/pull); hubs sync
their databases with each other periodically. A hub's database maps
erb_id -> ERB, and the Fig. 7 snapshot table is derivable from metadata.

Hub failure loses only ERBs no other hub holds; agent failure loses only
that agent's untrained round — the paper's robustness claims, which the
property tests assert.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set

import numpy as np

from repro.core.erb import ERB


@dataclass
class Hub:
    hub_id: int
    database: Dict[str, ERB] = field(default_factory=dict)
    alive: bool = True

    def push(self, erb: ERB) -> None:
        """Agent -> hub (or hub -> hub) transfer of one ERB."""
        if self.alive:
            self.database.setdefault(erb.meta.erb_id, erb)

    def pull_unseen(self, seen: Set[str]) -> List[ERB]:
        """Hub -> agent: every ERB the agent has not yet learned from."""
        if not self.alive:
            return []
        return [e for eid, e in sorted(self.database.items())
                if eid not in seen]

    def snapshot(self) -> List[dict]:
        """Fig. 7 table: one row per ERB in the shared database."""
        return [{
            "erb_id": e.meta.erb_id,
            "modality": e.meta.task.modality,
            "landmark": e.meta.task.landmark,
            "pathology": e.meta.task.pathology,
            "source_agent": e.meta.source_agent,
            "size": e.meta.size,
        } for _, e in sorted(self.database.items())]

    def fail(self) -> None:
        self.alive = False
        self.database.clear()


def sync_hubs(hubs: Sequence[Hub], rng: np.random.Generator,
              dropout: float = 0.0) -> int:
    """Periodic pairwise database sync. Each (record, dest-hub) transfer
    independently drops with probability ``dropout`` (the 75% ablation).
    Returns the number of records transferred."""
    live = [h for h in hubs if h.alive]
    transferred = 0
    for src in live:
        for dst in live:
            if src is dst:
                continue
            for eid, erb in list(src.database.items()):
                if eid in dst.database:
                    continue
                if dropout > 0.0 and rng.random() < dropout:
                    continue
                dst.push(erb)
                transferred += 1
    return transferred
