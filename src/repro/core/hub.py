"""Hub nodes: the homogeneous distributed shared database (Fig. 6/7).

Every agent talks only to its hub (bidirectional push/pull); hubs sync
their databases with each other periodically.  A hub carries one store
per :class:`~repro.core.plane.SharePlane` — the paper's ERB plane plus
any extra planes (e.g. the FedAsync-style weight plane).  Each store
maps record_id -> record; the Fig. 7 snapshot table is derivable from
ERB metadata as before, and ``Hub.database`` remains the ERB store for
backward compatibility.

Hub failure loses only records no other hub holds; agent failure loses
only that agent's untrained round — the paper's robustness claims, which
the property tests assert (now for every plane uniformly).

Hub-hub sync can account bytes-on-wire on a shared
:class:`~repro.core.gossip.BandwidthMeter` so the backbone traffic is
comparable with the gossip topology's; backbone transfer *time* is not
modeled (hubs are assumed to sit on fast interconnect).
"""

from __future__ import annotations

from collections.abc import Sequence
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core.gossip import BandwidthMeter
from repro.core.plane import ERBPlane, SharePlane

_DEFAULT_PLANE = ERBPlane()


@dataclass
class Hub:
    hub_id: int
    stores: dict[str, dict[str, Any]] = field(default_factory=dict)
    alive: bool = True

    def store(self, plane: str = "erb") -> dict[str, Any]:
        """The record_id -> record map for one plane (created on demand)."""
        return self.stores.setdefault(plane, {})

    @property
    def database(self) -> dict[str, Any]:
        """The ERB-plane store (the paper's 'distributed database')."""
        return self.store("erb")

    def push(self, item: Any, plane: SharePlane = _DEFAULT_PLANE) -> bool:
        """Agent -> hub (or hub -> hub) transfer of one record."""
        if not self.alive:
            return False
        return plane.admit(self.store(plane.name), item)

    def pull_unseen(self, seen: set[str], plane: str = "erb") -> list[Any]:
        """Hub -> agent: every record the agent has not yet consumed."""
        if not self.alive:
            return []
        return [v for k, v in sorted(self.store(plane).items()) if k not in seen]

    def snapshot(self) -> list[dict]:
        """Fig. 7 table: one row per ERB in the shared database."""
        return [
            {
                "erb_id": e.meta.erb_id,
                "modality": e.meta.task.modality,
                "landmark": e.meta.task.landmark,
                "pathology": e.meta.task.pathology,
                "source_agent": e.meta.source_agent,
                "size": e.meta.size,
            }
            for _, e in sorted(self.database.items())
        ]

    def fail(self) -> None:
        self.alive = False
        self.stores.clear()


def sync_hubs(
    hubs: Sequence[Hub],
    rng: np.random.Generator,
    dropout: float = 0.0,
    planes: Sequence[SharePlane] = (_DEFAULT_PLANE,),
    meter: BandwidthMeter | None = None,
) -> int:
    """Periodic pairwise database sync over every registered plane.

    Each (record, dest-hub) transfer independently drops with probability
    ``dropout`` (the 75% ablation).  Delivered transfers are accounted on
    ``meter`` when given.  Returns the number of records transferred."""
    live = [h for h in hubs if h.alive]
    transferred = 0
    for plane in planes:
        for src in live:
            for dst in live:
                if src is dst:
                    continue
                dst_store = dst.store(plane.name)
                for rid, rec in sorted(src.store(plane.name).items()):
                    if rid in dst_store:
                        continue
                    if dropout > 0.0 and rng.random() < dropout:
                        continue
                    if plane.admit(dst_store, rec):
                        transferred += 1
                        if meter is not None:
                            meter.account(plane.name, plane.payload_nbytes(rec))
    return transferred
