"""ADFLL system orchestration + the paper's comparison systems.

* :class:`ADFLLSystem` — the contribution: asynchronous decentralized
  federated lifelong learning over a pluggable topology (the paper's
  hub layout, hub-less gossip, or both), driven by the event-driven
  scheduler with heterogeneous agent speeds, dropout, and agent churn.
  Link time (latency + bytes/rate) of every pull/push is charged to
  simulated time, so message size shows up in the makespan.
* Agent X (all-knowing), Agent Y (partially-knowing), Agent M (traditional
  sequential lifelong learner) — Table 1 baselines.
* :class:`CentralAggregationSystem` — conventional synchronous federated
  averaging of DQN weights (the framework the paper positions against).

All of them implement the :class:`repro.experiments.protocol.System`
protocol (``run() -> Report`` + ``evaluate()``); the baselines are
wrapped as single-agent systems in ``repro.experiments.systems``.
``ADFLLSystem`` additionally supports declarative churn
(:meth:`ADFLLSystem.schedule_churn`) and emits
:class:`~repro.core.experiment.ExperimentHooks` lifecycle callbacks
(``on_round_start`` / ``on_mix`` / ``on_push`` / ``on_round_end`` /
``on_churn``) instead of hard-wiring its metrics collection.
"""

from __future__ import annotations

from collections.abc import Sequence
from dataclasses import replace

import jax
import numpy as np

from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.core.erb import TaskTag, erb_init
from repro.core.experiment import (
    ChurnEvent,
    ExperimentHooks,
    HistoryRecorder,
    HubFailure,
    Report,
    RoundRecord,
)
from repro.core.gossip import LinkModel, make_sampler
from repro.core.hub import Hub
from repro.core.network import Network
from repro.core.plane import CompressedWeightPlane, WeightPlane, staleness_alphas
from repro.core.scheduler import Scheduler
from repro.observatory import Observatory
from repro.models.sharding import make_fleet_mesh
from repro.rl.agent import DQNAgent
from repro.rl.env import LandmarkEnv
from repro.rl.fleet import FleetEngine, collect_fleet
from repro.rl.synth import make_volume
from repro.telemetry import NULL, Telemetry


def env_for(task: TaskTag, patient: int, cfg: DQNConfig) -> LandmarkEnv:
    vol, lm = make_volume(task, patient, n=cfg.volume_shape[0])
    return LandmarkEnv(vol, lm, cfg)


def evaluate_on_tasks(
    agent: DQNAgent,
    tasks: Sequence[TaskTag],
    patients: Sequence[int],
    cfg: DQNConfig,
    *,
    max_patients: int | None = 4,
    n_episodes: int = 4,
) -> dict[str, float]:
    """Mean terminal distance per task over the held-out patients.

    ``max_patients`` caps how many of ``patients`` are evaluated (None =
    all of them) and ``n_episodes`` is the greedy rollouts per patient —
    both explicit so a :class:`~repro.core.experiment.Report` can record
    exactly what its errors were measured over.
    """
    pats = list(patients) if max_patients is None else list(patients)[:max_patients]
    out = {}
    for t in tasks:
        errs = [agent.evaluate(env_for(t, p, cfg), n_episodes=n_episodes) for p in pats]
        out[t.name] = float(np.mean(errs))
    return out


def _make_weight_plane(cfg: ADFLLConfig) -> WeightPlane:
    if cfg.weight_compression == "none":
        return WeightPlane(max_versions=cfg.weight_max_versions)
    return CompressedWeightPlane(
        max_versions=cfg.weight_max_versions,
        compression=cfg.weight_compression,
        k_frac=cfg.weight_topk_frac,
    )


class ADFLLSystem:
    """The paper's deployment system (Fig. 2 topology by default).

    ``seed`` is the single source of truth for every random stream
    (defaulting to ``sys_cfg.seed``): the round rng, the network rng,
    the gossip sampler/rng, the task-curriculum rng, and each agent's
    init seed (``seed + agent_id``) all derive from it.
    """

    def __init__(
        self,
        sys_cfg: ADFLLConfig,
        dqn_cfg: DQNConfig,
        tasks: Sequence[TaskTag],
        train_patients: Sequence[int],
        *,
        seed: int | None = None,
        hooks: Sequence[ExperimentHooks] = (),
        telemetry: Telemetry | None = None,
    ):
        self.sys_cfg = sys_cfg
        self.dqn_cfg = dqn_cfg
        self.tasks = list(tasks)
        self.train_patients = list(train_patients)
        self.seed = int(sys_cfg.seed if seed is None else seed)
        # observe-only: telemetry never touches a random stream or any
        # run state, so enabled/disabled runs stay bit-identical
        self.telemetry = telemetry if telemetry is not None else NULL
        self._recorder = HistoryRecorder()
        self.hooks: tuple[ExperimentHooks, ...] = (self._recorder, *hooks)
        self.history: list[RoundRecord] = self._recorder.records
        self.rng = np.random.default_rng(self.seed)
        n_hubs = 0 if sys_cfg.topology == "gossip" else sys_cfg.n_hubs
        self.network = Network(
            hubs=[Hub(h) for h in range(n_hubs)],
            dropout=sys_cfg.dropout,
            rng=np.random.default_rng(self.seed + 1),
            topology=sys_cfg.topology,
            link=LinkModel(
                latency=sys_cfg.link_latency,
                rate=sys_cfg.link_rate,
                drop=sys_cfg.link_drop,
            ),
        )
        self.network.meter.bind(self.telemetry.registry)
        if sys_cfg.topology in ("gossip", "hybrid"):
            self.network.enable_gossip(
                make_sampler(
                    sys_cfg.gossip_sampler,
                    fanout=sys_cfg.gossip_fanout,
                    seed=self.seed + 2,
                ),
                rng=np.random.default_rng(self.seed + 3),
            )
            self.network.gossip.telemetry = self.telemetry
        if sys_cfg.engine not in ("fleet", "fleet-eager", "stepwise"):
            raise ValueError(f"unknown engine: {sys_cfg.engine!r}")
        mesh = make_fleet_mesh(sys_cfg.fleet_devices) if sys_cfg.fleet_devices else None
        self.engine: FleetEngine | None = (
            FleetEngine(dqn_cfg, mesh=mesh)
            if sys_cfg.engine.startswith("fleet")
            else None
        )
        if self.engine is not None:
            self.engine.telemetry = self.telemetry
        # the observatory rides the telemetry bundle: enabled telemetry
        # means per-agent learning dynamics, propagation tracking, and
        # health detection — all observe-only, like telemetry itself
        self.observatory: Observatory | None = (
            Observatory(self.telemetry) if self.telemetry.enabled else None
        )
        if self.observatory is not None and self.engine is not None:
            self.engine.observatory = self.observatory
        if self.observatory is not None and self.network.gossip is not None:
            prop = self.observatory.propagation
            self.network.gossip.on_deliver = prop.on_gossip_deliver
        self.use_erb = "erb" in sys_cfg.share_planes
        self.use_weights = "weights" in sys_cfg.share_planes
        if self.use_weights:
            self.network.register_plane(_make_weight_plane(sys_cfg))
        if sys_cfg.task_curriculum not in ("roundrobin", "blocked", "shuffled"):
            raise ValueError(f"unknown curriculum: {sys_cfg.task_curriculum!r}")
        self._task_rng = np.random.default_rng(self.seed + 4)
        self._task_queue: list[int] = []
        self.agents: dict[int, DQNAgent] = {}
        self.sched = Scheduler(telemetry=self.telemetry)
        if self.engine is not None:
            self.engine.sim_clock = lambda: self.sched.now
        self._tel_off_since: dict[int, float] = {}  # open offline windows
        self._task_cursor = 0
        self._next_agent_id = 0
        self._outstanding = 0  # finish events not yet processed
        self._pending_churn = 0  # scheduled churn events not yet applied
        self._pending_failures = 0  # scheduled hub failures not yet applied
        # population simulation: availability bookkeeping (set lazily by
        # repro.population.compile_onto) and rounds deferred while offline
        self.population = None
        self._deferred: set = set()
        if self.network.gossip is not None:
            # availability view: anti-entropy never samples an offline peer
            self.network.gossip.online = self._agent_is_online
        for i in range(sys_cfg.n_agents):
            hub = sys_cfg.agent_hub[i] if i < len(sys_cfg.agent_hub) else None
            self.add_agent(
                speed=(
                    sys_cfg.agent_speed[i] if i < len(sys_cfg.agent_speed) else 1.0
                ),
                hub_id=hub,
                at=0.0,
            )
        if sys_cfg.topology != "gossip":
            self.sched.every(
                sys_cfg.hub_sync_period,
                lambda s, t: self.network.sync(),
                tag="hub_sync",
            )
        if self.network.gossip is not None:
            self.sched.every(
                sys_cfg.gossip_period,
                lambda s, t: self.network.gossip.anti_entropy(s),
                tag="gossip",
            )

    # -- hooks ----------------------------------------------------------------
    def _emit(self, name: str, *args) -> None:
        for h in self.hooks:
            getattr(h, name)(self, *args)

    # -- membership -----------------------------------------------------------
    def add_agent(
        self,
        *,
        speed: float = 1.0,
        hub_id: int | None = None,
        at: float | None = None,
    ) -> int:
        aid = self._next_agent_id
        self._next_agent_id += 1
        agent = DQNAgent(
            aid,
            self.dqn_cfg,
            seed=self.seed + aid,
            speed=speed,
            backend="fleet" if self.engine is not None else "stepwise",
            engine=self.engine,
        )
        self.agents[aid] = agent
        if self.observatory is not None:
            if self.engine is not None:
                self.observatory.register_slot(agent.slot, aid)
            self.observatory.propagation.note_round(aid, 0)
        self.network.attach_agent(aid, hub_id)
        t = self.sched.now if at is None else at
        if self.population is not None:
            self.population.note_join(aid, t, speed)
        self.sched.at(t, lambda s, tt, a=aid: self._start_round(a), tag=f"A{aid}_join")
        return aid

    def remove_agent(self, agent_id: int):
        agent = self.agents[agent_id]
        if self.engine is not None:
            # retire the departing agent's in-flight round now so its
            # record lands in the same history position as sequential
            self.engine.ensure_flushed(agent.slot)
        agent.active = False
        self._deferred.discard(agent_id)
        if self.population is not None:
            self.population.note_depart(agent_id, self.sched.now)
        self.network.detach_agent(agent_id)

    def live_agents(self) -> dict[int, DQNAgent]:
        return {
            aid: a
            for aid, a in self.agents.items()
            if getattr(a, "active", True) is not False
        }

    # -- availability ---------------------------------------------------------
    def set_online(self, agent_id: int, online: bool) -> None:
        """Flip one agent's availability.  Offline agents keep in-flight
        rounds (disconnection granularity is one round) but start no new
        ones; coming back online resumes a round deferred while away.
        Emits ``on_availability`` on every state *change*."""
        agent = self.agents.get(agent_id)
        if agent is None or getattr(agent, "active", True) is False:
            return
        was = getattr(agent, "online", True)
        agent.online = online
        if self.population is not None:
            self.population.note_toggle(agent_id, online, self.sched.now)
        if online == was:
            return
        now = self.sched.now
        if self.telemetry.enabled:
            track = f"agent{agent_id}"
            self.telemetry.instant(
                "online" if online else "offline", track, now, agent=agent_id
            )
            self.telemetry.count("availability.toggles", 1, agent=agent_id)
            if online:
                t0 = self._tel_off_since.pop(agent_id, None)
                if t0 is not None:
                    self.telemetry.span("offline", track, t0, now, agent=agent_id)
            else:
                self._tel_off_since[agent_id] = now
        self._emit("on_availability", agent_id, online, now)
        if online and agent_id in self._deferred:
            self._deferred.discard(agent_id)
            self._start_round(agent_id)

    def _agent_is_online(self, agent_id: int) -> bool:
        """The gossip layer's availability view: live *and* online."""
        agent = self.agents.get(agent_id)
        return (
            agent is not None
            and getattr(agent, "active", True) is not False
            and getattr(agent, "online", True) is not False
        )

    # -- population -----------------------------------------------------------
    def apply_population(self, pop) -> None:
        """Compile a :class:`~repro.population.PopulationSpec` onto the
        scheduler: cohort arrivals, availability processes, departures,
        and hub outages all become ordinary events feeding the churn
        machinery.  This is the one entry point for population dynamics;
        :meth:`schedule_churn` / :meth:`schedule_hub_failures` are thin
        shims over it."""
        from repro.population.compile import compile_onto

        compile_onto(self, pop)

    def schedule_churn(self, events: Sequence[ChurnEvent]) -> None:
        """Classic churn shim: lifts the events into a
        :class:`~repro.population.PopulationSpec` (point-arrival cohorts
        and departures) and compiles it — bit-identical scheduling to the
        historical hand-rolled path.  Each event fires on the scheduler
        at its time and emits ``on_churn``; the run does not stop while
        churn events are still pending, so late joiners get their rounds
        even if the incumbents finished first."""
        if not events:
            return
        from repro.population.spec import PopulationSpec

        self.apply_population(PopulationSpec.from_churn(events))

    def _apply_churn(self, ev: ChurnEvent, t: float) -> list[int]:
        self._pending_churn -= 1
        ids: list[int] = []
        if ev.action == "add":
            for _ in range(ev.count):
                ids.append(self.add_agent(speed=ev.speed, hub_id=ev.hub))
        else:
            for _ in range(ev.count):
                aid = ev.agent_id
                live = self.live_agents()
                if aid is None:
                    if not live:
                        break
                    aid = max(live)  # newest joiner leaves first
                elif aid not in live:
                    break  # unknown/already-departed id: nothing to remove
                self.remove_agent(aid)
                ids.append(aid)
        if self.telemetry.enabled and ids:
            self.telemetry.instant(
                f"churn.{ev.action}", "population", t, agents=ids
            )
            self.telemetry.count("churn.events", 1, action=ev.action)
        self._emit("on_churn", ev, ids, t)
        return ids

    # -- hub failures -----------------------------------------------------------
    def schedule_hub_failures(self, events: Sequence[HubFailure]) -> None:
        """Classic hub-failure shim (the paper's Table 2 robustness
        experiment): lifts the events into hub outages on a
        :class:`~repro.population.PopulationSpec` and compiles it.  Bad
        schedules raise before anything touches the scheduler; each
        outage kills its hub at its time and emits ``on_hub_failure``.
        The run does not stop while failures are pending, so a failure
        landing after the incumbents' last round still fires."""
        if not events:
            return
        from repro.population.spec import PopulationSpec

        self.apply_population(PopulationSpec.from_churn(hub_failures=events))

    def _apply_hub_failure(self, ev: HubFailure, t: float) -> None:
        self._pending_failures -= 1
        orphaned = self.network.fail_hub(ev.hub_id)
        if self.telemetry.enabled:
            self.telemetry.instant(
                "hub.failure", "population", t, hub=ev.hub_id, orphaned=orphaned
            )
            self.telemetry.count("hub.failures", 1)
        self._emit("on_hub_failure", ev, orphaned, t)

    # -- round machinery --------------------------------------------------------
    def _next_task(self) -> TaskTag:
        """The scenario's task curriculum: round-robin (the paper),
        blocked (one task per cohort of agents before moving on), or a
        seeded shuffle of each full pass."""
        cur = self.sys_cfg.task_curriculum
        if cur == "roundrobin":
            idx = self._task_cursor % len(self.tasks)
        elif cur == "blocked":
            block = max(1, self.sys_cfg.n_agents)
            idx = (self._task_cursor // block) % len(self.tasks)
        else:  # shuffled
            if not self._task_queue:
                self._task_queue = list(self._task_rng.permutation(len(self.tasks)))
            idx = int(self._task_queue.pop())
        self._task_cursor += 1
        return self.tasks[idx]

    def _round_duration(self, agent: DQNAgent, n_incoming: int) -> float:
        """Simulated wall time of one round: base cost grows with replay
        volume; divided by hardware speed."""
        base = 1.0 + 0.1 * n_incoming
        jitter = float(self.rng.uniform(0.9, 1.1))
        return base * jitter / agent.speed

    def _start_round(self, agent_id: int):
        agent = self.agents.get(agent_id)
        if agent is None or getattr(agent, "active", True) is False:
            return
        if agent.rounds_done >= self.sys_cfg.rounds:
            return
        if getattr(agent, "online", True) is False:
            # offline: park the round; set_online(True) resumes it
            self._deferred.add(agent_id)
            if self.telemetry.enabled:
                self.telemetry.instant(
                    "round.deferred", f"agent{agent_id}", self.sched.now
                )
                self.telemetry.count("rounds.deferred", 1)
            return
        task = self._next_task()
        self._emit("on_round_start", agent_id, task, self.sched.now)
        patient = int(self.rng.choice(self.train_patients))
        env = env_for(task, patient, self.dqn_cfg)
        comm = 0.0
        if self.use_erb:
            pulled = self.network.agent_pull(agent_id, agent.seen_erb_ids)
            incoming = list(pulled.records)
            comm += pulled.comm_time
            if self.observatory is not None and incoming:
                self.observatory.propagation.note_erb_consumed(
                    agent_id, incoming, self.sched.now
                )
        else:
            incoming = []
        if self.use_weights:
            n_mixed, mix_comm = self._mix_peer_weights(agent_id)
            comm += mix_comm
            if n_mixed:
                self._emit("on_mix", agent_id, n_mixed, mix_comm, self.sched.now)
        else:
            n_mixed = 0
        start = self.sched.now
        shared, future = agent.begin_round(
            env,
            task,
            incoming,
            erb_capacity=self.sys_cfg.erb_capacity,
            share_size=self.sys_cfg.erb_share_size,
            train_steps=self.sys_cfg.train_steps_per_round,
        )
        if self.sys_cfg.engine == "fleet-eager" and self.engine is not None:
            self.engine.flush()
        dur = self._round_duration(agent, len(incoming)) + comm
        end = start + dur
        # the round record is complete except for the loss, which the
        # fleet engine produces at flush time; futures resolve in
        # submission order, so history order matches sequential driving
        round_idx = agent.rounds_done - 1
        n_incoming = len(incoming)
        if self.telemetry.enabled:
            self.telemetry.span(
                "round",
                f"agent{agent_id}",
                start,
                end,
                task=task.name,
                round_idx=round_idx,
                n_incoming=n_incoming,
                n_mixed=n_mixed,
                comm=comm,
            )
            self.telemetry.count("rounds.started", 1)
            self.telemetry.observe("round.duration", dur)
            self.telemetry.observe("round.incoming", n_incoming)
            if n_mixed:
                self.telemetry.count("mix.snapshots", n_mixed)

        def emit_record(loss):
            self._emit(
                "on_round_end",
                RoundRecord(
                    agent_id,
                    round_idx,
                    task.name,
                    start,
                    end,
                    n_incoming,
                    loss,
                    n_mixed,
                    comm,
                ),
            )

        future.on_done(emit_record)

        def finish(s: Scheduler, t: float, aid=agent_id, erb=shared):
            self._outstanding -= 1
            # an agent removed mid-round shares nothing: its untrained round
            # is lost (the paper's failure semantics), and it is no longer
            # attached to any hub or gossip store anyway
            a = self.agents.get(aid)
            if a is None or getattr(a, "active", True) is False:
                return
            obs = self.observatory
            comm_out = 0.0
            if self.use_erb:
                if obs is not None:
                    # stamp BrainTorrent-style provenance (observe-only:
                    # the default empty vector is never read numerically)
                    erb.meta = replace(
                        erb.meta, version_vector=obs.propagation.version_vector()
                    )
                    obs.propagation.note_erb_push(aid, erb, t)
                res = self.network.agent_push(aid, erb)
                comm_out += res.comm_time
                if self.telemetry.enabled and res.comm_time > 0.0:
                    self.telemetry.span(
                        "push.erb", f"agent{aid}", t, t + res.comm_time
                    )
                self._emit("on_push", aid, "erb", res, t)
            if self.use_weights:
                snap = a.snapshot_params(t)
                if obs is not None:
                    snap = replace(
                        snap, version_vector=obs.propagation.version_vector()
                    )
                    obs.propagation.note_snapshot_push(aid, snap, t)
                res = self.network.agent_push(aid, snap, plane="weights")
                comm_out += res.comm_time
                if self.telemetry.enabled and res.comm_time > 0.0:
                    self.telemetry.span(
                        "push.weights", f"agent{aid}", t, t + res.comm_time
                    )
                self._emit("on_push", aid, "weights", res, t)
            if comm_out > 0.0:
                # the upload occupies the agent's link before its next round
                s.at(
                    t + comm_out,
                    lambda s2, t2, a2=aid: self._maybe_continue(a2),
                    tag=f"A{aid}_push_done",
                )
            else:
                self._maybe_continue(aid)

        self._outstanding += 1
        self.sched.at(end, finish, tag=f"A{agent_id}_round_done")

    def _mix_peer_weights(self, agent_id: int) -> tuple[int, float]:
        """Pull unseen peer snapshots and fold them into the agent's
        params, staleness-discounted (FedAsync alpha*s(dtau)); compressed
        snapshots are dequantized inside the mix.  Returns the number of
        snapshots consumed and the pull's link time."""
        agent = self.agents[agent_id]
        res = self.network.agent_pull(agent_id, agent.seen_snap_ids, plane="weights")
        snaps = list(res.records)
        if not snaps:
            return 0, res.comm_time
        cfg = self.sys_cfg
        now = self.sched.now if cfg.staleness_clock == "time" else agent.rounds_done
        alphas = staleness_alphas(
            snaps,
            now,
            alpha=cfg.mix_alpha,
            flag=cfg.staleness_flag,
            hinge_a=cfg.staleness_hinge_a,
            hinge_b=cfg.staleness_hinge_b,
            poly_a=cfg.staleness_poly_a,
            clock=cfg.staleness_clock,
        )
        if self.observatory is not None:
            self.observatory.propagation.note_mix(
                agent_id, snaps, alphas, now, cfg.staleness_clock
            )
        return agent.mix_params(snaps, alphas), res.comm_time

    def _maybe_continue(self, agent_id: int):
        """Paper policy: start a new round whenever unseen ERBs exist (or a
        fresh task remains); otherwise poll again after the next sync."""
        agent = self.agents.get(agent_id)
        if agent is None or getattr(agent, "active", True) is False:
            return
        if agent.rounds_done >= self.sys_cfg.rounds:
            return
        self._start_round(agent_id)

    # -- run ------------------------------------------------------------------
    def run(self, until: float = 1e6) -> Report:
        def done() -> bool:
            return (
                self._outstanding == 0
                and self._pending_churn == 0
                and self._pending_failures == 0
                and all(
                    a.rounds_done >= self.sys_cfg.rounds
                    for a in self.agents.values()
                    if getattr(a, "active", True)
                )
            )

        t = self.sched.run(until=until, stop=done)
        if self.engine is not None:
            self.engine.flush()  # retire in-flight rounds before reporting
        self.network.sync()
        return self.report(makespan=t)

    def report(self, *, makespan: float) -> Report:
        """Assemble the run-side :class:`Report` (evaluation fields are
        filled by the runner via :meth:`evaluate`)."""
        hist = list(self.history)
        meter = self.network.meter
        extra = {}
        if self.network.gossip is not None:
            st = self.network.gossip.stats
            extra["gossip"] = {
                "rounds": st.n_rounds,
                "exchanges": st.n_exchanges,
                "sent": st.n_sent,
                "delivered": st.n_delivered,
                "dropped": st.n_dropped,
            }
        if self.population is not None:
            extra["population"] = self.population.summary(float(makespan))
        if self.telemetry.enabled:
            extra["telemetry"] = self.telemetry.summary()
        if self.observatory is not None:
            extra.update(self.observatory.report_extra(makespan=float(makespan)))
        return Report(
            system="adfll",
            seed=self.seed,
            makespan=float(makespan),
            n_rounds=len(hist),
            comm_time=float(sum(r.comm_time for r in hist)),
            history=hist,
            n_mixed=sum(r.n_mixed for r in hist),
            n_foreign_erbs=sum(r.n_incoming for r in hist),
            bytes_by_plane=dict(meter.bytes_by_plane),
            msgs_by_plane=dict(meter.msgs_by_plane),
            plane_pushed=dict(self.network.plane_pushed),
            records_known={
                p: len(self.network.all_known(p)) for p in sorted(self.network.planes)
            },
            extra=extra,
        )

    # -- evaluation -----------------------------------------------------------
    def evaluate(
        self,
        tasks: Sequence[TaskTag],
        patients: Sequence[int],
        *,
        max_patients: int | None = 4,
        n_episodes: int = 4,
    ) -> dict[str, dict[str, float]]:
        """Per-live-agent mean terminal distance per task (labels follow
        the paper's 1-based numbering: agent 0 is ``"Agent1"``)."""
        return {
            f"Agent{aid + 1}": evaluate_on_tasks(
                agent,
                tasks,
                patients,
                self.dqn_cfg,
                max_patients=max_patients,
                n_episodes=n_episodes,
            )
            for aid, agent in sorted(self.live_agents().items())
        }


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------
def train_all_knowing(
    dqn_cfg: DQNConfig,
    tasks: Sequence[TaskTag],
    patients: Sequence[int],
    *,
    steps_per_task: int = 150,
    erb_capacity: int = 2048,
    seed: int = 100,
) -> DQNAgent:
    """Agent X: all datasets available at once, ONE round over the union."""
    agent = DQNAgent(-1, dqn_cfg, seed=seed)
    rng = np.random.default_rng(seed)
    erbs = []
    for t in tasks:
        env = env_for(t, int(rng.choice(patients)), dqn_cfg)
        erb = erb_init(erb_capacity, dqn_cfg.box_size, task=t)
        agent.collect(env, erb, n_episodes=24)
        erbs.append(erb)
    # one round of training over the union (current pool = all ERBs)
    agent.personal_erbs = erbs
    agent.train_steps(steps_per_task * len(tasks), None, ())
    return agent


def train_partial(
    dqn_cfg: DQNConfig,
    task: TaskTag,
    patients: Sequence[int],
    *,
    steps: int = 150,
    erb_capacity: int = 2048,
    seed: int = 200,
) -> DQNAgent:
    """Agent Y: a single dataset, a single round."""
    agent = DQNAgent(-2, dqn_cfg, seed=seed)
    rng = np.random.default_rng(seed)
    env = env_for(task, int(rng.choice(patients)), dqn_cfg)
    erb = erb_init(erb_capacity, dqn_cfg.box_size, task=task)
    agent.collect(env, erb, n_episodes=24)
    agent.train_steps(steps, erb, ())
    return agent


def train_sequential_ll(
    dqn_cfg: DQNConfig,
    tasks: Sequence[TaskTag],
    patients: Sequence[int],
    *,
    steps_per_round: int = 150,
    erb_capacity: int = 2048,
    seed: int = 300,
) -> DQNAgent:
    """Agent M: traditional lifelong learner — tasks arrive sequentially,
    replay over personal past ERBs only (no federation)."""
    agent = DQNAgent(-3, dqn_cfg, seed=seed)
    rng = np.random.default_rng(seed)
    for t in tasks:
        env = env_for(t, int(rng.choice(patients)), dqn_cfg)
        agent.train_round(
            env,
            t,
            incoming=(),
            erb_capacity=erb_capacity,
            share_size=1,  # nothing is shared
            train_steps=steps_per_round,
        )
    return agent


class CentralAggregationSystem:
    """Conventional synchronous FedAvg over DQN weights: all agents train
    locally for a round, a central server averages, repeat. The contrast
    system for DESIGN.md §1 (requires homogeneous architectures and a
    central node — both restrictions ADFLL removes).

    Implements the ``System`` protocol: ``run()`` executes ``rounds``
    synchronous rounds and returns a :class:`Report`; ``evaluate()``
    reports the shared post-aggregation model under the ``"FedAvg"``
    label (after a sync round every agent holds identical parameters).
    """

    def __init__(
        self,
        n_agents: int,
        dqn_cfg: DQNConfig,
        tasks: Sequence[TaskTag],
        patients: Sequence[int],
        *,
        rounds: int = 3,
        steps: int = 150,
        erb_capacity: int = 2048,
        seed: int = 400,
        devices: int = 0,
    ):
        self.dqn_cfg = dqn_cfg
        self.tasks = list(tasks)
        self.patients = list(patients)
        self.rounds = rounds
        self.steps = steps
        self.erb_capacity = erb_capacity
        self.seed = seed
        mesh = make_fleet_mesh(devices) if devices else None
        engine = FleetEngine(dqn_cfg, mesh=mesh)  # one stacked fleet for the cohort
        self.agents = [
            DQNAgent(i, dqn_cfg, seed=seed + i, engine=engine) for i in range(n_agents)
        ]
        self.rng = np.random.default_rng(seed)

    def round(
        self,
        round_idx: int,
        *,
        steps: int | None = None,
        erb_capacity: int | None = None,
    ):
        steps = self.steps if steps is None else steps
        erb_capacity = self.erb_capacity if erb_capacity is None else erb_capacity
        agents = self.agents
        tasks = [
            self.tasks[(round_idx * len(agents) + i) % len(self.tasks)]
            for i in range(len(agents))
        ]
        # the cohort's patient draws come off self.rng exactly as the
        # per-agent loop drew them (collection uses per-agent streams, so
        # hoisting the draws changes nothing)
        envs = [
            env_for(t, int(self.rng.choice(self.patients)), self.dqn_cfg)
            for t in tasks
        ]
        erbs = [
            erb_init(
                erb_capacity,
                self.dqn_cfg.box_size,
                task=t,
                source_agent=i,
                round_idx=round_idx,
            )
            for i, t in enumerate(tasks)
        ]
        if agents and agents[0].engine is not None:
            # sync baselines scale like the fleet: ONE stacked greedy-act
            # dispatch per env step collects for the whole cohort, and
            # submit-only training makes the round a single batched flush,
            # forced by the params read during aggregation
            collect_fleet(agents, envs, erbs, n_episodes=24)
            for agent, erb in zip(agents, erbs, strict=True):
                agent._submit_steps(steps, erb, ())
                agent.personal_erbs.append(erb)
        else:
            for agent, env, erb in zip(agents, envs, erbs, strict=True):
                agent.collect(env, erb, n_episodes=24)
                agent.train_steps(steps, erb, ())
                agent.personal_erbs.append(erb)
        # synchronous central aggregation (the bottleneck ADFLL removes)
        mean_params = jax.tree_util.tree_map(
            lambda *xs: sum(xs) / len(xs), *[a.params for a in self.agents]
        )
        for a in self.agents:
            a.params = mean_params
            a.target_params = mean_params

    def run(self) -> Report:
        for r in range(self.rounds):
            self.round(r)
        return Report(
            system="fedavg",
            seed=self.seed,
            n_rounds=self.rounds * len(self.agents),
        )

    def evaluate(
        self,
        tasks: Sequence[TaskTag],
        patients: Sequence[int],
        *,
        max_patients: int | None = 4,
        n_episodes: int = 4,
    ) -> dict[str, dict[str, float]]:
        return {
            "FedAvg": evaluate_on_tasks(
                self.agents[0],
                tasks,
                patients,
                self.dqn_cfg,
                max_patients=max_patients,
                n_episodes=n_episodes,
            )
        }


__all__ = [
    "ADFLLSystem",
    "CentralAggregationSystem",
    "RoundRecord",
    "env_for",
    "evaluate_on_tasks",
    "train_all_knowing",
    "train_partial",
    "train_sequential_ll",
]
