"""Hub-less gossip topology: peer sampling, link models, bandwidth accounting.

BrainTorrent (Roy et al., 1905.06731) showed fully peer-to-peer federated
learning for medical imaging; flwr-serverless (Namjoshi et al., 2023)
demonstrates asynchronous serverless aggregation at scale.  This module
gives the simulation that endpoint: every agent keeps a local per-plane
store and reconciles it with sampled peers in anti-entropy push-pull
rounds driven by the event scheduler — no hub in the loop.

Three pieces compose:

* :class:`PeerSampler` policies (static ring, random-k, full mesh, and a
  time-varying exponential graph) pick who talks to whom each round;
* :class:`LinkModel` prices every message (fixed latency plus
  ``bytes / rate``) and drops it with a configurable probability, so
  simulated time genuinely reflects payload size;
* :class:`BandwidthMeter` accounts bytes-on-wire per plane.  The meter is
  shared with the hub path in :class:`~repro.core.network.Network`, so hub
  and gossip transport costs are directly comparable in benchmarks.

Records ride the same :class:`~repro.core.plane.SharePlane` registry as
the hub topology: dedup/retention (``plane.admit``), wire encoding
(``plane.encode``), and payload sizing (``plane.payload_nbytes``) apply
identically, which is what makes ``topology="hybrid"`` coherent.
"""

from __future__ import annotations

import math
from collections.abc import Callable, Sequence
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core.plane import SharePlane
from repro.telemetry import NULL, MetricsRegistry, Telemetry

# ---------------------------------------------------------------------------
# link + bandwidth accounting
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LinkModel:
    """Per-message cost model: ``latency + nbytes / rate``, p(drop).

    The default link is free and lossless, which keeps every pre-existing
    hub-topology behavior (and its event timings) bit-identical.
    """

    latency: float = 0.0
    rate: float = math.inf  # bytes per unit of simulated time
    drop: float = 0.0  # per-message drop probability

    def transfer_time(self, nbytes: int) -> float:
        if math.isinf(self.rate):
            return self.latency
        return self.latency + float(nbytes) / self.rate


@dataclass
class SiteLinks:
    """Per-link heterogeneous rates: fast intra-site, slow cross-site.

    Agents (and hubs) are assigned to sites; a message between two
    endpoints on the same site is priced by ``intra``, one crossing
    sites by ``inter``, and any endpoint without a site assignment falls
    back to ``default``.  One instance is shared between
    :class:`~repro.core.network.Network` (agent-hub legs) and
    :class:`GossipTopology` (agent-agent legs) so the whole topology
    sees one consistent link map.
    """

    default: LinkModel
    agent_site: dict[int, int] = field(default_factory=dict)
    hub_site: dict[int, int] = field(default_factory=dict)
    intra: LinkModel | None = None
    inter: LinkModel | None = None

    def _pick(self, same_site: bool | None) -> LinkModel:
        if same_site is None:
            return self.default
        if same_site:
            return self.intra if self.intra is not None else self.default
        return self.inter if self.inter is not None else self.default

    def agent_hub(self, agent_id: int, hub_id: int | None) -> LinkModel:
        sa = self.agent_site.get(agent_id)
        sh = self.hub_site.get(hub_id) if hub_id is not None else None
        if sa is None or sh is None:
            return self._pick(None)
        return self._pick(sa == sh)

    def pair(self, a: int, b: int) -> LinkModel:
        sa, sb = self.agent_site.get(a), self.agent_site.get(b)
        if sa is None or sb is None:
            return self._pick(None)
        return self._pick(sa == sb)


class BandwidthMeter:
    """Bytes/messages that crossed a link, keyed by plane name.

    Since the telemetry subsystem landed, the meter is a thin view over
    ``comm.bytes`` / ``comm.msgs`` counter series in a
    :class:`~repro.telemetry.MetricsRegistry`.  It owns a private,
    always-enabled registry by default so run semantics (the per-plane
    byte totals in :class:`~repro.core.experiment.Report`) never depend
    on telemetry being switched on; :meth:`bind` rebases it onto a run
    registry so the same totals also appear in exported traces.
    The ``bytes_by_plane`` / ``msgs_by_plane`` / ``total_bytes``
    interface is unchanged.
    """

    def __init__(self, registry: MetricsRegistry | None = None):
        if registry is None or not registry.enabled:
            registry = MetricsRegistry(max_series=64)
        self._registry = registry

    def bind(self, registry: MetricsRegistry) -> None:
        """Account onto ``registry`` from now on (ignored when disabled —
        a NullRegistry would silently drop the run's byte totals)."""
        if registry.enabled:
            self._registry = registry

    def account(self, plane: str, nbytes: int) -> None:
        self._registry.count("comm.bytes", int(nbytes), plane=plane)
        self._registry.count("comm.msgs", 1, plane=plane)

    @property
    def bytes_by_plane(self) -> dict[str, int]:
        by = self._registry.counters_by_label("comm.bytes", "plane")
        return {k: int(v) for k, v in sorted(by.items())}

    @property
    def msgs_by_plane(self) -> dict[str, int]:
        by = self._registry.counters_by_label("comm.msgs", "plane")
        return {k: int(v) for k, v in sorted(by.items())}

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_plane.values())


# ---------------------------------------------------------------------------
# peer-sampling policies
# ---------------------------------------------------------------------------


class PeerSampler:
    """Picks gossip partners for one agent in one anti-entropy round."""

    name = "base"

    def new_round(self, t: float) -> None:
        """Hook called once per anti-entropy round (time-varying policies)."""

    def peers(self, agent_id: int, ids: Sequence[int]) -> list[int]:
        raise NotImplementedError


class RingSampler(PeerSampler):
    """Static directed ring: each agent exchanges with its ``fanout``
    successors in sorted-id order."""

    name = "ring"

    def __init__(self, fanout: int = 1):
        self.fanout = max(1, int(fanout))

    def peers(self, agent_id: int, ids: Sequence[int]) -> list[int]:
        ring = sorted(ids)
        if agent_id not in ring or len(ring) < 2:
            return []
        i = ring.index(agent_id)
        k = min(self.fanout, len(ring) - 1)
        return [ring[(i + s) % len(ring)] for s in range(1, k + 1)]


class RandomKSampler(PeerSampler):
    """``k`` distinct uniform peers per agent per round (seeded)."""

    name = "random"

    def __init__(self, k: int = 2, seed: int = 0):
        self.k = max(1, int(k))
        self.rng = np.random.default_rng(seed)

    def peers(self, agent_id: int, ids: Sequence[int]) -> list[int]:
        others = sorted(x for x in ids if x != agent_id)
        if not others:
            return []
        k = min(self.k, len(others))
        pick = self.rng.choice(len(others), size=k, replace=False)
        return [others[int(j)] for j in sorted(pick)]


class FullMeshSampler(PeerSampler):
    """Every agent exchanges with every other agent (n^2 baseline)."""

    name = "full"

    def peers(self, agent_id: int, ids: Sequence[int]) -> list[int]:
        return [x for x in sorted(ids) if x != agent_id]


class TimeVaryingSampler(PeerSampler):
    """One-peer time-varying exponential graph: at round ``r`` every agent
    talks to the peer ``2**(r mod ceil(log2 n))`` hops ahead on the id
    ring, so a record provably reaches all ``n`` agents in O(log n)
    rounds with constant per-round degree."""

    name = "timevary"

    def __init__(self):
        self._round = -1

    def new_round(self, t: float) -> None:
        self._round += 1

    def peers(self, agent_id: int, ids: Sequence[int]) -> list[int]:
        ring = sorted(ids)
        n = len(ring)
        if agent_id not in ring or n < 2:
            return []
        n_offsets = max(1, math.ceil(math.log2(n)))
        offset = 2 ** (max(0, self._round) % n_offsets) % n
        offset = offset or 1
        return [ring[(ring.index(agent_id) + offset) % n]]


def make_sampler(name: str, *, fanout: int = 2, seed: int = 0) -> PeerSampler:
    """Factory keyed by ``ADFLLConfig.gossip_sampler``."""
    if name == "ring":
        return RingSampler(fanout=fanout)
    if name == "random":
        return RandomKSampler(k=fanout, seed=seed)
    if name == "full":
        return FullMeshSampler()
    if name == "timevary":
        return TimeVaryingSampler()
    raise ValueError(f"unknown peer sampler: {name!r}")


# ---------------------------------------------------------------------------
# the topology
# ---------------------------------------------------------------------------


@dataclass
class GossipStats:
    n_rounds: int = 0
    n_exchanges: int = 0
    n_sent: int = 0
    n_delivered: int = 0
    n_dropped: int = 0


class GossipTopology:
    """Peer-to-peer record exchange over per-agent local stores.

    Agents publish records into their own store (``insert_local``) and
    consume from it (``pull_local``) — both free, they are node-local.
    Replication happens in :meth:`anti_entropy` rounds: each agent
    reconciles with peers chosen by the sampler, both directions
    (push-pull), one message per missing record.  Every message is
    priced by the :class:`LinkModel` and accounted on the shared
    :class:`BandwidthMeter`; with a scheduler attached, a record lands at
    ``now + latency + nbytes / rate``, so large payloads genuinely
    propagate later in simulated time.

    Unlike the hub topology, a departing agent takes its local store
    with it: knowledge survives only if it has already gossiped out —
    the honest BrainTorrent trade-off.
    """

    def __init__(
        self,
        planes: dict[str, SharePlane],
        sampler: PeerSampler,
        *,
        link: LinkModel | None = None,
        meter: BandwidthMeter | None = None,
        rng: np.random.Generator | None = None,
        site_links: SiteLinks | None = None,
        online: Callable[[int], bool] | None = None,
        telemetry: Telemetry | None = None,
    ):
        self.planes = planes  # shared registry (same dict as Network.planes)
        self.sampler = sampler
        self.link = link if link is not None else LinkModel()
        self.meter = meter if meter is not None else BandwidthMeter()
        self.telemetry = telemetry if telemetry is not None else NULL
        self.site_links = site_links  # shared with Network.configure_sites
        self.rng = rng if rng is not None else np.random.default_rng(0)
        self.stores: dict[int, dict[str, dict[str, Any]]] = {}
        self.stats = GossipStats()
        # availability view (population simulator): when set, anti-entropy
        # rounds run over online agents only — an offline peer is neither
        # sampled nor initiates an exchange.  Its local store stays put (a
        # mailbox for in-flight deliveries); None = everyone reachable.
        self.online = online
        # observe-only delivery hook (the observatory's coverage curves):
        # called as on_deliver(dst, rec, plane_name, arrival_time) after
        # every *admitted* delivery.  None costs one attribute check.
        self.on_deliver: Callable[[int, Any, str, float], None] | None = None

    # -- membership ---------------------------------------------------------
    def add_agent(self, agent_id: int) -> None:
        self.stores.setdefault(agent_id, {})

    def remove_agent(self, agent_id: int) -> None:
        self.stores.pop(agent_id, None)

    def local_store(self, agent_id: int, plane: str) -> dict[str, Any]:
        """The agent's own store for one plane ({} if the agent has left —
        never re-created, so departed agents stay departed)."""
        agent = self.stores.get(agent_id)
        if agent is None:
            return {}
        return agent.setdefault(plane, {})

    # -- node-local publish/consume ----------------------------------------
    def insert_local(self, agent_id: int, item: Any, plane: SharePlane) -> bool:
        """Publish one (already encoded) record into the agent's own store."""
        if agent_id not in self.stores:
            return False
        return plane.admit(self.local_store(agent_id, plane.name), item)

    def pull_local(self, agent_id: int, seen: set[str], plane: str) -> list[Any]:
        return [
            v
            for k, v in sorted(self.local_store(agent_id, plane).items())
            if k not in seen
        ]

    # -- anti-entropy -------------------------------------------------------
    def anti_entropy(self, sched=None, now: float = 0.0) -> int:
        """One push-pull round over sampled peer pairs.

        With ``sched`` (a :class:`~repro.core.scheduler.Scheduler`), each
        record is delivered by a future event at its link transfer time;
        without one, delivery is immediate (tests, final flushes).
        Returns the number of records put on the wire.

        With an ``online`` view attached, the round runs over currently
        online agents only: offline peers are invisible to the sampler.
        """
        t = sched.now if sched is not None else now
        self.sampler.new_round(t)
        self.stats.n_rounds += 1
        ids = sorted(self.stores)
        if self.online is not None:
            ids = [a for a in ids if self.online(a)]
        sent = 0
        done_pairs = set()  # an exchange is push-pull: reconcile a pair once
        for aid in ids:
            for peer in self.sampler.peers(aid, ids):
                if peer not in self.stores:
                    continue
                pair = (min(aid, peer), max(aid, peer))
                if pair in done_pairs:
                    continue
                done_pairs.add(pair)
                self.stats.n_exchanges += 1
                sent += self._exchange(sched, t, aid, peer)
        return sent

    def pair_link(self, a: int, b: int) -> LinkModel:
        """The link pricing one a<->b exchange (site-aware when sites
        are configured, the shared default link otherwise)."""
        if self.site_links is not None:
            return self.site_links.pair(a, b)
        return self.link

    def _exchange(self, sched, t: float, a: int, b: int) -> int:
        """Push-pull reconciliation of one pair, every plane."""
        sent = 0
        pair_bytes = 0
        t_last = t
        link = self.pair_link(a, b)
        for name in sorted(self.planes):
            plane = self.planes[name]
            for src, dst in ((a, b), (b, a)):
                dst_store = self.local_store(dst, name)
                for rid, rec in sorted(self.local_store(src, name).items()):
                    if rid in dst_store:
                        continue
                    self.stats.n_sent += 1
                    sent += 1
                    if link.drop > 0.0 and self.rng.random() < link.drop:
                        self.stats.n_dropped += 1
                        self.telemetry.count("gossip.dropped", 1, plane=name)
                        continue
                    nbytes = plane.payload_nbytes(rec)
                    pair_bytes += nbytes
                    self.meter.account(name, nbytes)
                    if sched is None:
                        self._deliver(dst, rec, name, t)
                    else:
                        arrival = t + link.transfer_time(nbytes)
                        t_last = max(t_last, arrival)
                        sched.at(
                            arrival,
                            lambda s, tt, d=dst, r=rec, p=name: self._deliver(
                                d, r, p, tt
                            ),
                            tag=f"gossip_deliver_{name}",
                        )
        if self.telemetry.enabled and sent:
            # span from initiation to the last in-flight delivery of the
            # pair — a "gossip burst" on the shared gossip track
            self.telemetry.span(
                "gossip.exchange",
                "gossip",
                t,
                t_last,
                pair=f"{a}<->{b}",
                records=sent,
                bytes=pair_bytes,
            )
            self.telemetry.count("gossip.exchange.bytes", pair_bytes)
            self.telemetry.observe("gossip.exchange.records", sent)
        return sent

    def _deliver(self, dst: int, rec: Any, plane_name: str, t: float = 0.0) -> bool:
        if dst not in self.stores:  # agent left while the record was in flight
            return False
        plane = self.planes[plane_name]
        if plane.admit(self.local_store(dst, plane_name), rec):
            self.stats.n_delivered += 1
            if self.on_deliver is not None:
                self.on_deliver(dst, rec, plane_name, t)
            return True
        return False

    # -- introspection ------------------------------------------------------
    def all_known(self, plane: str) -> set[str]:
        ids: set[str] = set()
        for aid in self.stores:
            ids |= set(self.local_store(aid, plane))
        return ids

    def converged(self, plane: str) -> bool:
        """True iff every live agent holds the identical record set."""
        stores = [set(self.local_store(a, plane)) for a in sorted(self.stores)]
        return all(s == stores[0] for s in stores[1:]) if stores else True
