"""Selective experience replay — the lifelong-learning mechanism (A.2).

During training an agent samples each minibatch from three pools:
  (1) the ERB of its *current* task,
  (2) its *personal* past-task ERBs,
  (3) *incoming* ERBs received from the network (other agents' experience).
Mixing (2) and (3) into every update is what prevents catastrophic
forgetting and what federates learning without sharing weights.

The sampler is split into *selection* (:meth:`SelectiveReplaySampler.plan`
— pure host-side index math) and *materialization* (gathering the rows).
The classic host path does both; the fleet engine takes only the plan and
gathers the rows on device from resident ERB buffers via the
``replay_gather`` Pallas kernel. Both paths consume the ``rng`` stream in
exactly the same order, so they select bit-identical batches.
"""

from __future__ import annotations

from collections.abc import Sequence
from dataclasses import dataclass, field

import numpy as np

from repro.core.erb import ERB, erb_sample_indices, erb_take, stack_batches


@dataclass(frozen=True)
class ReplayPlan:
    """One minibatch worth of selection: ordered per-ERB row picks plus
    the final in-batch shuffle. ``picks`` concatenated in order (before
    ``perm``) spell out the batch exactly as the host path stacks it."""

    picks: tuple[tuple[ERB, np.ndarray], ...]  # (erb, local row indices)
    perm: np.ndarray = field(repr=False)  # [batch_size] final shuffle

    @property
    def batch_size(self) -> int:
        return len(self.perm)


@dataclass
class SelectiveReplaySampler:
    """mix = (current, personal, incoming) fractions; renormalized over
    non-empty pools."""

    mix: Sequence[float] = (0.5, 0.25, 0.25)
    use_pallas: bool = False

    def plan(
        self,
        rng: np.random.Generator,
        batch_size: int,
        current: ERB | None,
        personal: Sequence[ERB] = (),
        incoming: Sequence[ERB] = (),
    ) -> ReplayPlan:
        """Select which rows make up the next minibatch without touching
        the experience data itself."""
        pools: list[list[ERB]] = [
            [e for e in ([current] if current is not None else []) if len(e) > 0],
            [e for e in personal if len(e) > 0],
            [e for e in incoming if len(e) > 0],
        ]
        weights = np.array(
            [m if pool else 0.0 for m, pool in zip(self.mix, pools, strict=True)],
            np.float64,
        )
        if weights.sum() == 0:
            raise ValueError("all replay pools are empty")
        weights = weights / weights.sum()
        counts = np.floor(weights * batch_size).astype(int)
        counts[int(np.argmax(weights))] += batch_size - counts.sum()

        picks: list[tuple[ERB, np.ndarray]] = []
        for pool, n in zip(pools, counts, strict=True):
            if n == 0 or not pool:
                continue
            # spread n over the ERBs in this pool, uniformly per-ERB
            per = np.bincount(rng.integers(0, len(pool), size=n), minlength=len(pool))
            for erb, m in zip(pool, per, strict=True):
                if m > 0:
                    picks.append((erb, erb_sample_indices(erb, rng, int(m))))
        perm = rng.permutation(batch_size)
        return ReplayPlan(picks=tuple(picks), perm=perm)

    def sample(
        self,
        rng: np.random.Generator,
        batch_size: int,
        current: ERB | None,
        personal: Sequence[ERB] = (),
        incoming: Sequence[ERB] = (),
    ) -> dict[str, np.ndarray]:
        plan = self.plan(rng, batch_size, current, personal=personal, incoming=incoming)
        return self.materialize(plan)

    def materialize(self, plan: ReplayPlan) -> dict[str, np.ndarray]:
        """Host-side row gather of a plan (the classic path)."""
        batches = [
            erb_take(erb, idx, use_pallas=self.use_pallas) for erb, idx in plan.picks
        ]
        batch = stack_batches(batches)
        return {k: v[plan.perm] for k, v in batch.items()}
