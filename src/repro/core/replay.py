"""Selective experience replay — the lifelong-learning mechanism (A.2).

During training an agent samples each minibatch from three pools:
  (1) the ERB of its *current* task,
  (2) its *personal* past-task ERBs,
  (3) *incoming* ERBs received from the network (other agents' experience).
Mixing (2) and (3) into every update is what prevents catastrophic
forgetting and what federates learning without sharing weights.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.erb import ERB, erb_sample, stack_batches


@dataclass
class SelectiveReplaySampler:
    """mix = (current, personal, incoming) fractions; renormalized over
    non-empty pools."""

    mix: Sequence[float] = (0.5, 0.25, 0.25)
    use_pallas: bool = False

    def sample(
        self,
        rng: np.random.Generator,
        batch_size: int,
        current: Optional[ERB],
        personal: Sequence[ERB] = (),
        incoming: Sequence[ERB] = (),
    ) -> Dict[str, np.ndarray]:
        pools: List[List[ERB]] = [
            [e for e in ([current] if current is not None else []) if len(e) > 0],
            [e for e in personal if len(e) > 0],
            [e for e in incoming if len(e) > 0],
        ]
        weights = np.array(
            [m if pool else 0.0 for m, pool in zip(self.mix, pools, strict=True)],
            np.float64,
        )
        if weights.sum() == 0:
            raise ValueError("all replay pools are empty")
        weights = weights / weights.sum()
        counts = np.floor(weights * batch_size).astype(int)
        counts[int(np.argmax(weights))] += batch_size - counts.sum()

        batches = []
        for pool, n in zip(pools, counts, strict=True):
            if n == 0 or not pool:
                continue
            # spread n over the ERBs in this pool, uniformly per-ERB
            per = np.bincount(rng.integers(0, len(pool), size=n), minlength=len(pool))
            for erb, m in zip(pool, per, strict=True):
                if m > 0:
                    batches.append(
                        erb_sample(erb, rng, int(m), use_pallas=self.use_pallas)
                    )
        batch = stack_batches(batches)
        perm = rng.permutation(batch_size)
        return {k: v[perm] for k, v in batch.items()}
