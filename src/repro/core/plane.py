"""Pluggable sharing planes — the generic data planes federated via hubs.

The paper federates exactly one artifact: experience replay buffers
(:class:`~repro.core.erb.ERB`).  This module generalizes that into a
``SharePlane`` protocol so the hub topology can carry *any* record type,
and adds parameter-level planes:

* :class:`ERBPlane` — the paper's plane. Records are ERBs, identity is
  ``meta.erb_id``, hubs keep everything (experience never goes stale).
* :class:`WeightPlane` — a parameter-level plane in the spirit of
  FedAsync (Xie et al., 1903.03934) and BrainTorrent's peer-to-peer FL:
  agents push :class:`WeightSnapshot` records (params + round/timestamp
  provenance) and pull peer snapshots, which they fold into their own
  parameters with a staleness-discounted mixing rate
  ``alpha_t = alpha * s(delta_tau)``.
* :class:`CompressedWeightPlane` — the same plane, wire-efficient:
  snapshots cross the network as int8-quantized pytrees or top-k
  int8-quantized deltas (:class:`CompressedWeightSnapshot`) instead of
  full float32 pytrees, and are dequantized on the receiving side
  inside :func:`mix_params`.

Every plane also prices its records (``payload_nbytes``) and may
re-encode them at the network ingress edge (``encode``); the transport
layers (hub links, gossip links) use both for bandwidth accounting, so
simulated time reflects message size.

Planes ride the same :class:`~repro.core.network.Network` /
:class:`~repro.core.hub.Hub` machinery (or the hub-less
:class:`~repro.core.gossip.GossipTopology`) and the same event-driven
scheduler, so asynchrony, communication dropout, hub failure, and
heterogeneous agent speeds apply to them uniformly.

Staleness functions follow FedAsync's three families (``constant`` /
``hinge`` / ``poly``), clamped to (0, 1] so mixing is always a convex
combination.
"""

from __future__ import annotations

import itertools
from collections.abc import Sequence
from dataclasses import dataclass
from typing import Any

import jax
import numpy as np

from repro.core.erb import ERB

_SNAP_COUNTER = itertools.count()


def new_snap_id(prefix: str = "W") -> str:
    return f"{prefix}_{next(_SNAP_COUNTER):05d}"


@dataclass(frozen=True)
class WeightSnapshot:
    """One pushed parameter state: the unit of the weight plane.

    ``round_idx`` is the sender's local round counter when the snapshot
    was taken (the FedAsync ``tau``); ``sim_time`` is scheduler time at
    the push, kept for analysis/debugging.  ``params`` is a JAX pytree
    (immutable arrays — safe to share by reference).

    ``version_vector`` is BrainTorrent-style provenance the observatory
    stamps when enabled: a sorted tuple of ``(agent_id, round_idx)``
    pairs recording the sender's view of every peer's progress at push
    time.  Purely observational — the default empty tuple is never read
    by the numeric mixing path.
    """

    snap_id: str
    agent_id: int
    round_idx: int
    sim_time: float
    params: Any
    version_vector: tuple = ()

    @property
    def record_id(self) -> str:
        return self.snap_id


# ---------------------------------------------------------------------------
# quantized wire format (CompressedWeightPlane)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class QuantizedLeaf:
    """One pytree leaf on the wire: int8 codes + scale (+ top-k indices).

    Dense leaves (``idx is None``) carry a code per element; sparse
    delta leaves carry codes only for the ``idx`` coordinates.
    """

    q: np.ndarray  # int8 codes, flat
    scale: float
    shape: tuple[int, ...]
    idx: np.ndarray | None = None  # int32 flat coords (top-k deltas)

    @property
    def nbytes(self) -> int:
        n = self.q.nbytes + 4  # codes + float32 scale
        if self.idx is not None:
            n += self.idx.nbytes
        return n

    def dequantize_dense(self) -> np.ndarray:
        return (self.q.astype(np.float32) * self.scale).reshape(self.shape)


def _quantize_int8(x: np.ndarray) -> tuple[np.ndarray, float]:
    """Symmetric per-tensor int8: ``x ~= q * scale`` with |q| <= 127."""
    amax = float(np.max(np.abs(x))) if x.size else 0.0
    if amax <= 0.0:
        return np.zeros(x.shape, np.int8), 0.0
    scale = amax / 127.0
    q = np.clip(np.rint(x / scale), -127, 127).astype(np.int8)
    return q, scale


@dataclass(frozen=True)
class CompressedWeightSnapshot:
    """Wire-format weight record: quantized leaves instead of a pytree.

    ``mode`` is ``"dense"`` (int8 full snapshot, self-contained) or
    ``"delta"`` (top-k int8-quantized delta vs the sender's previous
    transmitted state).  Delta records carry the sender-side
    reconstruction (``dense_params``) so hub replication and late pulls
    need not replay the delta chain — equivalent to assuming reliable
    in-order delta delivery per sender.  The transports uphold that:
    encoding happens once at the network ingress edge *after* the
    hub-link drop/liveness decision (a dropped upload never advances
    the chain), and gossip anti-entropy retries a record from the
    sender's persistent store until a copy lands, so every encoded
    delta eventually reaches some live store.  ``payload_nbytes``
    counts only what would cross the wire: codes, indices, and scales.
    """

    snap_id: str
    agent_id: int
    round_idx: int
    sim_time: float
    mode: str
    leaves: tuple[QuantizedLeaf, ...]
    treedef: Any
    payload_nbytes: int
    dense_params: Any = None  # delta mode: sender-side reconstruction
    version_vector: tuple = ()  # observational provenance, carried verbatim

    @property
    def record_id(self) -> str:
        return self.snap_id

    def dequantize(self) -> Any:
        """Materialize the float32 pytree this record represents."""
        if self.dense_params is not None:
            return self.dense_params
        arrs = [leaf.dequantize_dense() for leaf in self.leaves]
        return jax.tree_util.tree_unflatten(self.treedef, arrs)


# ---------------------------------------------------------------------------
# plane protocol
# ---------------------------------------------------------------------------
class SharePlane:
    """One federated data plane: record identity + hub-side retention.

    A plane never talks to the network itself; :class:`Network`,
    ``sync_hubs``, and :class:`~repro.core.gossip.GossipTopology`
    consult it when inserting records into a per-plane store
    (``dict[record_id, record]``), when encoding records for the wire,
    and when pricing them for bandwidth accounting.
    """

    name: str = "base"

    def key(self, item: Any) -> str:
        raise NotImplementedError

    def admit(self, store: dict[str, Any], item: Any) -> bool:
        """Insert ``item`` into a hub store. Returns True iff newly kept."""
        k = self.key(item)
        if k in store:
            return False
        store[k] = item
        self.evict(store)
        return k in store

    def evict(self, store: dict[str, Any]) -> None:
        """Hub-side retention policy; default keeps everything."""

    def encode(self, item: Any) -> Any:
        """Wire encoding, applied once at the network ingress edge."""
        return item

    def payload_nbytes(self, item: Any) -> int:
        """Approximate bytes-on-wire of one record (bandwidth accounting)."""
        return 64  # bare metadata envelope; concrete planes override

    def forget_agent(self, agent_id: int) -> None:
        """Drop any per-sender codec state for a departed agent."""


class ERBPlane(SharePlane):
    """The paper's plane: experience replay buffers, kept forever."""

    name = "erb"

    def key(self, item: ERB) -> str:
        return item.meta.erb_id

    def payload_nbytes(self, item: ERB) -> int:
        return 64 + sum(np.asarray(v).nbytes for v in item.data.values())


class WeightPlane(SharePlane):
    """Parameter snapshots, deduplicated per source agent.

    Hubs keep at most ``max_versions`` snapshots per agent (newest
    ``round_idx`` wins) and refuse re-insertion of snapshots no newer
    than what they already hold from that agent — so hub-hub sync never
    resurrects an evicted stale version.
    """

    name = "weights"

    def __init__(self, max_versions: int = 2):
        assert max_versions >= 1
        self.max_versions = max_versions

    def key(self, item: WeightSnapshot) -> str:
        return item.snap_id

    def admit(self, store: dict[str, Any], item: WeightSnapshot) -> bool:
        if item.snap_id in store:
            return False
        newest = max(
            (s.round_idx for s in store.values() if s.agent_id == item.agent_id),
            default=None,
        )
        if newest is not None and item.round_idx <= newest:
            return False
        store[item.snap_id] = item
        self.evict(store)
        return item.snap_id in store

    def evict(self, store: dict[str, Any]) -> None:
        by_agent: dict[int, list[WeightSnapshot]] = {}
        for s in store.values():
            by_agent.setdefault(s.agent_id, []).append(s)
        for snaps in by_agent.values():
            snaps.sort(key=lambda s: (s.round_idx, s.snap_id), reverse=True)
            for stale in snaps[self.max_versions :]:
                del store[stale.snap_id]

    def payload_nbytes(self, item: Any) -> int:
        if isinstance(item, CompressedWeightSnapshot):
            return item.payload_nbytes
        leaves = jax.tree_util.tree_leaves(item.params)
        return 32 + sum(np.asarray(x).nbytes for x in leaves)


class CompressedWeightPlane(WeightPlane):
    """Weight plane whose records cross the wire compressed.

    ``compression="int8"``: every snapshot is a dense int8-quantized
    pytree — self-contained, ~4x smaller than float32.

    ``compression="topk"`` (default): the first snapshot from each agent
    is a dense int8 keyframe; each later one carries only the largest
    ``k_frac`` fraction of coordinates of the delta vs the sender's last
    *transmitted* state, int8-quantized.  Because the next delta is
    taken against the reconstruction (not the raw previous params), the
    untransmitted residual accumulates and is sent once it grows —
    sender-side error feedback, so repeated pushes converge to the true
    parameters even with aggressive sparsification.

    Dedup/retention semantics are inherited from :class:`WeightPlane`
    unchanged; only the wire format differs.
    """

    def __init__(
        self,
        max_versions: int = 2,
        compression: str = "topk",
        k_frac: float = 0.05,
    ):
        super().__init__(max_versions=max_versions)
        if compression not in ("int8", "topk"):
            raise ValueError(f"unknown compression: {compression!r}")
        self.compression = compression
        self.k_frac = float(k_frac)
        self._ref: dict[int, Any] = {}  # per-sender transmitted state

    def forget_agent(self, agent_id: int) -> None:
        """Departed senders free their reference pytree (churn runs would
        otherwise hold one full model copy per agent that ever pushed)."""
        self._ref.pop(agent_id, None)

    def encode(self, item: Any) -> Any:
        if isinstance(item, CompressedWeightSnapshot):
            return item  # already on the wire format (hub-hub relay)
        flat, treedef = jax.tree_util.tree_flatten(item.params)
        flat = [np.asarray(x, np.float32) for x in flat]
        ref = self._ref.get(item.agent_id)
        leaves: list[QuantizedLeaf] = []
        recon: list[np.ndarray] = []
        if self.compression == "int8" or ref is None:
            mode = "dense"
            for x in flat:
                q, scale = _quantize_int8(x.ravel())
                leaf = QuantizedLeaf(q, scale, x.shape)
                leaves.append(leaf)
                recon.append(leaf.dequantize_dense())
        else:
            mode = "delta"
            ref_flat = [
                np.asarray(r, np.float32) for r in jax.tree_util.tree_leaves(ref)
            ]
            for x, r in zip(flat, ref_flat, strict=True):
                d = (x - r).ravel()
                k = max(1, int(round(self.k_frac * d.size)))
                idx = np.sort(np.argpartition(np.abs(d), -k)[-k:]).astype(np.int32)
                q, scale = _quantize_int8(d[idx])
                leaves.append(QuantizedLeaf(q, scale, x.shape, idx=idx))
                rec = r.ravel().copy()
                rec[idx] += q.astype(np.float32) * scale
                recon.append(rec.reshape(x.shape))
        recon_tree = jax.tree_util.tree_unflatten(treedef, recon)
        if self.compression == "topk":
            self._ref[item.agent_id] = recon_tree
        payload = 32 + sum(leaf.nbytes for leaf in leaves)
        return CompressedWeightSnapshot(
            item.snap_id,
            item.agent_id,
            item.round_idx,
            item.sim_time,
            mode,
            tuple(leaves),
            treedef,
            payload,
            dense_params=recon_tree if mode == "delta" else None,
            version_vector=item.version_vector,
        )


# ---------------------------------------------------------------------------
# staleness weighting (FedAsync s(delta_tau) families)
# ---------------------------------------------------------------------------
def staleness_weight(
    delta_tau: float,
    flag: str = "poly",
    *,
    hinge_a: float = 10.0,
    hinge_b: float = 4.0,
    poly_a: float = 0.5,
) -> float:
    """FedAsync staleness discount ``s(delta_tau)``, clamped to (0, 1].

    ``constant``: 1 — staleness ignored (plain async averaging).
    ``hinge``:    1 until ``hinge_b`` rounds of lag, then 1/(a*(d-b)).
    ``poly``:     (d+1)^-a — smooth polynomial decay.
    """
    d = max(0.0, float(delta_tau))
    if flag == "constant":
        return 1.0
    if flag == "hinge":
        if d <= hinge_b:
            return 1.0
        return min(1.0, 1.0 / (hinge_a * (d - hinge_b)))
    if flag == "poly":
        return float((d + 1.0) ** (-poly_a))
    raise ValueError(f"unknown staleness flag: {flag!r}")


def staleness_alphas(
    snaps: Sequence[WeightSnapshot],
    now: float,
    *,
    alpha: float = 0.6,
    flag: str = "poly",
    hinge_a: float = 10.0,
    hinge_b: float = 4.0,
    poly_a: float = 0.5,
    clock: str = "round",
) -> np.ndarray:
    """Per-snapshot mixing rates ``alpha * s(now - tau_k)``.

    ``clock`` picks the timescale ``tau`` lives on:

    * ``"round"`` — FedAsync-literal: ``tau_k`` is the sender's local
      round counter and ``now`` the receiver's. Only meaningful when
      agents advance rounds at comparable rates.
    * ``"time"``  — ``tau_k`` is the snapshot's push time on the shared
      scheduler clock and ``now`` the receiver's current time; the
      right choice under heterogeneous agent speeds, where local round
      counters are incomparable (a speed-2.5x agent's round 10 is not
      older than a slow peer's round 4).
    """
    taus = [s.round_idx if clock == "round" else s.sim_time for s in snaps]
    out = [
        alpha
        * staleness_weight(
            now - tau, flag, hinge_a=hinge_a, hinge_b=hinge_b, poly_a=poly_a
        )
        for tau in taus
    ]
    return np.asarray(out, np.float64)


def snapshot_params(snap: Any) -> Any:
    """The float32 pytree a snapshot carries, dequantizing if compressed."""
    if hasattr(snap, "dequantize"):
        return snap.dequantize()
    return snap.params


def mix_params(params: Any, snaps: Sequence[Any], alphas: Sequence[float]) -> Any:
    """Sequential FedAsync mixing: ``p <- (1-a_k) p + a_k w_k``.

    Snapshots are applied stalest-first on the shared clock (ascending
    ``sim_time``, then ``round_idx``, ties by id) so the freshest peer
    has the final word — and so the result is deterministic regardless
    of hub iteration order.  Compressed snapshots are dequantized here,
    on the receiving side (dequantize-and-apply).
    """
    order = sorted(
        range(len(snaps)),
        key=lambda i: (snaps[i].sim_time, snaps[i].round_idx, snaps[i].snap_id),
    )
    for i in order:
        a = float(alphas[i])
        params = jax.tree_util.tree_map(
            lambda p, q, a=a: (1.0 - a) * p + a * q,
            params,
            snapshot_params(snaps[i]),
        )
    return params
