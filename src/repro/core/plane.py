"""Pluggable sharing planes — the generic data planes federated via hubs.

The paper federates exactly one artifact: experience replay buffers
(:class:`~repro.core.erb.ERB`).  This module generalizes that into a
``SharePlane`` protocol so the hub topology can carry *any* record type,
and adds a second concrete plane:

* :class:`ERBPlane` — the paper's plane. Records are ERBs, identity is
  ``meta.erb_id``, hubs keep everything (experience never goes stale).
* :class:`WeightPlane` — a parameter-level plane in the spirit of
  FedAsync (Xie et al., 1903.03934) and BrainTorrent's peer-to-peer FL:
  agents push :class:`WeightSnapshot` records (params + round/timestamp
  provenance) and pull peer snapshots, which they fold into their own
  parameters with a staleness-discounted mixing rate
  ``alpha_t = alpha * s(delta_tau)``.

Both planes ride the same :class:`~repro.core.network.Network` /
:class:`~repro.core.hub.Hub` machinery and the same event-driven
scheduler, so asynchrony, communication dropout, hub failure, and
heterogeneous agent speeds apply to them uniformly.

Staleness functions follow FedAsync's three families (``constant`` /
``hinge`` / ``poly``), clamped to (0, 1] so mixing is always a convex
combination.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Any, Dict, List, Sequence

import jax
import numpy as np

from repro.core.erb import ERB

_SNAP_COUNTER = itertools.count()


def new_snap_id(prefix: str = "W") -> str:
    return f"{prefix}_{next(_SNAP_COUNTER):05d}"


@dataclass(frozen=True)
class WeightSnapshot:
    """One pushed parameter state: the unit of the weight plane.

    ``round_idx`` is the sender's local round counter when the snapshot
    was taken (the FedAsync ``tau``); ``sim_time`` is scheduler time at
    the push, kept for analysis/debugging.  ``params`` is a JAX pytree
    (immutable arrays — safe to share by reference).
    """
    snap_id: str
    agent_id: int
    round_idx: int
    sim_time: float
    params: Any

    @property
    def record_id(self) -> str:
        return self.snap_id


# ---------------------------------------------------------------------------
# plane protocol
# ---------------------------------------------------------------------------
class SharePlane:
    """One federated data plane: record identity + hub-side retention.

    A plane never talks to the network itself; :class:`Network` and
    ``sync_hubs`` consult it when inserting records into a hub's
    per-plane store (``Dict[record_id, record]``).
    """

    name: str = "base"

    def key(self, item: Any) -> str:
        raise NotImplementedError

    def admit(self, store: Dict[str, Any], item: Any) -> bool:
        """Insert ``item`` into a hub store. Returns True iff newly kept."""
        k = self.key(item)
        if k in store:
            return False
        store[k] = item
        self.evict(store)
        return k in store

    def evict(self, store: Dict[str, Any]) -> None:
        """Hub-side retention policy; default keeps everything."""


class ERBPlane(SharePlane):
    """The paper's plane: experience replay buffers, kept forever."""

    name = "erb"

    def key(self, item: ERB) -> str:
        return item.meta.erb_id


class WeightPlane(SharePlane):
    """Parameter snapshots, deduplicated per source agent.

    Hubs keep at most ``max_versions`` snapshots per agent (newest
    ``round_idx`` wins) and refuse re-insertion of snapshots no newer
    than what they already hold from that agent — so hub-hub sync never
    resurrects an evicted stale version.
    """

    name = "weights"

    def __init__(self, max_versions: int = 2):
        assert max_versions >= 1
        self.max_versions = max_versions

    def key(self, item: WeightSnapshot) -> str:
        return item.snap_id

    def admit(self, store: Dict[str, Any], item: WeightSnapshot) -> bool:
        if item.snap_id in store:
            return False
        newest = max((s.round_idx for s in store.values()
                      if s.agent_id == item.agent_id), default=None)
        if newest is not None and item.round_idx <= newest:
            return False
        store[item.snap_id] = item
        self.evict(store)
        return item.snap_id in store

    def evict(self, store: Dict[str, Any]) -> None:
        by_agent: Dict[int, List[WeightSnapshot]] = {}
        for s in store.values():
            by_agent.setdefault(s.agent_id, []).append(s)
        for snaps in by_agent.values():
            snaps.sort(key=lambda s: (s.round_idx, s.snap_id), reverse=True)
            for stale in snaps[self.max_versions:]:
                del store[stale.snap_id]


# ---------------------------------------------------------------------------
# staleness weighting (FedAsync s(delta_tau) families)
# ---------------------------------------------------------------------------
def staleness_weight(delta_tau: float, flag: str = "poly", *,
                     hinge_a: float = 10.0, hinge_b: float = 4.0,
                     poly_a: float = 0.5) -> float:
    """FedAsync staleness discount ``s(delta_tau)``, clamped to (0, 1].

    ``constant``: 1 — staleness ignored (plain async averaging).
    ``hinge``:    1 until ``hinge_b`` rounds of lag, then 1/(a*(d-b)).
    ``poly``:     (d+1)^-a — smooth polynomial decay.
    """
    d = max(0.0, float(delta_tau))
    if flag == "constant":
        return 1.0
    if flag == "hinge":
        if d <= hinge_b:
            return 1.0
        return min(1.0, 1.0 / (hinge_a * (d - hinge_b)))
    if flag == "poly":
        return float((d + 1.0) ** (-poly_a))
    raise ValueError(f"unknown staleness flag: {flag!r}")


def staleness_alphas(snaps: Sequence[WeightSnapshot], now: float,
                     *, alpha: float = 0.6, flag: str = "poly",
                     hinge_a: float = 10.0, hinge_b: float = 4.0,
                     poly_a: float = 0.5,
                     clock: str = "round") -> np.ndarray:
    """Per-snapshot mixing rates ``alpha * s(now - tau_k)``.

    ``clock`` picks the timescale ``tau`` lives on:

    * ``"round"`` — FedAsync-literal: ``tau_k`` is the sender's local
      round counter and ``now`` the receiver's. Only meaningful when
      agents advance rounds at comparable rates.
    * ``"time"``  — ``tau_k`` is the snapshot's push time on the shared
      scheduler clock and ``now`` the receiver's current time; the
      right choice under heterogeneous agent speeds, where local round
      counters are incomparable (a speed-2.5x agent's round 10 is not
      older than a slow peer's round 4).
    """
    taus = [s.round_idx if clock == "round" else s.sim_time
            for s in snaps]
    out = [alpha * staleness_weight(now - tau, flag,
                                    hinge_a=hinge_a, hinge_b=hinge_b,
                                    poly_a=poly_a)
           for tau in taus]
    return np.asarray(out, np.float64)


def mix_params(params: Any, snaps: Sequence[WeightSnapshot],
               alphas: Sequence[float]) -> Any:
    """Sequential FedAsync mixing: ``p <- (1-a_k) p + a_k w_k``.

    Snapshots are applied stalest-first on the shared clock (ascending
    ``sim_time``, then ``round_idx``, ties by id) so the freshest peer
    has the final word — and so the result is deterministic regardless
    of hub iteration order.
    """
    order = sorted(range(len(snaps)),
                   key=lambda i: (snaps[i].sim_time, snaps[i].round_idx,
                                  snaps[i].snap_id))
    for i in order:
        a = float(alphas[i])
        params = jax.tree_util.tree_map(
            lambda p, q, a=a: (1.0 - a) * p + a * q, params,
            snaps[i].params)
    return params
