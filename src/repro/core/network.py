"""Pluggable topology: hub/agent routing, hub-less gossip, failure injection.

Three topologies share one transport API (``agent_push`` / ``agent_pull``
/ ``sync``):

* ``"hub"`` — the paper's Fig. 2 layout: each agent talks to one hub,
  hubs sync pairwise.  Communication is linear in agents; hub-hub sync
  is the only n^2 term and n_hubs << n_agents.
* ``"gossip"`` — no hubs at all: agents publish into their own local
  store and :class:`~repro.core.gossip.GossipTopology` replicates
  records peer-to-peer in anti-entropy rounds (BrainTorrent-style).
* ``"hybrid"`` — both at once: pushes land on the hub *and* the local
  gossip store; pulls merge the two, deduplicated per plane key.

The network is plane-agnostic: it carries a registry of
:class:`~repro.core.plane.SharePlane` objects (the ERB plane by
default), and every push/pull names the plane it rides on.  Records are
wire-encoded once at the ingress edge (``plane.encode``), and every
hub-link message is priced by the :class:`~repro.core.gossip.LinkModel`
and accounted on the shared :class:`~repro.core.gossip.BandwidthMeter`.
Each push/pull returns an explicit :class:`PushResult` /
:class:`PullResult` carrying the records plus the link time and bytes it
cost, so the scheduler-driven system charges communication to simulated
time without any mutable side-channel.  With
:meth:`Network.configure_sites`, agent-hub and agent-agent legs are
priced per link (fast intra-site, slow cross-site).  Dropout, hub
liveness, and hub-hub sync apply to all planes uniformly.
"""

from __future__ import annotations

from collections.abc import Iterator
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core.gossip import (
    BandwidthMeter,
    GossipTopology,
    LinkModel,
    PeerSampler,
    SiteLinks,
)
from repro.core.hub import Hub, sync_hubs
from repro.core.plane import ERBPlane, SharePlane


@dataclass(frozen=True)
class PushResult:
    """Outcome of one ``agent_push``: delivery + what the link charged.

    Truthy iff the record was newly kept anywhere (so existing
    ``assert net.agent_push(...)`` call sites keep reading naturally).
    """

    delivered: bool
    comm_time: float = 0.0
    nbytes: int = 0

    def __bool__(self) -> bool:
        return self.delivered


@dataclass(frozen=True, eq=False)
class PullResult:
    """Outcome of one ``agent_pull``: the records + what the link charged.

    Behaves like the plain record list it used to be (iteration, len,
    indexing, equality against sequences) while carrying the explicit
    ``comm_time`` / ``nbytes`` accounting.
    """

    records: tuple[Any, ...] = ()
    comm_time: float = 0.0
    nbytes: int = 0

    def __iter__(self) -> Iterator[Any]:
        return iter(self.records)

    def __len__(self) -> int:
        return len(self.records)

    def __getitem__(self, i):
        return self.records[i]

    def __bool__(self) -> bool:
        return bool(self.records)

    def __eq__(self, other) -> bool:
        if isinstance(other, PullResult):
            return self.records == other.records
        if isinstance(other, (list, tuple)):
            return list(self.records) == list(other)
        return NotImplemented

    def __hash__(self):
        return hash(self.records)


@dataclass
class Network:
    hubs: list[Hub]
    agent_hub: dict[int, int] = field(default_factory=dict)
    dropout: float = 0.0
    rng: np.random.Generator = field(default_factory=lambda: np.random.default_rng(0))
    planes: dict[str, SharePlane] = field(default_factory=lambda: {"erb": ERBPlane()})
    topology: str = "hub"  # hub | gossip | hybrid
    link: LinkModel = field(default_factory=LinkModel)
    meter: BandwidthMeter = field(default_factory=BandwidthMeter)
    gossip: GossipTopology | None = None
    # statistics (aggregate and per plane)
    n_pushed: int = 0
    n_dropped: int = 0
    n_synced: int = 0
    plane_pushed: dict[str, int] = field(default_factory=dict)
    # per-link heterogeneous rates (None = every leg uses `link`)
    site_links: SiteLinks | None = None

    def __post_init__(self):
        if self.topology not in ("hub", "gossip", "hybrid"):
            raise ValueError(f"unknown topology: {self.topology!r}")

    # -- wiring ------------------------------------------------------------
    def enable_gossip(
        self,
        sampler: PeerSampler,
        *,
        rng: np.random.Generator | None = None,
    ) -> GossipTopology:
        """Attach a gossip overlay sharing this network's planes/meter/link."""
        self.gossip = GossipTopology(
            self.planes,
            sampler,
            link=self.link,
            meter=self.meter,
            rng=rng,
            site_links=self.site_links,
        )
        for aid in self.agent_hub:
            self.gossip.add_agent(aid)
        return self.gossip

    def configure_sites(
        self,
        agent_site: dict[int, int],
        *,
        hub_site: dict[int, int] | None = None,
        intra: LinkModel | None = None,
        inter: LinkModel | None = None,
    ) -> SiteLinks:
        """Enable per-link heterogeneous rates (fast intra-site, slow
        cross-site).  Endpoints without a site keep the default link;
        the gossip overlay (if any) shares the same link map."""
        self.site_links = SiteLinks(
            default=self.link,
            agent_site=dict(agent_site),
            hub_site=dict(hub_site or {}),
            intra=intra,
            inter=inter,
        )
        if self.gossip is not None:
            self.gossip.site_links = self.site_links
        return self.site_links

    def link_for(self, agent_id: int) -> LinkModel:
        """The link pricing this agent's hub leg."""
        if self.site_links is None:
            return self.link
        return self.site_links.agent_hub(agent_id, self.agent_hub.get(agent_id))

    def register_plane(self, plane: SharePlane) -> SharePlane:
        self.planes[plane.name] = plane
        return plane

    def attach_agent(self, agent_id: int, hub_id: int | None = None):
        """New agents attach to the least-loaded live hub by default.

        Under ``hybrid``, agents attached before :meth:`enable_gossip`
        are back-filled into the overlay from ``agent_hub``; under pure
        ``gossip`` there is no hub record to back-fill from, so
        attaching before the overlay exists would silently lose the
        agent — refuse instead."""
        if self.gossip is not None:
            self.gossip.add_agent(agent_id)
        if self.topology == "gossip":
            if self.gossip is None:
                raise RuntimeError(
                    "topology='gossip' needs enable_gossip() before agents attach"
                )
            return
        if hub_id is None:
            loads = {h.hub_id: 0 for h in self.hubs if h.alive}
            if not loads:
                # every hub is dead: the joiner stays detached (hub
                # uploads drop, pulls return nothing) — same orphan
                # semantics as re-homing after a total failure
                return
            for a, hid in self.agent_hub.items():
                if hid in loads:
                    loads[hid] += 1
            hub_id = min(loads, key=lambda k: (loads[k], k))
        self.agent_hub[agent_id] = hub_id

    def detach_agent(self, agent_id: int):
        self.agent_hub.pop(agent_id, None)
        if self.gossip is not None:
            self.gossip.remove_agent(agent_id)
        for plane in self.planes.values():
            plane.forget_agent(agent_id)

    def hub_of(self, agent_id: int) -> Hub:
        return self.hubs[self.agent_hub[agent_id]]

    # -- data planes ---------------------------------------------------------
    def agent_push(self, agent_id: int, item: Any, plane: str = "erb") -> PushResult:
        """Agent publishes one record on ``plane``.

        Hub topologies upload to the agent's hub (may drop); gossip
        topologies insert into the agent's own local store (free — the
        wire cost is paid when anti-entropy replicates it).  The result
        is truthy iff the record was newly kept anywhere and carries the
        link time/bytes the upload cost."""
        if self.topology != "hub" and self.gossip is None:
            raise RuntimeError(f"topology={self.topology!r} needs enable_gossip()")
        pl = self.planes[plane]
        # decide the hub link's fate BEFORE encoding: a dropped upload must
        # not advance sender-side codec state (compressed delta chains stay
        # consistent with what some live store actually received)
        hub_up = False
        if self.topology != "gossip":
            if agent_id not in self.agent_hub:
                # orphaned by hub failure with no survivor to re-home to:
                # the upload is lost (hybrid still lands it on gossip)
                self.n_dropped += 1
            elif self.dropout > 0.0 and self.rng.random() < self.dropout:
                self.n_dropped += 1
            elif not self.hub_of(agent_id).alive:
                self.n_dropped += 1
            else:
                hub_up = True
        if self.gossip is None and not hub_up:
            # pure hub: the upload is lost, nothing to encode
            return PushResult(False)
        item = pl.encode(item)
        delivered = False
        comm, nbytes_out = 0.0, 0
        if self.gossip is not None and self.gossip.insert_local(agent_id, item, pl):
            delivered = True
        if hub_up and self.hub_of(agent_id).push(item, pl):
            nbytes = pl.payload_nbytes(item)
            self.meter.account(plane, nbytes)
            comm = self.link_for(agent_id).transfer_time(nbytes)
            nbytes_out = nbytes
            delivered = True
        if delivered:
            self.n_pushed += 1
            self.plane_pushed[plane] = self.plane_pushed.get(plane, 0) + 1
        return PushResult(delivered, comm, nbytes_out)

    def agent_pull(
        self, agent_id: int, seen: set[str], plane: str = "erb"
    ) -> PullResult:
        """Every unseen record reachable by the agent on ``plane``.

        Local gossip copies are free (their wire cost was paid at
        anti-entropy delivery), so under ``hybrid`` the hub leg only
        downloads — and only prices — records the agent does not already
        hold locally.  The result carries the records plus the priced
        link time/bytes of the hub leg."""
        pl = self.planes[plane]
        local: list[Any] = []
        if self.gossip is not None:
            local = self.gossip.pull_local(agent_id, seen, plane)
        out: list[Any] = []
        comm, nbytes_total = 0.0, 0
        if self.topology != "gossip" and agent_id in self.agent_hub:
            skip = set(seen) | {pl.key(e) for e in local}
            pulled = self.hub_of(agent_id).pull_unseen(skip, plane)
            if self.dropout > 0.0:
                pulled = [e for e in pulled if self.rng.random() >= self.dropout]
            link = self.link_for(agent_id)
            for e in pulled:
                nbytes = pl.payload_nbytes(e)
                self.meter.account(plane, nbytes)
                comm += link.transfer_time(nbytes)
                nbytes_total += nbytes
            out.extend(pulled)
        out.extend(local)
        return PullResult(tuple(out), comm, nbytes_total)

    def sync(self) -> int:
        """Hub-hub backbone sync (no-op under pure gossip)."""
        if self.topology == "gossip":
            return 0
        n = sync_hubs(
            self.hubs,
            self.rng,
            self.dropout,
            planes=[self.planes[k] for k in sorted(self.planes)],
            meter=self.meter,
        )
        self.n_synced += n
        return n

    # -- failures ------------------------------------------------------------
    def fail_hub(self, hub_id: int) -> list[int]:
        """Kill a hub; returns the agents it stranded.

        Orphans re-home to the least-loaded surviving hub when one
        exists.  With every hub dead they stay detached: hub uploads are
        lost and hub pulls return nothing — under ``hybrid`` the gossip
        overlay keeps carrying their records (the Table 2 failover)."""
        self.hubs[hub_id].fail()
        orphaned = sorted(a for a, hid in self.agent_hub.items() if hid == hub_id)
        for a in orphaned:
            del self.agent_hub[a]
            if any(h.alive for h in self.hubs):
                self.attach_agent(a)
        return orphaned

    def all_known(self, plane: str = "erb") -> set[str]:
        ids: set[str] = set()
        for h in self.hubs:
            ids |= set(h.store(plane))
        if self.gossip is not None:
            ids |= self.gossip.all_known(plane)
        return ids
