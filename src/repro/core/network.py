"""Decentralized topology: agents <-> hubs, hub peering, failure injection.

Communication complexity is linear in agents (each talks to one hub);
hub-hub sync is the only n^2 term and n_hubs << n_agents.

The network is plane-agnostic: it carries a registry of
:class:`~repro.core.plane.SharePlane` objects (the ERB plane by
default), and every push/pull names the plane it rides on.  Dropout,
hub liveness, and hub-hub sync apply to all planes uniformly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set

import numpy as np

from repro.core.hub import Hub, sync_hubs
from repro.core.plane import ERBPlane, SharePlane


@dataclass
class Network:
    hubs: List[Hub]
    agent_hub: Dict[int, int] = field(default_factory=dict)
    dropout: float = 0.0
    rng: np.random.Generator = field(
        default_factory=lambda: np.random.default_rng(0))
    planes: Dict[str, SharePlane] = field(
        default_factory=lambda: {"erb": ERBPlane()})
    # statistics (aggregate and per plane)
    n_pushed: int = 0
    n_dropped: int = 0
    n_synced: int = 0
    plane_pushed: Dict[str, int] = field(default_factory=dict)

    # -- wiring ------------------------------------------------------------
    def register_plane(self, plane: SharePlane) -> SharePlane:
        self.planes[plane.name] = plane
        return plane

    def attach_agent(self, agent_id: int, hub_id: Optional[int] = None):
        """New agents attach to the least-loaded live hub by default."""
        if hub_id is None:
            loads = {h.hub_id: 0 for h in self.hubs if h.alive}
            for a, hid in self.agent_hub.items():
                if hid in loads:
                    loads[hid] += 1
            hub_id = min(loads, key=lambda k: (loads[k], k))
        self.agent_hub[agent_id] = hub_id

    def detach_agent(self, agent_id: int):
        self.agent_hub.pop(agent_id, None)

    def hub_of(self, agent_id: int) -> Hub:
        return self.hubs[self.agent_hub[agent_id]]

    # -- data planes ---------------------------------------------------------
    def agent_push(self, agent_id: int, item: Any,
                   plane: str = "erb") -> bool:
        """Agent uploads one record to its hub on ``plane`` (may drop)."""
        if self.dropout > 0.0 and self.rng.random() < self.dropout:
            self.n_dropped += 1
            return False
        hub = self.hub_of(agent_id)
        if not hub.alive:
            self.n_dropped += 1
            return False
        if not hub.push(item, self.planes[plane]):
            return False          # refused by the plane (duplicate/stale)
        self.n_pushed += 1
        self.plane_pushed[plane] = self.plane_pushed.get(plane, 0) + 1
        return True

    def agent_pull(self, agent_id: int, seen: Set[str],
                   plane: str = "erb") -> List[Any]:
        hub = self.hub_of(agent_id)
        pulled = hub.pull_unseen(seen, plane)
        if self.dropout > 0.0:
            pulled = [e for e in pulled if self.rng.random() >= self.dropout]
        return pulled

    def sync(self) -> int:
        n = sync_hubs(self.hubs, self.rng, self.dropout,
                      planes=[self.planes[k] for k in sorted(self.planes)])
        self.n_synced += n
        return n

    # -- failures ------------------------------------------------------------
    def fail_hub(self, hub_id: int):
        self.hubs[hub_id].fail()
        # re-home orphaned agents to surviving hubs
        for a, hid in list(self.agent_hub.items()):
            if hid == hub_id:
                del self.agent_hub[a]
                if any(h.alive for h in self.hubs):
                    self.attach_agent(a)

    def all_known(self, plane: str = "erb") -> Set[str]:
        ids: Set[str] = set()
        for h in self.hubs:
            ids |= set(h.store(plane))
        return ids

    def all_known_erbs(self) -> Set[str]:
        return self.all_known("erb")
