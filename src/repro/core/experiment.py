"""Experiment-facing primitives shared by core systems and ``repro.experiments``.

This module is the dependency floor of the declarative experiment API:
it defines the artifacts a :class:`~repro.experiments.protocol.System`
produces (:class:`Report`, :class:`RoundRecord`, :class:`EvalPoint`),
the declarative churn schedule entry (:class:`ChurnEvent`), and the
lifecycle hook protocol (:class:`ExperimentHooks`) that systems emit
into.  It imports nothing from the rest of ``repro.core``, so both the
core systems (``repro.core.federated``) and the scenario layer
(``repro.experiments``) can import it without cycles.

Hooks replace the old inline ``history.append`` calls: a system carries
a tuple of :class:`ExperimentHooks` and emits ``on_round_start`` /
``on_mix`` / ``on_push`` / ``on_round_end`` / ``on_eval`` / ``on_churn``
at the corresponding points of its event loop.  Metrics, forgetting
curves, and bandwidth accounting become pluggable callbacks; the default
:class:`HistoryRecorder` reproduces the classic ``system.history`` list.
"""

from __future__ import annotations

from collections.abc import Sequence
from dataclasses import dataclass, field
from typing import Any


@dataclass
class RoundRecord:
    """One completed ADFLL round (what ``system.history`` collects)."""

    agent_id: int
    round_idx: int
    task: str
    start: float
    end: float
    n_incoming: int
    loss: float
    n_mixed: int = 0  # peer weight snapshots folded in (weight plane)
    comm_time: float = 0.0  # link time charged to this round (pull side)


@dataclass
class EvalPoint:
    """One evaluation probe: mean error over the live agents at time t."""

    t: float
    n_agents: int
    mean_err: float
    per_agent: dict[str, float] = field(default_factory=dict)


@dataclass(frozen=True)
class ChurnEvent:
    """One timed membership change in a scenario's churn schedule.

    ``action="add"`` joins ``count`` fresh agents (``speed``/``hub``
    apply to each); ``action="remove"`` detaches ``agent_id`` — or, when
    ``agent_id`` is None, the ``count`` newest live agents (matching the
    paper's deletion ablation, which retires the most recent joiners).
    """

    at: float
    action: str  # "add" | "remove"
    count: int = 1
    agent_id: int | None = None
    speed: float = 1.0
    hub: int | None = None

    def __post_init__(self):
        if self.action not in ("add", "remove"):
            raise ValueError(f"unknown churn action: {self.action!r}")
        if self.agent_id is not None and self.count != 1:
            raise ValueError("explicit agent_id implies count=1")


@dataclass(frozen=True)
class HubFailure:
    """One timed hub failure in a scenario's failure schedule.

    At simulated time ``at`` hub ``hub_id`` dies, losing every record no
    other hub holds; its agents re-home to surviving hubs (if any) or
    fall back to the gossip overlay under ``topology="hybrid"``.  This
    is the paper's Table 2 robustness experiment as a declarative event.
    """

    at: float
    hub_id: int

    def __post_init__(self):
        if self.hub_id < 0:
            raise ValueError(f"negative hub_id: {self.hub_id}")


@dataclass
class Report:
    """What ``System.run()`` returns: one experiment's full outcome.

    The run-side fields (makespan, history, transport counters) are
    filled by the system itself; the evaluation fields (``task_errors``,
    ``mean_dist_err``, ``eval_curve``) are filled by the runner after it
    calls ``System.evaluate``.  ``task_errors`` maps an agent label
    (``"Agent1"``, ``"AgentX"``, ``"FedAvg"``, ...) to per-task mean
    terminal distance errors.
    """

    scenario: str = ""
    system: str = ""
    seed: int = 0
    # -- run ---------------------------------------------------------------
    makespan: float = 0.0
    n_rounds: int = 0
    comm_time: float = 0.0
    history: list[RoundRecord] = field(default_factory=list)
    n_mixed: int = 0
    n_foreign_erbs: int = 0
    # -- transport ---------------------------------------------------------
    bytes_by_plane: dict[str, int] = field(default_factory=dict)
    msgs_by_plane: dict[str, int] = field(default_factory=dict)
    plane_pushed: dict[str, int] = field(default_factory=dict)
    records_known: dict[str, int] = field(default_factory=dict)
    # -- evaluation --------------------------------------------------------
    task_errors: dict[str, dict[str, float]] = field(default_factory=dict)
    mean_dist_err: float = float("nan")
    best_agent_err: float = float("nan")
    eval_curve: list[EvalPoint] = field(default_factory=list)
    eval_patients: int | None = None
    eval_episodes: int = 0
    extra: dict[str, Any] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_plane.values())

    def agent_means(self) -> dict[str, float]:
        """Per-agent mean error across the evaluated tasks."""
        return {
            label: float(sum(errs.values()) / len(errs))
            for label, errs in self.task_errors.items()
            if errs
        }

    def summary(self) -> dict[str, Any]:
        """Flat JSON-able metrics (the ``configs`` entry CI gates on)."""
        out = {
            "system": self.system,
            "seed": self.seed,
            "mean_dist_err": self.mean_dist_err,
            "best_agent_err": self.best_agent_err,
            # None (not 0.0) for systems with no simulated clock
            "sim_makespan": self.makespan or None,
            "comm_time": self.comm_time,
            "n_rounds": self.n_rounds,
            "n_mixed": self.n_mixed,
            "n_foreign_erbs": self.n_foreign_erbs,
            "pushed": dict(self.plane_pushed),
            "bytes_by_plane": dict(self.bytes_by_plane),
            "msgs_by_plane": dict(self.msgs_by_plane),
            "total_bytes": self.total_bytes,
            "eval_patients": self.eval_patients,
            "eval_episodes": self.eval_episodes,
            "eval_curve": [
                {"t": p.t, "n_agents": p.n_agents, "mean_err": p.mean_err}
                for p in self.eval_curve
            ],
        }
        if self.extra:
            out["extra"] = dict(self.extra)
        return out


class ExperimentHooks:
    """Lifecycle callbacks a system emits; every method is a no-op.

    ``system`` is the emitting system object; hooks must not consume any
    of its random streams (determinism across hook configurations is a
    tested invariant).
    """

    def on_round_start(self, system, agent_id: int, task, t: float) -> None:
        """An agent begins a round on ``task`` at simulated time ``t``."""

    def on_mix(
        self, system, agent_id: int, n_mixed: int, comm_time: float, t: float
    ) -> None:
        """Peer weight snapshots were folded into ``agent_id``'s params."""

    def on_push(self, system, agent_id: int, plane: str, result, t: float) -> None:
        """A record left the agent on ``plane`` (``result`` is a
        :class:`~repro.core.network.PushResult`)."""

    def on_round_end(self, system, record: RoundRecord) -> None:
        """A round's training completed (training is eager; ``record``
        carries the projected simulated ``start``/``end``).  The round's
        pushes fire later, at ``record.end`` on the simulated clock —
        and never fire at all if the agent is removed while the round is
        in flight, though the record remains (the paper's failure
        semantics: the work happened, its shares were lost)."""

    def on_eval(self, system, point: EvalPoint) -> None:
        """An evaluation probe fired."""

    def on_churn(
        self, system, event: ChurnEvent, agent_ids: Sequence[int], t: float
    ) -> None:
        """A churn event was applied to ``agent_ids``."""

    def on_hub_failure(
        self, system, event: HubFailure, orphaned: Sequence[int], t: float
    ) -> None:
        """A hub died; ``orphaned`` are the agents it stranded (they are
        re-homed to surviving hubs when any exist)."""

    def on_availability(self, system, agent_id: int, online: bool, t: float) -> None:
        """An agent's availability changed (population dynamics): offline
        agents finish in-flight rounds but start no new ones and are
        never sampled by gossip."""


class HistoryRecorder(ExperimentHooks):
    """The default metrics hook: collects :class:`RoundRecord` objects
    (what used to be an inline ``self.history.append``)."""

    def __init__(self):
        self.records: list[RoundRecord] = []

    def on_round_end(self, system, record: RoundRecord) -> None:
        self.records.append(record)


class CommLog(ExperimentHooks):
    """Optional bandwidth-accounting hook: one row per push, with the
    link time and bytes the transport charged for it."""

    def __init__(self):
        self.rows: list[dict[str, Any]] = []

    def on_push(self, system, agent_id: int, plane: str, result, t: float) -> None:
        self.rows.append(
            {
                "t": t,
                "agent_id": agent_id,
                "plane": plane,
                "delivered": bool(result),
                "comm_time": getattr(result, "comm_time", 0.0),
                "nbytes": getattr(result, "nbytes", 0),
            }
        )


__all__ = [
    "ChurnEvent",
    "CommLog",
    "EvalPoint",
    "ExperimentHooks",
    "HistoryRecorder",
    "HubFailure",
    "Report",
    "RoundRecord",
]
