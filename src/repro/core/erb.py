"""Experience Replay Buffers (ERBs) — the unit of federation in ADFLL.

An ERB is (a) a fixed-capacity ring buffer of [s, a, r, s', done] tuples
held as a JAX pytree of arrays, and (b) a metadata record (Fig. 7 of the
paper: modality / landmark / pathology tags plus provenance) that hubs use
to index their shared database.

The paper shares experience *data*, never model weights — that is what
makes ADFLL architecture-agnostic. ERBs are therefore self-describing and
model-free.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, replace
from typing import Any

import jax.numpy as jnp
import numpy as np

_ERB_COUNTER = itertools.count()


@dataclass(frozen=True)
class TaskTag:
    """One BraTS task-environment: modality x orientation x pathology."""

    modality: str  # t1 | t1ce | t2 | flair
    orientation: str  # axial | coronal | sagittal
    pathology: str  # HGG | LGG
    landmark: str = "top_left_ventricle"

    @property
    def name(self) -> str:
        return f"{self.orientation}_{self.pathology}_{self.modality}"


@dataclass(frozen=True)
class ERBMeta:
    erb_id: str
    task: TaskTag
    source_agent: int
    round_idx: int
    size: int
    #: observatory-stamped provenance: sorted (agent_id, round_idx) pairs
    #: of the sender's peer-progress view at share time; never read by
    #: the numeric path (default stays empty when telemetry is off).
    version_vector: tuple = ()


def new_erb_id(prefix: str = "ERB") -> str:
    return f"{prefix}_{next(_ERB_COUNTER):05d}"


@dataclass
class ERB:
    """data: dict of arrays with leading dim = capacity; ``size`` filled."""

    meta: ERBMeta
    data: dict[str, Any]
    capacity: int
    size: int = 0
    cursor: int = 0
    version: int = 0  # bumped by erb_add; device-side caches key on it

    def __len__(self) -> int:
        return self.size


def erb_init(
    capacity: int,
    obs_shape: tuple[int, ...],
    *,
    task: TaskTag,
    source_agent: int = -1,
    round_idx: int = 0,
    dtype=np.float32,
) -> ERB:
    data = {
        "obs": np.zeros((capacity, *obs_shape), dtype),
        "loc": np.zeros((capacity, 3), dtype),
        "action": np.zeros((capacity,), np.int32),
        "reward": np.zeros((capacity,), np.float32),
        "next_obs": np.zeros((capacity, *obs_shape), dtype),
        "next_loc": np.zeros((capacity, 3), dtype),
        "done": np.zeros((capacity,), np.float32),
    }
    meta = ERBMeta(new_erb_id(), task, source_agent, round_idx, 0)
    return ERB(meta=meta, data=data, capacity=capacity)


def erb_add(erb: ERB, batch: dict[str, np.ndarray]) -> ERB:
    """Ring-append a batch of experiences (host-side, in place on data)."""
    n = int(batch["action"].shape[0])
    cap = erb.capacity
    idx = (erb.cursor + np.arange(n)) % cap
    for k, v in batch.items():
        erb.data[k][idx] = np.asarray(v)
    size = min(cap, erb.size + n)
    erb.size = size
    erb.cursor = (erb.cursor + n) % cap
    erb.version += 1
    erb.meta = replace(erb.meta, size=size)
    return erb


def erb_sample_indices(erb: ERB, rng: np.random.Generator, n: int) -> np.ndarray:
    """The index-selection half of :func:`erb_sample`: uniformly choose n
    row indices (with replacement iff n > size), consuming ``rng`` exactly
    as ``erb_sample`` does.  The fleet engine uses this to plan batches on
    the host while materializing rows on device."""
    assert erb.size > 0, "sampling an empty ERB"
    replace_ = n > erb.size
    return rng.choice(erb.size, size=n, replace=replace_)


def erb_take(
    erb: ERB, idx: np.ndarray, *, use_pallas: bool = False
) -> dict[str, np.ndarray]:
    """Materialize the rows selected by ``idx`` (host gather, or the
    Pallas ``replay_gather`` kernel when ``use_pallas``)."""
    n = len(idx)
    if use_pallas:
        from repro.kernels.replay_gather.ops import replay_gather

        flat = {}
        for k, v in erb.data.items():
            arr = jnp.asarray(v).reshape(erb.capacity, -1)
            w = jnp.ones((n,), jnp.float32)
            out = replay_gather(arr, jnp.asarray(idx, jnp.int32), w)
            flat[k] = np.asarray(out).reshape((n,) + v.shape[1:])
        return flat
    return {k: v[idx] for k, v in erb.data.items()}


def erb_sample(
    erb: ERB, rng: np.random.Generator, n: int, *, use_pallas: bool = False
) -> dict[str, np.ndarray]:
    """Uniformly sample n experiences (with replacement if n > size)."""
    return erb_take(erb, erb_sample_indices(erb, rng, n), use_pallas=use_pallas)


# -- flat row layout (device-resident replay) --------------------------------
# The fleet engine keeps each ERB on device as one [size, F] float32 matrix
# so a minibatch is a single row gather. Column order is fixed:
FLAT_FIELDS: tuple[str, ...] = (
    "obs",
    "loc",
    "action",
    "reward",
    "next_obs",
    "next_loc",
    "done",
)


def flat_width(obs_shape: tuple[int, ...]) -> int:
    """Row width of the flattened experience layout."""
    obs_f = int(np.prod(obs_shape))
    return 2 * obs_f + 3 + 3 + 3  # obs+next_obs, loc+next_loc, a/r/done


def erb_flatten(erb: ERB) -> np.ndarray:
    """[size, F] float32 view of the filled rows, columns in FLAT_FIELDS
    order (action stored as float32 — exact for small ints)."""
    s = erb.size
    cols = []
    for k in FLAT_FIELDS:
        v = erb.data[k][:s]
        cols.append(v.reshape(s, -1).astype(np.float32, copy=False))
    return np.concatenate(cols, axis=1)


def erb_share_slice(
    erb: ERB, n: int, rng: np.random.Generator, strategy: str = "uniform"
) -> ERB:
    """Selective share: a new ERB holding <=n selected experiences.

    This is the paper's 'resulting experience from the training is shared'
    step; selective experience replay (Rolnick et al.) shares a subset, not
    the raw stream.

    strategy:
      "uniform" — uniform subsample (the paper's implicit choice);
      "reward"  — beyond-paper: surprise-weighted selection, sampling
                  proportional to |reward| + eps (Rolnick et al. found
                  reward-based selection strongest for forgetting).
    """
    n = min(n, erb.size)
    if strategy == "reward":
        w = np.abs(erb.data["reward"][: erb.size]).astype(np.float64) + 1e-3
        p = w / w.sum()
        idx = rng.choice(erb.size, size=n, replace=False, p=p)
    else:
        idx = rng.choice(erb.size, size=n, replace=False)
    data = {k: v[idx].copy() for k, v in erb.data.items()}
    # pad to capacity n exactly (shared ERBs are full by construction)
    meta = ERBMeta(
        new_erb_id(), erb.meta.task, erb.meta.source_agent, erb.meta.round_idx, n
    )
    return ERB(meta=meta, data=data, capacity=n, size=n, cursor=0)


def stack_batches(batches) -> dict[str, np.ndarray]:
    keys = batches[0].keys()
    return {k: np.concatenate([b[k] for b in batches], 0) for k in keys}
