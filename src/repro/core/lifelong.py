"""LifelongTrainer — model-agnostic ADFLL wrapper.

The paper's replay mixing is model-free: it works for any learner whose
update consumes a batch pytree. This wrapper federates *any* train_step —
the DQN agents use it implicitly via ``DQNAgent.train_steps``; the LM
example (examples/federated_lm.py) uses it to lifelong-train a transformer
from the zoo on a stream of text "tasks", proving the architecture-
agnosticism claim at framework level.
"""

from __future__ import annotations

from collections.abc import Callable, Sequence
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core.erb import ERB
from repro.core.replay import SelectiveReplaySampler


@dataclass
class LifelongTrainer:
    """train_step(state, batch) -> (state, metrics); batches are pytrees
    of numpy arrays sampled from ERBs via selective replay."""

    train_step: Callable
    state: Any
    batch_size: int
    mix: Sequence[float] = (0.5, 0.25, 0.25)
    rng: np.random.Generator = field(default_factory=lambda: np.random.default_rng(0))
    personal: list[ERB] = field(default_factory=list)
    seen_erb_ids: set = field(default_factory=set)

    def __post_init__(self):
        self.sampler = SelectiveReplaySampler(mix=self.mix)

    def steps(
        self, n: int, current: ERB | None, incoming: Sequence[ERB] = ()
    ) -> dict[str, float]:
        for e in incoming:
            self.seen_erb_ids.add(e.meta.erb_id)
        metrics: dict[str, float] = {}
        for _ in range(n):
            batch = self.sampler.sample(
                self.rng,
                self.batch_size,
                current,
                personal=self.personal,
                incoming=incoming,
            )
            self.state, m = self.train_step(self.state, batch)
            metrics = {k: float(v) for k, v in m.items()}
        if current is not None:
            self.personal.append(current)
            self.seen_erb_ids.add(current.meta.erb_id)
        return metrics
