from repro.core.erb import ERB, ERBMeta, TaskTag, erb_init  # noqa: F401
from repro.core.experiment import (  # noqa: F401
    ChurnEvent,
    CommLog,
    EvalPoint,
    ExperimentHooks,
    HistoryRecorder,
    Report,
    RoundRecord,
)
from repro.core.federated import (  # noqa: F401
    ADFLLSystem,
    CentralAggregationSystem,
    train_all_knowing,
    train_partial,
    train_sequential_ll,
)
from repro.core.gossip import (  # noqa: F401
    BandwidthMeter,
    FullMeshSampler,
    GossipTopology,
    LinkModel,
    PeerSampler,
    RandomKSampler,
    RingSampler,
    SiteLinks,
    TimeVaryingSampler,
    make_sampler,
)
from repro.core.hub import Hub, sync_hubs  # noqa: F401
from repro.core.lifelong import LifelongTrainer  # noqa: F401
from repro.core.network import Network, PullResult, PushResult  # noqa: F401
from repro.core.plane import (  # noqa: F401
    CompressedWeightPlane,
    CompressedWeightSnapshot,
    ERBPlane,
    SharePlane,
    WeightPlane,
    WeightSnapshot,
    mix_params,
    staleness_alphas,
    staleness_weight,
)
from repro.core.replay import SelectiveReplaySampler  # noqa: F401
from repro.core.scheduler import Scheduler  # noqa: F401
