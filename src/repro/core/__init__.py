from repro.core.erb import ERB, ERBMeta, TaskTag, erb_init  # noqa: F401
from repro.core.federated import (ADFLLSystem,  # noqa: F401
                                  CentralAggregationSystem,
                                  train_all_knowing, train_partial,
                                  train_sequential_ll)
from repro.core.hub import Hub, sync_hubs  # noqa: F401
from repro.core.lifelong import LifelongTrainer  # noqa: F401
from repro.core.network import Network  # noqa: F401
from repro.core.plane import (ERBPlane, SharePlane,  # noqa: F401
                              WeightPlane, WeightSnapshot, mix_params,
                              staleness_alphas, staleness_weight)
from repro.core.replay import SelectiveReplaySampler  # noqa: F401
from repro.core.scheduler import Scheduler  # noqa: F401
