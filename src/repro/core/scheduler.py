"""Asynchronous event-driven scheduler.

XLA programs are bulk-synchronous, so ADFLL's *asynchrony* lives here, at
the host control plane: a discrete-event simulator with heterogeneous
agent speeds (the paper's V100-vs-T4 deployment), hub sync timers,
gossip anti-entropy timers, agent churn (addition/deletion ablations),
and the paper's round policy — "when an agent finishes training on a
task, as long as there are new ERBs it has not learned from, it starts a
new round".

The *content* of a round (DQN training on real tensors) executes eagerly
when its event fires; only simulated time is virtual.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

EventFn = Callable[["Scheduler", float], None]


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: EventFn = field(compare=False)
    tag: str = field(compare=False, default="")


class Scheduler:
    """Deterministic discrete-event loop (ties broken by insertion order)."""

    def __init__(self):
        self._heap: List[_Event] = []
        self._seq = itertools.count()
        self.now = 0.0
        self.log: List[Tuple[float, str]] = []

    def at(self, time: float, fn: EventFn, tag: str = "") -> None:
        heapq.heappush(self._heap, _Event(time, next(self._seq), fn, tag))

    def after(self, delay: float, fn: EventFn, tag: str = "") -> None:
        self.at(self.now + delay, fn, tag)

    def every(
        self,
        period: float,
        fn: EventFn,
        tag: str = "",
        until: Optional[float] = None,
        phase: Optional[float] = None,
    ) -> None:
        """Periodic event; first firing after ``phase`` (default: one
        period), so co-periodic timers can be offset from each other."""

        def tick(sched: "Scheduler", t: float):
            fn(sched, t)
            if until is None or t + period <= until:
                sched.at(t + period, tick, tag)

        first = period if phase is None else phase
        self.at(self.now + first, tick, tag)

    def cancel(self, tag: str) -> None:
        """Drop every *pending* event carrying ``tag``.

        Periodic timers stop because their next tick is removed before it
        can re-arm; the tag itself stays usable — re-registering an event
        under it later works.  A timer cannot cancel itself from inside
        its own callback (the re-arm happens after the callback returns);
        cancel from another event or use ``until`` for that."""
        if not tag:
            return
        self._heap = [e for e in self._heap if e.tag != tag]
        heapq.heapify(self._heap)

    def run(
        self,
        until: float = float("inf"),
        stop: Optional[Callable[[], bool]] = None,
    ) -> float:
        while self._heap:
            ev = heapq.heappop(self._heap)
            if ev.time > until:
                heapq.heappush(self._heap, ev)
                break
            self.now = ev.time
            if ev.tag:
                self.log.append((self.now, ev.tag))
            ev.fn(self, self.now)
            if stop is not None and stop():
                break
        return self.now
