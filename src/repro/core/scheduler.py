"""Asynchronous event-driven scheduler.

XLA programs are bulk-synchronous, so ADFLL's *asynchrony* lives here, at
the host control plane: a discrete-event simulator with heterogeneous
agent speeds (the paper's V100-vs-T4 deployment), hub sync timers,
gossip anti-entropy timers, agent churn (addition/deletion ablations),
population availability processes, and the paper's round policy — "when
an agent finishes training on a task, as long as there are new ERBs it
has not learned from, it starts a new round".

The *content* of a round (DQN training on real tensors) executes eagerly
when its event fires; only simulated time is virtual.

Every registration (``at`` / ``after`` / ``every``) returns a
:class:`Handle` whose ``cancel()`` works from *any* context — including
inside the event's own callback, which tag-based :meth:`Scheduler.cancel`
cannot reach (the periodic re-arm happens after the callback returns).
Availability processes lean on this to self-terminate.
"""

from __future__ import annotations

import heapq
import itertools
from collections import deque
from collections.abc import Callable
from dataclasses import dataclass, field

from repro.telemetry import NULL, Telemetry

EventFn = Callable[["Scheduler", float], None]


class Handle:
    """Cancellation token for one scheduled event or periodic timer.

    ``cancel()`` is safe from any context: a cancelled event is skipped
    (not fired, not logged) when it reaches the head of the heap, and a
    periodic timer checks the flag both before firing and before
    re-arming — so a timer *can* cancel itself from inside its own
    callback, which tag-based cancellation cannot do.
    """

    __slots__ = ("cancelled",)

    def __init__(self):
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True

    @property
    def active(self) -> bool:
        return not self.cancelled


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: EventFn = field(compare=False)
    tag: str = field(compare=False, default="")
    handle: Handle | None = field(compare=False, default=None)


class Scheduler:
    """Deterministic discrete-event loop (ties broken by insertion order).

    ``log_max`` bounds the tagged-event log to a ring buffer keeping the
    *newest* entries (``log_dropped`` counts evictions) — opt in for
    long population runs, where logging every tagged event forever would
    grow host memory linearly with simulated time.

    With a :class:`~repro.telemetry.Telemetry` bundle attached, every
    tagged event additionally lands as an instant on the ``scheduler``
    sim-clock track and increments the ``sched.events{tag=...}``
    counter; the ``log``/``log_dropped`` ring stays as-is, so existing
    consumers keep working unchanged.
    """

    def __init__(
        self,
        log_max: int | None = None,
        *,
        telemetry: Telemetry | None = None,
    ):
        self._heap: list[_Event] = []
        self._seq = itertools.count()
        self.now = 0.0
        self.log_max = log_max
        self.log = deque(maxlen=log_max) if log_max is not None else []
        self.log_dropped = 0
        self.telemetry = telemetry if telemetry is not None else NULL

    def at(self, time: float, fn: EventFn, tag: str = "") -> Handle:
        handle = Handle()
        heapq.heappush(self._heap, _Event(time, next(self._seq), fn, tag, handle))
        return handle

    def after(self, delay: float, fn: EventFn, tag: str = "") -> Handle:
        return self.at(self.now + delay, fn, tag)

    def every(
        self,
        period: float,
        fn: EventFn,
        tag: str = "",
        until: float | None = None,
        phase: float | None = None,
    ) -> Handle:
        """Periodic event; first firing after ``phase`` (default: one
        period), so co-periodic timers can be offset from each other.
        Every tick shares the returned :class:`Handle`: cancelling it —
        even from inside ``fn`` itself — stops the timer for good."""

        handle = Handle()

        def tick(sched: "Scheduler", t: float):
            fn(sched, t)
            if handle.cancelled:
                return
            if until is None or t + period <= until:
                sched._push(t + period, tick, tag, handle)

        first = period if phase is None else phase
        self._push(self.now + first, tick, tag, handle)
        return handle

    def _push(self, time: float, fn: EventFn, tag: str, handle: Handle) -> None:
        heapq.heappush(self._heap, _Event(time, next(self._seq), fn, tag, handle))

    def cancel(self, tag: str) -> None:
        """Drop every *pending* event carrying ``tag`` (shim over the
        handle machinery for call sites that did not keep a handle).

        Periodic timers stop because their next tick is removed before
        it can re-arm; the tag itself stays usable — re-registering an
        event under it later works.  A timer cannot cancel itself by tag
        from inside its own callback (the re-arm happens after the
        callback returns); use the :class:`Handle` returned by
        :meth:`every` for that."""
        if not tag:
            return
        self._heap = [e for e in self._heap if e.tag != tag]
        heapq.heapify(self._heap)

    def _log(self, tag: str) -> None:
        if self.log_max is not None and len(self.log) >= self.log_max:
            self.log_dropped += 1
        self.log.append((self.now, tag))
        if self.telemetry.enabled:
            self.telemetry.instant(tag, "scheduler", self.now)
            self.telemetry.count("sched.events", 1, tag=tag)

    def run(
        self,
        until: float = float("inf"),
        stop: Callable[[], bool] | None = None,
    ) -> float:
        while self._heap:
            ev = heapq.heappop(self._heap)
            if ev.handle is not None and ev.handle.cancelled:
                continue
            if ev.time > until:
                heapq.heappush(self._heap, ev)
                break
            self.now = ev.time
            if ev.tag:
                self._log(ev.tag)
            ev.fn(self, self.now)
            if stop is not None and stop():
                break
        return self.now


__all__ = ["EventFn", "Handle", "Scheduler"]
