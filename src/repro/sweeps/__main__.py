"""CLI for multi-seed sweep grids.

    PYTHONPATH=src python -m repro.sweeps --list
    PYTHONPATH=src python -m repro.sweeps --sweep ci_smoke --fast
    PYTHONPATH=src python -m repro.sweeps --sweep paper_table1_sweep \
        --fast --json out.json
    PYTHONPATH=src python -m repro.sweeps --compare old.json new.json

``--sweep`` expands the grid, resumes from the on-disk report store
(``--store``, default ``.sweeps/<name>[.fast].jsonl``), runs the missing
cells in parallel under per-cell wall-time budgets, and prints per-
variant mean ± 95% CI plus paired p-values against the sweep's baseline
variant.  Exit is nonzero when any cell failed (error or budget).

``--compare`` diffs two summary JSONs seed-paired per variant/metric and
exits nonzero on a significant regression of a gated metric.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from repro.experiments.suggest import unknown_name_message
from repro.sweeps.aggregate import GATE_METRICS, compare
from repro.sweeps.executor import failed_cells, run_sweep
from repro.sweeps.registry import get_sweep, list_sweeps
from repro.sweeps.store import ReportStore


def _fmt(x, width=10, prec=3):
    if x is None:
        return " " * (width - 1) + "-"
    if isinstance(x, float):
        return f"{x:{width}.{prec}f}"
    return f"{x:>{width}}"


def _fmt_ci(ci) -> str:
    """``[lo, hi]`` bootstrap interval -> ``[lo, hi]`` cell (or ``-``)."""
    if not ci or ci[0] is None or ci[1] is None:
        return "-"
    return f"[{ci[0]:.3f}, {ci[1]:.3f}]"


def _print_summary(summary: dict) -> None:
    print(f"\nsweep {summary['sweep']} (seeds={summary['seeds']})")
    metrics = None
    for label, v in summary["variants"].items():
        if metrics is None:
            metrics = list(v["metrics"])
            print(f"{'variant':<22} {'n':>3} " + " ".join(f"{m:>24}" for m in metrics))
        cols = []
        for m in metrics:
            st = v["metrics"][m]
            mean, ci = st["mean"], st["ci95"]
            if mean is None:
                cell = "-"
            elif ci is None:
                cell = f"{mean:.3f}"
            else:
                cell = f"{mean:.3f} ± {ci:.3f}"
            cols.append(f"{cell:>24}")
        print(f"{label:<22} {v['n_ok']:>3} " + " ".join(cols))
    if summary["comparisons"]:
        print(f"\npaired vs {summary['baseline']!r}:")
        print(
            f"{'variant':<22} {'metric':<14} {'delta':>10} {'d95%':>21} "
            f"{'d':>7} {'t':>8} {'p(t)':>8} {'p(adj)':>8} {'p(perm)':>8}"
        )
        for c in summary["comparisons"]:
            print(
                f"{c['variant']:<22} {c['metric']:<14} {_fmt(c['delta'])} "
                f"{_fmt_ci(c.get('delta_ci95')):>21} "
                f"{_fmt(c.get('cohens_d'), 7, 2)} {_fmt(c['t'], 8)} "
                f"{_fmt(c['p_ttest'], 8, 4)} {_fmt(c.get('p_ttest_adj'), 8, 4)} "
                f"{_fmt(c['p_permutation'], 8, 4)}"
            )


def _cmd_compare(args) -> int:
    with open(args.compare[0]) as f:
        a = json.load(f)
    with open(args.compare[1]) as f:
        b = json.load(f)
    rows, regressions = compare(a, b, alpha=args.alpha, gate_metrics=args.gate)
    if not rows:
        print(
            "no overlapping (variant, metric, seed) cells to compare", file=sys.stderr
        )
        return 2
    print(
        f"{'variant':<22} {'metric':<14} {'A':>10} {'B':>10} {'delta':>10} "
        f"{'d95%':>21} {'d':>7} {'p(t)':>8} {'p(adj)':>8} {'p(perm)':>8}  flag"
    )
    for r in rows:
        flag = "REGRESSION" if r["regression"] else ("*" if r["significant"] else "")
        print(
            f"{r['variant']:<22} {r['metric']:<14} {_fmt(r['mean_a'])} "
            f"{_fmt(r['mean_b'])} {_fmt(r['delta'])} "
            f"{_fmt_ci(r.get('delta_ci95')):>21} "
            f"{_fmt(r.get('cohens_d'), 7, 2)} {_fmt(r['p_ttest'], 8, 4)} "
            f"{_fmt(r.get('p_ttest_adj'), 8, 4)} "
            f"{_fmt(r['p_permutation'], 8, 4)}  {flag}"
        )
    for r in regressions:
        print(
            f"REGRESSION {r['variant']}.{r['metric']}: "
            f"{r['mean_a']:.3f} -> {r['mean_b']:.3f} "
            f"(Holm-adjusted p={r['p_ttest_adj']:.4f})",
            file=sys.stderr,
        )
    return 1 if regressions else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.sweeps")
    ap.add_argument("--list", action="store_true", help="list registered sweeps")
    ap.add_argument("--sweep", metavar="NAME", help="sweep to run")
    ap.add_argument(
        "--fast", action="store_true", help="reduced per-cell step counts (CI)"
    )
    ap.add_argument(
        "--seeds", type=int, default=None, metavar="N", help="truncate the seed list"
    )
    ap.add_argument(
        "--workers",
        type=int,
        default=None,
        help="parallel worker processes (default: min(4, cpus, cells); "
        "1 runs inline)",
    )
    ap.add_argument(
        "--store",
        default=None,
        metavar="PATH",
        help="report-store JSONL for resume (default .sweeps/<name>[.fast].jsonl; "
        "'none' disables)",
    )
    ap.add_argument(
        "--budget",
        type=float,
        default=None,
        metavar="SECONDS",
        help="override the sweep's per-cell wall-time budget",
    )
    ap.add_argument("--json", default=None, metavar="OUT", help="write the summary")
    ap.add_argument(
        "--compare",
        nargs=2,
        metavar=("A.json", "B.json"),
        help="diff two sweep summaries; exit 1 on significant regression",
    )
    ap.add_argument("--alpha", type=float, default=0.05, help="significance level")
    ap.add_argument(
        "--gate",
        nargs="+",
        default=list(GATE_METRICS),
        help="metrics whose significant increase counts as a regression",
    )
    args = ap.parse_args(argv)

    if args.compare:
        return _cmd_compare(args)

    if args.list or not args.sweep:
        print(f"{'sweep':<26} {'cells':>6}  description")
        for sw in list_sweeps():
            n = len(sw.variants) * len(sw.seeds)
            print(f"{sw.name:<26} {n:>6}  {sw.description}")
        return 0

    try:
        sweep = get_sweep(args.sweep)
    except KeyError:
        known = [s.name for s in list_sweeps()]
        print(unknown_name_message("sweep", args.sweep, known), file=sys.stderr)
        return 2
    if args.seeds is not None:
        if args.seeds < 1:
            print("--seeds must be >= 1", file=sys.stderr)
            return 2
        sweep = sweep.with_seeds(sweep.seeds[: args.seeds])
    if args.budget is not None and args.budget <= 0:
        print("--budget must be > 0 seconds", file=sys.stderr)
        return 2

    store = None
    if args.store != "none":
        path = args.store or os.path.join(
            ".sweeps", f"{sweep.name}{'.fast' if args.fast else ''}.jsonl"
        )
        store = ReportStore(path)

    summary = run_sweep(
        sweep,
        fast=args.fast,
        workers=args.workers,
        store=store,
        budget_s=args.budget,
        echo=print,
    )
    _print_summary(summary)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
        print(f"\nwrote {args.json}")
    bad = failed_cells(summary)
    for c in bad:
        print(
            f"FAILED cell {c['label']} seed={c['seed']}: {c['status']}",
            file=sys.stderr,
        )
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
