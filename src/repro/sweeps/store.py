"""On-disk JSONL result store: the unit of sweep resumability.

One line per finished cell, keyed by the content-addressed cell key
(variant label + seed + derived-spec hash).  Re-running a sweep loads
the store first and only executes cells without an ``"ok"`` row — an
interrupted 20-cell sweep with 14 completed cells re-executes exactly
the missing 6.  Failed cells (errors, budget overruns) are re-attempted
on the next run; their old rows are superseded because later lines win.

The store is written by a single process (the sweep executor appends as
futures complete) and read by anyone; rows are self-contained JSON
objects, so a truncated final line (a crash mid-write) is skipped
rather than poisoning the file.
"""

from __future__ import annotations

import json
import os
from collections.abc import Iterable
from typing import Any

Row = dict[str, Any]

STATUS_OK = "ok"
STATUS_ERROR = "error"
STATUS_BUDGET = "budget_exceeded"


class ReportStore:
    """Append-only JSONL of per-cell results, keyed by cell key."""

    def __init__(self, path: str):
        self.path = str(path)

    # -- reading -----------------------------------------------------------
    def load(self) -> dict[str, Row]:
        """key -> newest row (malformed/truncated lines are skipped)."""
        rows: dict[str, Row] = {}
        if not os.path.exists(self.path):
            return rows
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    row = json.loads(line)
                except json.JSONDecodeError:
                    continue  # crash mid-append: ignore the torn tail
                key = row.get("key")
                if isinstance(key, str):
                    rows[key] = row
        return rows

    def completed(self) -> dict[str, Row]:
        """key -> row for cells that finished successfully."""
        return {k: r for k, r in self.load().items() if r.get("status") == STATUS_OK}

    def get(self, key: str) -> Row | None:
        return self.load().get(key)

    # -- writing -----------------------------------------------------------
    def append(self, row: Row) -> None:
        if "key" not in row:
            raise ValueError("store rows need a 'key'")
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(self.path, "a") as f:
            f.write(json.dumps(row, sort_keys=True) + "\n")
            f.flush()

    def extend(self, rows: Iterable[Row]) -> None:
        for row in rows:
            self.append(row)

    def prune(self, keep_keys: Iterable[str]) -> int:
        """Rewrite the file keeping only ``keep_keys`` (newest rows);
        returns how many rows were dropped.  Useful after a sweep's grid
        changed and stale cells would otherwise accumulate forever."""
        keep = set(keep_keys)
        rows = self.load()
        kept: list[Row] = [r for k, r in sorted(rows.items()) if k in keep]
        dropped = len(rows) - len(kept)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            for r in kept:
                f.write(json.dumps(r, sort_keys=True) + "\n")
        os.replace(tmp, self.path)
        return dropped


__all__ = ["ReportStore", "Row", "STATUS_BUDGET", "STATUS_ERROR", "STATUS_OK"]
