"""Significance-aware aggregation of per-seed reports.

Folds the per-cell rows a sweep produced into one summary document:

* per variant, per metric — mean ± 95% CI (t-based, scipy-free), std,
  n, and the per-seed values (kept so two summaries can later be
  *paired* by seed);
* per (baseline, variant) pair — paired t-test and paired sign-flip
  permutation p-values on each metric, seeds paired positionally by
  value (the grid guarantees every variant ran the same seed list);
* the cell ledger — status, elapsed wall time, cached-or-executed —
  so a summary is also an execution audit.

:func:`compare` diffs two summary documents (the ``--compare`` CLI
mode): a per-metric delta table with p-values, flagging *significant
regressions* (worse mean on a gated metric with p below alpha).
"""

from __future__ import annotations

import math
from collections.abc import Sequence
from typing import Any

from repro.sweeps.spec import SweepSpec
from repro.sweeps.stats import (
    bootstrap_ci,
    cohens_d,
    holm_bonferroni,
    mean_ci,
    paired_permutation_test,
    paired_ttest,
)
from repro.sweeps.store import STATUS_OK, Row

#: metrics whose significant increase fails a comparison gate
GATE_METRICS = ("mean_dist_err", "forgetting")


def _finite(x: Any) -> float | None:
    """float(x) if it is a finite number, else None (JSON-safe)."""
    if x is None:
        return None
    try:
        v = float(x)
    except (TypeError, ValueError):
        return None
    return v if math.isfinite(v) else None


def forgetting_of(summary: dict[str, Any]) -> float | None:
    """Error increase from the best probe to the final evaluation.

    ``max(0, final - min_over_curve)`` over the report's eval curve: 0
    when the final evaluation is the best seen (nothing forgotten), the
    recovery gap otherwise.  Scenarios without probes have a one-point
    curve and therefore forgetting 0."""
    curve = summary.get("eval_curve") or []
    errs = [_finite(p.get("mean_err")) for p in curve]
    errs = [e for e in errs if e is not None]
    if not errs:
        return None
    return max(0.0, errs[-1] - min(errs))


def _metric_values(rows: Sequence[Row], metric: str) -> dict[str, float]:
    """seed (as str, JSON-stable) -> finite metric value."""
    out: dict[str, float] = {}
    for r in rows:
        v = _finite((r.get("summary") or {}).get(metric))
        if v is not None:
            out[str(r["seed"])] = v
    return out


def _pair(
    a: dict[str, float], b: dict[str, float]
) -> tuple[list[float], list[float], list[str]]:
    seeds = sorted(set(a) & set(b), key=lambda s: (len(s), s))
    return [a[s] for s in seeds], [b[s] for s in seeds], seeds


def _stats_entry(values: dict[str, float]) -> dict[str, Any]:
    xs = [values[s] for s in sorted(values, key=lambda s: (len(s), s))]
    mean, half = mean_ci(xs)
    std = None
    if len(xs) >= 2:
        m = sum(xs) / len(xs)
        std = math.sqrt(sum((x - m) ** 2 for x in xs) / (len(xs) - 1))
    return {
        "mean": _finite(mean),
        "ci95": _finite(half),
        "std": _finite(std),
        "n": len(xs),
        "values": values,
    }


def summarize(
    sweep: SweepSpec, rows: Sequence[Row], *, fast: bool = False
) -> dict[str, Any]:
    """The sweep summary document (what ``--json`` writes)."""
    by_label: dict[str, list[Row]] = {v.label: [] for v in sweep.variants}
    for r in rows:
        if r.get("label") in by_label and r.get("status") == STATUS_OK:
            by_label[r["label"]].append(r)
    for vrows in by_label.values():
        vrows.sort(key=lambda r: int(r["seed"]))

    variants: dict[str, Any] = {}
    for v in sweep.variants:
        vrows = by_label[v.label]
        variants[v.label] = {
            "scenario": v.scenario,
            "overrides": [list(o) for o in v.overrides],
            "n_ok": len(vrows),
            "metrics": {
                m: _stats_entry(_metric_values(vrows, m)) for m in sweep.metrics
            },
        }

    comparisons: list[dict[str, Any]] = []
    if sweep.baseline is not None:
        base_rows = by_label[sweep.baseline]
        for v in sweep.variants:
            if v.label == sweep.baseline:
                continue
            for m in sweep.metrics:
                a, b, seeds = _pair(
                    _metric_values(base_rows, m),
                    _metric_values(by_label[v.label], m),
                )
                if not seeds:
                    continue
                t, p_t = paired_ttest(b, a)
                deltas = [y - x for x, y in zip(a, b, strict=True)]
                ci_lo, ci_hi = bootstrap_ci(deltas)
                comparisons.append(
                    {
                        "baseline": sweep.baseline,
                        "variant": v.label,
                        "metric": m,
                        "n": len(seeds),
                        "mean_baseline": _finite(sum(a) / len(a)),
                        "mean_variant": _finite(sum(b) / len(b)),
                        "delta": _finite(sum(b) / len(b) - sum(a) / len(a)),
                        "delta_ci95": [_finite(ci_lo), _finite(ci_hi)],
                        "cohens_d": _finite(cohens_d(b, a)),
                        "t": _finite(t),
                        "p_ttest": _finite(p_t),
                        "p_permutation": _finite(paired_permutation_test(b, a)),
                    }
                )
        # Holm–Bonferroni across the whole comparison family: every
        # (variant, metric) pair tested against the baseline is one
        # hypothesis, so gate-worthy significance must survive the
        # step-down adjustment, not just the raw paired t.
        adj = holm_bonferroni([c["p_ttest"] for c in comparisons])
        for c, p_adj in zip(comparisons, adj, strict=True):
            c["p_ttest_adj"] = _finite(p_adj)

    cells = [
        {
            "key": r["key"],
            "label": r.get("label"),
            "scenario": r.get("scenario"),
            "seed": r.get("seed"),
            "status": r.get("status"),
            "elapsed_s": _finite(r.get("elapsed_s")),
            "cached": bool(r.get("cached", False)),
            "error": r.get("error"),
        }
        for r in rows
    ]
    return {
        "benchmark": "sweeps",
        "sweep": sweep.name,
        "fast": bool(fast),
        "seeds": list(sweep.seeds),
        "baseline": sweep.baseline,
        "cell_budget_s": sweep.cell_budget_s,
        "variants": variants,
        "comparisons": comparisons,
        "cells": cells,
    }


def compare(
    a: dict[str, Any],
    b: dict[str, Any],
    *,
    alpha: float = 0.05,
    gate_metrics: Sequence[str] = GATE_METRICS,
) -> tuple[list[dict[str, Any]], list[dict[str, Any]]]:
    """Diff two sweep summaries; returns (delta rows, regressions).

    Rows pair per-seed values variant-by-variant and metric-by-metric.
    A *regression* is a gated metric that got significantly worse
    (higher mean, Holm-adjusted paired-t p < alpha across the whole
    comparison family); callers exit nonzero when the regression list
    is non-empty."""
    rows: list[dict[str, Any]] = []
    va, vb = a.get("variants", {}), b.get("variants", {})
    for label in sorted(set(va) & set(vb)):
        ma, mb = va[label].get("metrics", {}), vb[label].get("metrics", {})
        for metric in [m for m in ma if m in mb]:
            xs, ys, seeds = _pair(
                ma[metric].get("values", {}), mb[metric].get("values", {})
            )
            if not seeds:
                continue
            mean_a, mean_b = sum(xs) / len(xs), sum(ys) / len(ys)
            t, p_t = paired_ttest(ys, xs)
            p_perm = paired_permutation_test(ys, xs)
            deltas = [y - x for x, y in zip(xs, ys, strict=True)]
            ci_lo, ci_hi = bootstrap_ci(deltas)
            rows.append(
                {
                    "variant": label,
                    "metric": metric,
                    "n": len(seeds),
                    "mean_a": _finite(mean_a),
                    "mean_b": _finite(mean_b),
                    "delta": _finite(mean_b - mean_a),
                    "delta_ci95": [_finite(ci_lo), _finite(ci_hi)],
                    "cohens_d": _finite(cohens_d(ys, xs)),
                    "pct": _finite(
                        100.0 * (mean_b - mean_a) / abs(mean_a) if mean_a else None
                    ),
                    "t": _finite(t),
                    "p_ttest": _finite(p_t),
                    "p_permutation": _finite(p_perm),
                }
            )
    # Significance is decided on the Holm-adjusted p across the whole
    # table — a 20-row diff should not flag a regression because one
    # raw p dipped below alpha by multiplicity alone.
    adj = holm_bonferroni([r["p_ttest"] for r in rows])
    for r, p_adj in zip(rows, adj, strict=True):
        r["p_ttest_adj"] = _finite(p_adj)
        significant = r["p_ttest_adj"] is not None and r["p_ttest_adj"] < alpha
        r["significant"] = significant
        r["regression"] = bool(
            significant
            and r["metric"] in gate_metrics
            and r["mean_b"] is not None
            and r["mean_a"] is not None
            and r["mean_b"] > r["mean_a"]
        )
    return rows, [r for r in rows if r["regression"]]


__all__ = ["GATE_METRICS", "compare", "forgetting_of", "summarize"]
