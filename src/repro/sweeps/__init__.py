"""Multi-seed sweep grids over the scenario registry.

One frozen :class:`SweepSpec` (scenario × seeds × overrides) expands
into a deterministic grid of derived
:class:`~repro.experiments.spec.ScenarioSpec` cells; a process-pool
executor runs the missing cells (resuming from the on-disk JSONL
:class:`ReportStore`), and the aggregation layer folds per-seed reports
into mean ± 95% CI summaries with paired t-test / permutation-test
significance between variants:

    from repro import sweeps
    summary = sweeps.run_sweep(sweeps.get_sweep("ci_smoke"), fast=True)

or from the shell:

    python -m repro.sweeps --list
    python -m repro.sweeps --sweep paper_table1_sweep --fast --json out.json
    python -m repro.sweeps --compare old.json new.json
"""

from repro.sweeps.aggregate import (  # noqa: F401
    GATE_METRICS,
    compare,
    forgetting_of,
    summarize,
)
from repro.sweeps.executor import (  # noqa: F401
    default_workers,
    failed_cells,
    run_sweep,
)
from repro.sweeps.registry import (  # noqa: F401
    get_sweep,
    list_sweeps,
    register_sweep,
)
from repro.sweeps.spec import (  # noqa: F401
    DEFAULT_METRICS,
    SweepCell,
    SweepSpec,
    SweepVariant,
    apply_overrides,
    spec_hash,
)
from repro.sweeps.stats import (  # noqa: F401
    mean_ci,
    paired_permutation_test,
    paired_ttest,
    t_crit,
    t_sf,
)
from repro.sweeps.store import ReportStore  # noqa: F401
