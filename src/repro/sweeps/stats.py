"""Scipy-free significance tests and confidence intervals for sweeps.

Promoted from ``benchmarks/stats.py`` (which now re-exports from here):
the regularized incomplete beta gives the Student-t tail, on top of
which sit the paired t-test, a t-based mean confidence interval, and a
paired sign-flip permutation test (exact over all ``2^n`` sign patterns
for small n, seeded Monte Carlo beyond that).  Effect-size companions:
paired Cohen's ``d_z`` and a seeded percentile-bootstrap interval for
the mean of the paired deltas.

Edge cases are explicit and tested: n < 2 yields ``(nan, nan)`` /
``nan`` half-widths / p = 1.0 (no evidence either way), and
zero-variance differences yield ``t = 0, p = 1.0``.
"""

from __future__ import annotations

import itertools
import math
from collections.abc import Sequence

import numpy as np


def _betacf(a, b, x, max_iter=200, eps=3e-12):
    qab, qap, qam = a + b, a + 1.0, a - 1.0
    c, d = 1.0, 1.0 - qab * x / qap
    if abs(d) < 1e-30:
        d = 1e-30
    d = 1.0 / d
    h = d
    for m in range(1, max_iter + 1):
        m2 = 2 * m
        aa = m * (b - m) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        if abs(d) < 1e-30:
            d = 1e-30
        c = 1.0 + aa / c
        if abs(c) < 1e-30:
            c = 1e-30
        d = 1.0 / d
        h *= d * c
        aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        if abs(d) < 1e-30:
            d = 1e-30
        c = 1.0 + aa / c
        if abs(c) < 1e-30:
            c = 1e-30
        d = 1.0 / d
        delta = d * c
        h *= delta
        if abs(delta - 1.0) < eps:
            break
    return h


def _betainc(a, b, x):
    if x <= 0.0:
        return 0.0
    if x >= 1.0:
        return 1.0
    ln_beta = (
        math.lgamma(a + b)
        - math.lgamma(a)
        - math.lgamma(b)
        + a * math.log(x)
        + b * math.log(1.0 - x)
    )
    front = math.exp(ln_beta)
    if x < (a + 1.0) / (a + b + 2.0):
        return front * _betacf(a, b, x) / a
    return 1.0 - front * _betacf(b, a, 1.0 - x) / b


def t_sf(t, df):
    """Two-sided p-value for a t statistic."""
    x = df / (df + t * t)
    return _betainc(df / 2.0, 0.5, x)


def t_crit(alpha: float, df: int) -> float:
    """The two-sided critical value: ``t_sf(t_crit, df) == alpha``.

    Bisection on the monotone tail — no scipy inverse needed."""
    if df < 1:
        return float("nan")
    lo, hi = 0.0, 1e3
    while t_sf(hi, df) > alpha:  # pathological alpha: widen
        hi *= 10.0
        if hi > 1e12:
            return float("inf")
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if t_sf(mid, df) > alpha:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def paired_ttest(a, b) -> tuple[float, float]:
    """Returns (t, two-sided p). a, b: paired samples.

    n < 2 has no t distribution: returns ``(nan, nan)``.  Zero-variance
    differences return ``(0.0, 1.0)`` (identical trajectories are not
    evidence of a difference)."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    d = a - b
    n = len(d)
    if n < 2:
        return float("nan"), float("nan")
    sd = d.std(ddof=1)
    if sd == 0:
        return 0.0, 1.0
    t = d.mean() / (sd / math.sqrt(n))
    return float(t), float(t_sf(abs(t), n - 1))


def paired_permutation_test(
    a, b, *, n_resamples: int = 10_000, seed: int = 0
) -> float:
    """Two-sided p for ``mean(a - b) != 0`` under paired sign-flips.

    The null distribution flips the sign of each paired difference
    independently.  All ``2^n`` patterns are enumerated exactly while
    ``2^n <= n_resamples``; beyond that a seeded Monte Carlo sample is
    drawn and the add-one estimator keeps p > 0.  n < 2 returns 1.0
    (a single pair cannot reach significance), as do all-zero
    differences."""
    d = np.asarray(a, np.float64) - np.asarray(b, np.float64)
    n = len(d)
    if n < 2 or not np.any(d):
        return 1.0
    obs = abs(float(d.mean()))
    tol = 1e-12 * max(1.0, obs)
    if 2**n <= n_resamples:
        hits = 0
        for signs in itertools.product((1.0, -1.0), repeat=n):
            if abs(float(np.dot(signs, d)) / n) >= obs - tol:
                hits += 1
        return hits / 2**n
    rng = np.random.default_rng(seed)
    signs = rng.choice((-1.0, 1.0), size=(n_resamples, n))
    means = np.abs(signs @ d) / n
    hits = int(np.sum(means >= obs - tol))
    return float((hits + 1) / (n_resamples + 1))


def cohens_d(a, b) -> float:
    """Paired effect size ``d_z = mean(a - b) / sd(a - b)``.

    The standardized size of a paired delta — p-values say whether an
    effect exists, ``d_z`` says whether it is big enough to care about
    (|d| ~ 0.2 small / 0.5 medium / 0.8 large, Cohen's conventions).

    n < 2 returns nan (no spread to standardize by).  Zero-variance
    differences return signed inf for a nonzero mean shift (every pair
    moved by exactly the same amount) and 0.0 when the trajectories are
    identical."""
    d = np.asarray(a, np.float64) - np.asarray(b, np.float64)
    n = len(d)
    if n < 2:
        return float("nan")
    sd = float(d.std(ddof=1))
    mean = float(d.mean())
    if sd == 0.0:
        return 0.0 if mean == 0.0 else math.copysign(float("inf"), mean)
    return mean / sd


def bootstrap_ci(
    values: Sequence[float],
    *,
    confidence: float = 0.95,
    n_resamples: int = 10_000,
    seed: int = 0,
) -> tuple[float, float]:
    """Seeded percentile-bootstrap interval ``(lo, hi)`` for the mean.

    Distribution-free companion to the t-based :func:`mean_ci` — with
    the handful of seeds a sweep runs, paired deltas are often visibly
    non-normal (one outlier seed) and the t interval under- or
    over-covers.  n == 0 returns ``(nan, nan)``; n == 1 returns
    ``(x, x)`` (resampling one value only ever yields itself)."""
    x = np.asarray(list(values), np.float64)
    n = len(x)
    if n == 0:
        return float("nan"), float("nan")
    if n == 1:
        return float(x[0]), float(x[0])
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n, size=(n_resamples, n))
    means = x[idx].mean(axis=1)
    tail = 100.0 * (1.0 - confidence) / 2.0
    lo, hi = np.percentile(means, [tail, 100.0 - tail])
    return float(lo), float(hi)


def holm_bonferroni(pvalues: Sequence[float | None]) -> list[float | None]:
    """Holm's step-down adjusted p-values for a family of comparisons.

    The sweep summary tests every (variant, metric) pair against the
    baseline — m hypotheses, so the chance of at least one spurious
    p < alpha grows with m.  Holm's procedure controls the family-wise
    error rate uniformly better than plain Bonferroni: sort the valid
    p-values ascending, multiply the k-th smallest by ``m - k`` (1-based:
    ``m, m-1, ...``), enforce monotonicity with a running max, and clip
    to 1.  Gating on the adjusted p keeps a 20-comparison table from
    flagging one of them at raw p = 0.03 by luck alone.

    ``None`` and NaN entries (n < 2 pairs) are passed through unchanged
    in their original positions and do not count toward the family size
    m."""
    valid: dict[int, float] = {}
    for i, p in enumerate(pvalues):
        if p is None:
            continue
        v = float(p)
        if v == v:  # drop NaN
            valid[i] = v
    m = len(valid)
    out: list[float | None] = list(pvalues)
    if m == 0:
        return out
    running = 0.0
    for k, i in enumerate(sorted(valid, key=valid.__getitem__)):
        running = max(running, (m - k) * valid[i])
        out[i] = min(1.0, running)
    return out


def mean_ci(
    values: Sequence[float], *, confidence: float = 0.95
) -> tuple[float, float]:
    """(mean, half-width) of the t-based confidence interval.

    n == 0 returns ``(nan, nan)``; n == 1 returns ``(x, nan)`` (a single
    run has no spread to bound); zero variance returns half-width 0."""
    x = np.asarray(list(values), np.float64)
    n = len(x)
    if n == 0:
        return float("nan"), float("nan")
    mean = float(x.mean())
    if n < 2:
        return mean, float("nan")
    sd = float(x.std(ddof=1))
    if sd == 0.0:
        return mean, 0.0
    half = t_crit(1.0 - confidence, n - 1) * sd / math.sqrt(n)
    return mean, float(half)


__all__ = [
    "bootstrap_ci",
    "cohens_d",
    "holm_bonferroni",
    "mean_ci",
    "paired_permutation_test",
    "paired_ttest",
    "t_crit",
    "t_sf",
]
