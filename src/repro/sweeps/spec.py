"""Frozen sweep descriptions and their deterministic grid expansion.

A :class:`SweepSpec` is scenario × seed-list × parameter overrides: each
:class:`SweepVariant` names a registered
:class:`~repro.experiments.spec.ScenarioSpec` plus dotted-path overrides
(``"sys.rounds"``, ``"dqn.batch_size"``, ``"n_patients"``), and
:meth:`SweepSpec.expand` derives one fully resolved ``ScenarioSpec`` per
(variant, seed) cell via ``replace``/``with_seed``/``fast``.

Every cell carries a content-addressed key — a stable hash of the fully
derived spec plus the seed — so the on-disk
:class:`~repro.sweeps.store.ReportStore` can skip completed cells across
interrupted runs and across processes.  The hash walks the dataclass
tree into canonical JSON (floats via ``repr``, mappings sorted), so it
does not depend on ``PYTHONHASHSEED`` or field declaration accidents.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, replace
from typing import Any

from repro.experiments.registry import get_scenario
from repro.experiments.spec import ScenarioSpec

#: metrics aggregated per variant (all are costs: lower is better)
DEFAULT_METRICS = (
    "mean_dist_err",
    "forgetting",
    "sim_makespan",
    "comm_time",
    "total_bytes",
)


def _canon(x: Any) -> Any:
    """Canonical JSON-able form of a (nested) dataclass value."""
    if dataclasses.is_dataclass(x) and not isinstance(x, type):
        d = {f.name: _canon(getattr(x, f.name)) for f in dataclasses.fields(x)}
        d["__type__"] = type(x).__name__
        return d
    if isinstance(x, (list, tuple)):
        return [_canon(v) for v in x]
    if isinstance(x, dict):
        return {str(k): _canon(v) for k, v in sorted(x.items())}
    if isinstance(x, float):
        return repr(x)  # stable for inf/nan and round-trippable precision
    return x


def spec_hash(spec: ScenarioSpec) -> str:
    """Content hash of a fully derived scenario spec."""
    payload = json.dumps(_canon(spec), sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(payload.encode()).hexdigest()


def apply_overrides(
    spec: ScenarioSpec, overrides: tuple[tuple[str, Any], ...]
) -> ScenarioSpec:
    """Apply dotted-path field overrides to a frozen spec.

    ``("sys.rounds", 2)`` replaces a field of the nested ``ADFLLConfig``;
    ``("n_patients", 8)`` a top-level spec field.  Unknown paths raise —
    a sweep must not silently no-op a typo."""
    for path, value in overrides:
        head, _, rest = path.partition(".")
        if not hasattr(spec, head):
            raise ValueError(f"override path {path!r}: no field {head!r}")
        if rest:
            inner = getattr(spec, head)
            if not hasattr(inner, rest):
                raise ValueError(f"override path {path!r}: no field {rest!r}")
            value = replace(inner, **{rest: value})
        if isinstance(value, list):
            value = tuple(value)
        spec = replace(spec, **{head: value})
    return spec


@dataclass(frozen=True)
class SweepVariant:
    """One row of the sweep grid: a scenario plus overrides."""

    label: str
    scenario: str  # registered ScenarioSpec name
    overrides: tuple[tuple[str, Any], ...] = ()

    def derive(self, seed: int, *, fast: bool = False) -> ScenarioSpec:
        """The fully resolved ScenarioSpec for one cell."""
        spec = apply_overrides(get_scenario(self.scenario), self.overrides)
        spec = spec.with_seed(seed)
        return spec.fast() if fast else spec


@dataclass(frozen=True)
class SweepCell:
    """One executable grid cell: (variant, seed) with its derived spec."""

    sweep: str
    label: str
    scenario: str
    seed: int
    spec: ScenarioSpec
    key: str  # "<label>:<seed>:<spec_hash>" — the ReportStore key

    @staticmethod
    def make(sweep: str, variant: SweepVariant, seed: int, *, fast: bool):
        spec = variant.derive(seed, fast=fast)
        key = f"{variant.label}:{seed}:{spec_hash(spec)}"
        return SweepCell(sweep, variant.label, variant.scenario, seed, spec, key)


@dataclass(frozen=True)
class SweepSpec:
    """One named multi-seed sweep grid."""

    name: str
    description: str = ""
    variants: tuple[SweepVariant, ...] = ()
    seeds: tuple[int, ...] = (0, 1, 2, 3, 4)
    # paired significance anchors on this variant label (None = no pairs)
    baseline: str | None = None
    metrics: tuple[str, ...] = DEFAULT_METRICS
    # wall-clock budget per cell in seconds (None = unlimited); the
    # executor marks over-budget cells failed, which fails the sweep
    cell_budget_s: float | None = None

    def __post_init__(self):
        if not self.variants:
            raise ValueError(f"sweep {self.name!r} has no variants")
        labels = [v.label for v in self.variants]
        if len(set(labels)) != len(labels):
            raise ValueError(f"sweep {self.name!r} has duplicate variant labels")
        if not self.seeds or len(set(self.seeds)) != len(self.seeds):
            raise ValueError(f"sweep {self.name!r} needs a non-empty unique seed list")
        if self.baseline is not None and self.baseline not in labels:
            raise ValueError(
                f"sweep {self.name!r}: baseline {self.baseline!r} is not a variant"
            )

    def with_seeds(self, seeds: tuple[int, ...]) -> "SweepSpec":
        return dataclasses.replace(self, seeds=tuple(seeds))

    def expand(self, *, fast: bool = False) -> tuple[SweepCell, ...]:
        """The deterministic grid: variants outer, seeds inner.

        Expansion is pure derivation from frozen specs — two expansions
        (in this process or any other) yield bit-identical keys."""
        return tuple(
            SweepCell.make(self.name, v, s, fast=fast)
            for v in self.variants
            for s in self.seeds
        )

    def grid_index(self, *, fast: bool = False) -> dict[str, SweepCell]:
        return {c.key: c for c in self.expand(fast=fast)}


__all__ = [
    "DEFAULT_METRICS",
    "SweepCell",
    "SweepSpec",
    "SweepVariant",
    "apply_overrides",
    "spec_hash",
]
