"""Named sweep registry: the paper's statistical claims as sweeps.

* ``paper_table1_sweep`` — ADFLL vs. the Table 1 agents (X all-knowing,
  Y partial, M sequential lifelong) across 5 seeds, paired significance
  against ADFLL: the reproduction of the paper's headline p = 0.01
  claim (7.81 vs. 15.17 mean distance error).
* ``paper_table2_hub_failure`` — the Table 2 robustness comparison:
  no-failure control vs. single-hub death (re-homing) vs. total hub
  death under pure-hub (sharing lost) vs. hybrid gossip failover.
* ``ci_smoke`` — a 2-seed, override-shrunk grid under per-cell
  wall-time budgets; CI's sweep-smoke step runs it ``--fast``.

Like scenarios, adding a sweep means registering a frozen spec.
"""

from __future__ import annotations

from repro.sweeps.spec import SweepSpec, SweepVariant

_REGISTRY: dict[str, SweepSpec] = {}


def register_sweep(sweep: SweepSpec) -> SweepSpec:
    """Add a sweep (rejects silent overwrites)."""
    if sweep.name in _REGISTRY:
        raise ValueError(f"sweep already registered: {sweep.name!r}")
    _REGISTRY[sweep.name] = sweep
    return sweep


def get_sweep(name: str) -> SweepSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown sweep {name!r}; registered: {known}") from None


def list_sweeps() -> list[SweepSpec]:
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


# ---------------------------------------------------------------------------
# built-in sweeps
# ---------------------------------------------------------------------------

register_sweep(
    SweepSpec(
        name="paper_table1_sweep",
        description="Table 1 significance: ADFLL vs Agent X (all-knowing) / "
        "Y (partial) / M (sequential LL) across 5 seeds, paired p-values "
        "against ADFLL (the paper's p=0.01 headline claim)",
        variants=(
            SweepVariant("adfll", "paper_fig2"),
            SweepVariant("agent_x_all_knowing", "baseline_all_knowing"),
            SweepVariant("agent_y_partial", "baseline_partial"),
            SweepVariant("agent_m_sequential", "baseline_sequential"),
        ),
        seeds=(0, 1, 2, 3, 4),
        baseline="adfll",
        cell_budget_s=1800.0,
    )
)

register_sweep(
    SweepSpec(
        name="paper_table2_hub_failure",
        description="Table 2 robustness: no-failure control vs hub death "
        "mid-training (re-homed), total hub death (pure hub, sharing "
        "lost) and hybrid gossip failover",
        variants=(
            SweepVariant("control", "paper_fig2"),
            SweepVariant("hub_failure", "paper_table2_hub_failure"),
            SweepVariant("total_failure", "paper_table2_total_failure"),
            SweepVariant("hybrid_failover", "paper_table2_hybrid_failover"),
        ),
        seeds=(0, 1, 2, 3, 4),
        baseline="control",
        cell_budget_s=1800.0,
    )
)

# CI-sized smoke: override-shrunk scenarios, tight wall-time budgets.
_SMOKE_OVERRIDES = (
    ("n_tasks", 2),
    ("eval_patients", 2),
    ("eval_episodes", 2),
    ("sys.rounds", 2),  # >= 2 so shared records actually flow
)

register_sweep(
    SweepSpec(
        name="ci_smoke",
        description="2-seed smoke grid (hub ERB plane vs gossip) with "
        "per-cell wall-time budgets — the CI sweep-smoke step",
        variants=(
            SweepVariant("erb_hub", "plane_erb_only", _SMOKE_OVERRIDES),
            SweepVariant("gossip", "topo_gossip", _SMOKE_OVERRIDES),
        ),
        seeds=(0, 1),
        baseline="erb_hub",
        cell_budget_s=300.0,
    )
)


__all__ = ["get_sweep", "list_sweeps", "register_sweep"]
