"""Parallel sweep execution with per-run isolation and resumability.

Cells run in a ``spawn``-context process pool (one fresh interpreter
per worker: no JAX state, RNG, or registry mutation leaks between
cells), results stream back to the parent, and every finished cell is
appended to the :class:`~repro.sweeps.store.ReportStore` as it lands —
an interrupted sweep resumes from the store and re-executes only the
missing cells.  ``workers=1`` (or ``0``) runs cells inline in this
process, which is what tests and tiny grids want.

Wall-time budgets are enforced per cell: an interval timer inside the
worker interrupts a cell that overruns its budget (Python-level code;
a hang inside a C extension is only caught on return to the
interpreter), and a finished cell whose wall clock exceeded the budget
is recorded the same way.  Either path yields a ``budget_exceeded``
row, which fails the sweep (CI uses this to keep scenario runtime
honest).  A worker that raises records an ``error`` row instead of
killing the sweep; both failure kinds are retried on the next run.
"""

from __future__ import annotations

import multiprocessing
import os
import signal
import threading
import time
import traceback
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from contextlib import contextmanager
from typing import Any

from repro.experiments import runner
from repro.sweeps.aggregate import forgetting_of, summarize
from repro.sweeps.spec import SweepCell, SweepSpec
from repro.sweeps.store import (
    STATUS_BUDGET,
    STATUS_ERROR,
    STATUS_OK,
    ReportStore,
    Row,
)


def default_workers(n_cells: int) -> int:
    return max(1, min(4, os.cpu_count() or 1, n_cells))


class _BudgetExceeded(Exception):
    """Raised inside a worker when the cell's interval timer fires."""


@contextmanager
def _budget_alarm(budget_s: float | None):
    """Interrupt the cell when its wall-time budget elapses.

    Uses ``SIGALRM``/``setitimer``, so it only arms on platforms that
    have it and in the process's main thread (both true for spawn-pool
    workers and the inline path); otherwise the post-hoc elapsed check
    still catches slow-but-finishing cells."""
    usable = (
        budget_s is not None
        and hasattr(signal, "setitimer")
        and threading.current_thread() is threading.main_thread()
    )
    if not usable:
        yield
        return

    def on_alarm(signum, frame):
        raise _BudgetExceeded

    prev = signal.signal(signal.SIGALRM, on_alarm)
    signal.setitimer(signal.ITIMER_REAL, budget_s)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, prev)


def _run_cell(payload: tuple[SweepCell, float | None]) -> Row:
    """Execute one cell (top-level so the spawn pool can pickle it)."""
    cell, budget_s = payload
    t0 = time.monotonic()
    row: Row = {
        "key": cell.key,
        "sweep": cell.sweep,
        "label": cell.label,
        "scenario": cell.scenario,
        "seed": cell.seed,
    }
    try:
        # the cell's spec is fully derived (seed + fast already applied)
        with _budget_alarm(budget_s):
            report = runner.run(cell.spec)
        summary = report.summary()
        summary["forgetting"] = forgetting_of(summary)
        row["summary"] = summary
        row["status"] = STATUS_OK
    except _BudgetExceeded:
        row["status"] = STATUS_BUDGET
    except Exception:
        row["status"] = STATUS_ERROR
        row["error"] = traceback.format_exc(limit=8)
    row["elapsed_s"] = time.monotonic() - t0
    if (
        row["status"] == STATUS_OK
        and budget_s is not None
        and row["elapsed_s"] > budget_s
    ):
        row["status"] = STATUS_BUDGET
    if row["status"] == STATUS_BUDGET:
        row["error"] = (
            f"cell took {row['elapsed_s']:.1f}s, budget is {budget_s:.1f}s"
        )
    return row


def run_sweep(
    sweep: SweepSpec,
    *,
    fast: bool = False,
    workers: int | None = None,
    store: ReportStore | None = None,
    budget_s: float | None = None,
    echo=None,
) -> dict[str, Any]:
    """Expand, execute (resuming from ``store``), aggregate.

    Returns the summary document from
    :func:`~repro.sweeps.aggregate.summarize`; cells that failed (error
    or budget) appear in its ``cells`` ledger with their status."""
    say = echo or (lambda *_: None)
    cells = sweep.expand(fast=fast)
    budget = sweep.cell_budget_s if budget_s is None else budget_s
    cached: dict[str, Row] = {}
    if store is not None:
        done = store.completed()
        cached = {c.key: dict(done[c.key], cached=True) for c in cells if c.key in done}
    pending = [c for c in cells if c.key not in cached]
    say(
        f"sweep {sweep.name}: {len(cells)} cells "
        f"({len(cached)} cached, {len(pending)} to run)"
    )

    fresh: dict[str, Row] = {}

    def record(row: Row) -> None:
        fresh[row["key"]] = row
        if store is not None:
            store.append(row)
        status = row["status"]
        mde = (row.get("summary") or {}).get("mean_dist_err")
        detail = (
            f"mean_dist_err={mde:.3f}"
            if isinstance(mde, float)
            else (row.get("error") or "").splitlines()[-1][:80]
        )
        say(
            f"  [{status}] {row['label']} seed={row['seed']} "
            f"({row['elapsed_s']:.1f}s) {detail}"
        )

    n_workers = default_workers(len(pending)) if workers is None else workers
    if pending and n_workers <= 1:
        for cell in pending:
            record(_run_cell((cell, budget)))
    elif pending:
        ctx = multiprocessing.get_context("spawn")
        with ProcessPoolExecutor(max_workers=n_workers, mp_context=ctx) as pool:
            futures = {pool.submit(_run_cell, (c, budget)): c for c in pending}
            remaining = set(futures)
            while remaining:
                finished, remaining = wait(remaining, return_when=FIRST_COMPLETED)
                for fut in finished:
                    cell = futures[fut]
                    exc = fut.exception()
                    if exc is None:
                        record(fut.result())
                    else:  # the worker process itself died
                        record(
                            {
                                "key": cell.key,
                                "sweep": cell.sweep,
                                "label": cell.label,
                                "scenario": cell.scenario,
                                "seed": cell.seed,
                                "status": STATUS_ERROR,
                                "error": f"worker failed: {exc!r}",
                                "elapsed_s": float("nan"),
                            }
                        )

    rows: list[Row] = [
        cached[c.key] if c.key in cached else fresh[c.key]
        for c in cells
        if c.key in cached or c.key in fresh
    ]
    return summarize(sweep, rows, fast=fast)


def failed_cells(summary: dict[str, Any]) -> list[dict[str, Any]]:
    """The summary's non-ok cells (empty list = clean sweep)."""
    return [c for c in summary.get("cells", []) if c.get("status") != STATUS_OK]


__all__ = ["default_workers", "failed_cells", "run_sweep"]
