"""3D-CNN deep Q-network (DQN, Mnih et al. 2013 adapted to 3D volumes)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.adfll_dqn import DQNConfig
from repro.models.layers import truncated_normal

F32 = jnp.float32


def _conv_init(key, cin, cout, k=3):
    scale = (cin * k**3) ** -0.5
    return truncated_normal(key, (k, k, k, cin, cout), scale, F32)


def dqn_init(key, cfg: DQNConfig) -> dict:
    ks = jax.random.split(key, 8)
    p = {}
    cin = 1
    for i, cout in enumerate(cfg.conv_features):
        p[f"conv{i}"] = {
            "w": _conv_init(ks[i], cin, cout),
            "b": jnp.zeros((cout,), F32),
        }
        cin = cout
    dims = list(cfg.box_size)
    for _ in cfg.conv_features:  # stride-2 SAME convs
        dims = [-(-d // 2) for d in dims]
    flat = dims[0] * dims[1] * dims[2] * cin
    d = flat + 16
    p["loc"] = {
        "w": truncated_normal(ks[5], (3, 16), 3**-0.5, F32),
        "b": jnp.zeros((16,), F32),
    }
    hs = list(cfg.hidden) + [cfg.n_actions]
    for i, h in enumerate(hs):
        ki = jax.random.fold_in(ks[6], i)
        p[f"fc{i}"] = {
            "w": truncated_normal(ki, (d, h), d**-0.5, F32),
            "b": jnp.zeros((h,), F32),
        }
        d = h
    return p


def dqn_apply(cfg: DQNConfig, p: dict, obs, loc):
    """obs [B, bx,by,bz], loc [B,3] normalized -> q [B, n_actions]."""
    x = obs[..., None]  # NDHWC
    for i in range(len(cfg.conv_features)):
        w, b = p[f"conv{i}"]["w"], p[f"conv{i}"]["b"]
        x = jax.lax.conv_general_dilated(
            x,
            w,
            window_strides=(2, 2, 2),
            padding="SAME",
            dimension_numbers=("NDHWC", "DHWIO", "NDHWC"),
        )
        x = jax.nn.relu(x + b)
    x = x.reshape(x.shape[0], -1)
    lh = jax.nn.relu(loc @ p["loc"]["w"] + p["loc"]["b"])
    x = jnp.concatenate([x, lh], -1)
    n_fc = sum(1 for k in p if k.startswith("fc"))
    for i in range(n_fc):
        x = x @ p[f"fc{i}"]["w"] + p[f"fc{i}"]["b"]
        if i < n_fc - 1:
            x = jax.nn.relu(x)
    return x
