"""Synthetic BraTS-like volumes (24 task-environments).

The real BraTS'17 data cannot ship in this container, so we generate
structured 3D phantoms that preserve the experimental *structure*:
4 modalities x 3 orientations x 2 pathologies, a consistent per-patient
anatomy, and a well-defined "top-left ventricle" landmark whose location
the generator knows exactly. Orderings between learning systems — not the
paper's absolute millimetre errors — are the reproduction target
(DESIGN.md §6).

Canonical frame is axial [z, y, x]; orientations permute axes; modalities
remap intensities; pathology controls lesion size/contrast.
"""

from __future__ import annotations

import zlib
from functools import lru_cache

import numpy as np

from repro.core.erb import TaskTag

MODALITIES = ("t1", "t1ce", "t2", "flair")
ORIENTATIONS = ("axial", "coronal", "sagittal")
PATHOLOGIES = ("HGG", "LGG")


def all_tasks() -> tuple[TaskTag, ...]:
    return tuple(
        TaskTag(m, o, p) for o in ORIENTATIONS for p in PATHOLOGIES for m in MODALITIES
    )


def paper_eight_tasks() -> tuple[TaskTag, ...]:
    """The 8 task-environment pairs sampled for the deployment experiment
    (paper §2.2)."""
    names = [
        ("t1ce", "axial", "HGG"),
        ("t1ce", "sagittal", "HGG"),
        ("t1ce", "coronal", "HGG"),
        ("flair", "axial", "HGG"),
        ("flair", "sagittal", "LGG"),
        ("flair", "coronal", "LGG"),
        ("t2", "coronal", "LGG"),
        ("t1", "sagittal", "LGG"),
    ]
    return tuple(TaskTag(m, o, p) for m, o, p in names)


def _grid(n: int):
    ax = np.linspace(-1.0, 1.0, n, dtype=np.float32)
    return np.meshgrid(ax, ax, ax, indexing="ij")


@lru_cache(maxsize=512)
def _canonical(patient: int, pathology: str, n: int):
    """Patient anatomy in the canonical axial frame.

    Returns (tissue maps dict, landmark zyx float array)."""
    rng = np.random.default_rng(10_000 + patient)
    z, y, x = _grid(n)

    def jit(s):
        return rng.uniform(-s, s)

    # head: ellipsoid
    head = ((z / 0.95) ** 2 + (y / 0.85) ** 2 + (x / 0.8) ** 2) < 1.0
    # lateral ventricles: two curved slabs around the midline
    vz, vy, vx = jit(0.08), jit(0.08), 0.22 + jit(0.05)
    vent_l = (
        ((z - vz) / 0.32) ** 2 + ((y - vy) / 0.18) ** 2 + ((x + vx) / 0.14) ** 2
    ) < 1.0
    vent_r = (
        ((z - vz) / 0.32) ** 2 + ((y - vy) / 0.18) ** 2 + ((x - vx) / 0.14) ** 2
    ) < 1.0
    vent = (vent_l | vent_r) & head
    # landmark: anterior-superior tip of the LEFT ventricle ("top left")
    lm_cont = np.array([vz - 0.30, vy - 0.16, -vx], np.float32)
    landmark = (lm_cont + 1.0) / 2.0 * (n - 1)

    # lesion: one blob in a random hemisphere location (not on ventricle)
    big = pathology == "HGG"
    r = (0.30 if big else 0.16) + jit(0.03)
    cz, cy = rng.uniform(-0.4, 0.4, 2)
    cx = rng.choice([-1, 1]) * rng.uniform(0.3, 0.55)
    lesion = (((z - cz) / r) ** 2 + ((y - cy) / r) ** 2 + ((x - cx) / r) ** 2) < 1.0
    lesion &= head & ~vent
    edema = (
        ((z - cz) / (r * 1.6)) ** 2
        + ((y - cy) / (r * 1.6)) ** 2
        + ((x - cx) / (r * 1.6)) ** 2
    ) < 1.0
    edema &= head & ~vent & ~lesion

    tissue = {
        "head": head.astype(np.float32),
        "vent": vent.astype(np.float32),
        "lesion": lesion.astype(np.float32),
        "edema": edema.astype(np.float32),
    }
    return tissue, landmark


_MODALITY_MIX = {
    #          head   vent  lesion edema
    "t1": (0.60, 0.15, 0.40, 0.55),
    "t1ce": (0.60, 0.15, 0.95, 0.55),
    "t2": (0.45, 0.95, 0.65, 0.75),
    "flair": (0.50, 0.10, 0.80, 0.95),
}

_ORIENT_PERM = {"axial": (0, 1, 2), "coronal": (1, 0, 2), "sagittal": (2, 1, 0)}


def make_volume(
    task: TaskTag, patient: int, n: int = 24, noise: float = 0.03
) -> tuple[np.ndarray, np.ndarray]:
    """-> (volume f32 [n,n,n] in [0,1], landmark float [3] in volume idx)."""
    tissue, landmark = _canonical(patient, task.pathology, n)
    wh, wv, wl, we = _MODALITY_MIX[task.modality]
    vol = (
        wh
        * tissue["head"]
        * (1 - tissue["vent"])
        * (1 - tissue["lesion"])
        * (1 - tissue["edema"])
        + wv * tissue["vent"]
        + wl * tissue["lesion"]
        + we * tissue["edema"]
    )
    # process-stable seed (Python's str hash is salted per interpreter,
    # which made every benchmark run draw different volume noise)
    rng = np.random.default_rng(zlib.crc32(f"{task.name}:{patient}".encode()))
    vol = vol + noise * rng.standard_normal(vol.shape).astype(np.float32)
    vol = np.clip(vol, 0.0, 1.0).astype(np.float32)
    perm = _ORIENT_PERM[task.orientation]
    vol = np.transpose(vol, perm)
    lm = landmark[list(perm)].copy()
    return vol, lm


def patient_split(n_patients: int = 100, train_frac: float = 0.8, seed: int = 7):
    """80:20 split as in the paper (48+32 train / 12+8 test by pathology)."""
    rng = np.random.default_rng(seed)
    ids = rng.permutation(n_patients)
    k = int(train_frac * n_patients)
    return ids[:k].tolist(), ids[k:].tolist()
