"""DQN agent: epsilon-greedy exploration, target network, fused TD loss,
and the ADFLL round API (collect -> train on mixed replay -> share ERB).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.adfll_dqn import DQNConfig
from repro.core.erb import ERB, TaskTag, erb_add, erb_init, erb_share_slice
from repro.core.plane import WeightSnapshot, mix_params, new_snap_id
from repro.core.replay import SelectiveReplaySampler
from repro.kernels.fused_td.ops import td_loss
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.rl.dqn import dqn_apply, dqn_init
from repro.rl.env import LandmarkEnv


def make_dqn_steps(cfg: DQNConfig, *, use_pallas: bool = False):
    """Returns (act_fn, train_fn) — both jitted."""

    @jax.jit
    def q_values(params, obs, loc):
        return dqn_apply(cfg, params, obs, loc)

    opt_cfg = AdamWConfig(
        lr=cfg.lr, weight_decay=0.0, clip_norm=10.0, warmup_steps=0, total_steps=10**9
    )

    def loss_fn(params, target_params, batch):
        q = dqn_apply(cfg, params, batch["obs"], batch["loc"])
        q_sel = jnp.take_along_axis(q, batch["action"][:, None], 1)
        q_next = dqn_apply(cfg, target_params, batch["next_obs"], batch["next_loc"])
        q_next = jax.lax.stop_gradient(q_next)
        return td_loss(
            q_sel,
            q_next,
            batch["reward"][:, None],
            batch["done"][:, None],
            cfg.gamma,
            use_pallas,
        )

    @jax.jit
    def train_fn(params, target_params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, target_params, batch)
        params, opt_state, _ = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, loss

    return q_values, train_fn, opt_cfg


@dataclass
class DQNAgent:
    """One ADFLL participant (also used standalone for Agents X/Y/M)."""

    agent_id: int
    cfg: DQNConfig
    seed: int = 0
    speed: float = 1.0  # relative hardware speed (sim time)
    use_pallas: bool = False

    def __post_init__(self):
        key = jax.random.PRNGKey(self.seed)
        self.params = dqn_init(key, self.cfg)
        self.target_params = self.params
        self.q_values, self.train_fn, opt_cfg = make_dqn_steps(
            self.cfg, use_pallas=self.use_pallas
        )
        self.opt_state = adamw_init(opt_cfg, self.params)
        self.rng = np.random.default_rng(abs(self.seed + 1000 * self.agent_id))
        self.step_count = 0
        self.personal_erbs: List[ERB] = []
        self.seen_erb_ids: set = set()
        self.seen_snap_ids: set = set()
        self.rounds_done = 0
        self.sampler = SelectiveReplaySampler(use_pallas=False)

    # -- acting ----------------------------------------------------------
    def epsilon(self) -> float:
        c = self.cfg
        frac = min(1.0, self.step_count / max(1, c.eps_decay_steps))
        return c.eps_start + frac * (c.eps_end - c.eps_start)

    def act(self, env: LandmarkEnv, locs: np.ndarray, eps: float) -> np.ndarray:
        q = np.asarray(
            self.q_values(self.params, env.observe(locs), env.norm_loc(locs))
        )
        greedy = q.argmax(-1)
        rand = self.rng.integers(0, self.cfg.n_actions, size=len(locs))
        coin = self.rng.random(len(locs)) < eps
        return np.where(coin, rand, greedy).astype(np.int32)

    # -- experience collection ---------------------------------------------
    def collect(self, env: LandmarkEnv, erb: ERB, n_episodes: int) -> ERB:
        c = self.cfg
        locs = env.start_locs(n_episodes, self.rng)
        alive = np.ones(n_episodes, bool)
        for _ in range(c.max_episode_steps):
            if not alive.any():
                break
            eps = self.epsilon()
            acts = self.act(env, locs, eps)
            new, r, done = env.step(locs, acts)
            idx = np.where(alive)[0]
            batch = {
                "obs": env.observe(locs[idx]),
                "loc": env.norm_loc(locs[idx]),
                "action": acts[idx],
                "reward": r[idx],
                "next_obs": env.observe(new[idx]),
                "next_loc": env.norm_loc(new[idx]),
                "done": done[idx].astype(np.float32),
            }
            erb_add(erb, batch)
            locs = new
            alive &= ~done
        return erb

    # -- learning ------------------------------------------------------------
    def train_steps(
        self, n_steps: int, current: Optional[ERB], incoming: Sequence[ERB] = ()
    ) -> float:
        last = 0.0
        for _ in range(n_steps):
            batch = self.sampler.sample(
                self.rng,
                self.cfg.batch_size,
                current,
                personal=self.personal_erbs,
                incoming=incoming,
            )
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt_state, loss = self.train_fn(
                self.params, self.target_params, self.opt_state, batch
            )
            self.step_count += 1
            if self.step_count % self.cfg.target_update == 0:
                self.target_params = self.params
            last = float(loss)
        return last

    # -- weight plane (beyond-paper: FedAsync-style mixing) -------------------
    def snapshot_params(self, sim_time: float = 0.0) -> WeightSnapshot:
        """Package current params for the weight plane (marked seen so the
        agent never pulls its own snapshot back)."""
        snap = WeightSnapshot(
            new_snap_id(), self.agent_id, self.rounds_done, sim_time, self.params
        )
        self.seen_snap_ids.add(snap.snap_id)
        return snap

    def mix_params(
        self, incoming: Sequence[WeightSnapshot], alphas: Sequence[float]
    ) -> int:
        """Fold peer snapshots into our params with staleness-discounted
        rates: ``p <- (1-a_k) p + a_k w_k`` (stalest first). Compressed
        snapshots (``CompressedWeightSnapshot``) are transparent here:
        ``mix_params`` dequantizes them on apply. The target network
        keeps its own cadence (next periodic sync picks up the mixed
        params). Returns the number of snapshots consumed."""
        snaps = [s for s in incoming if s.agent_id != self.agent_id]
        for s in incoming:
            self.seen_snap_ids.add(s.snap_id)
        if not snaps:
            return 0
        alphas = [
            a
            for s, a in zip(incoming, alphas, strict=True)
            if s.agent_id != self.agent_id
        ]
        self.params = mix_params(self.params, snaps, alphas)
        return len(snaps)

    # -- ADFLL round (paper A.3) ----------------------------------------------
    def train_round(
        self,
        env: LandmarkEnv,
        task: TaskTag,
        incoming: Sequence[ERB],
        *,
        erb_capacity: int,
        share_size: int,
        train_steps: int,
        collect_episodes: int = 24,
        share_strategy: str = "uniform",
    ) -> Tuple[ERB, float]:
        """Collect on the round's task, then train on
        current + personal + incoming replay. Returns (shared ERB, loss)."""
        current = erb_init(
            erb_capacity,
            self.cfg.box_size,
            task=task,
            source_agent=self.agent_id,
            round_idx=self.rounds_done,
        )
        self.collect(env, current, collect_episodes)
        for e in incoming:
            self.seen_erb_ids.add(e.meta.erb_id)
        loss = self.train_steps(train_steps, current, incoming)
        self.personal_erbs.append(current)
        self.rounds_done += 1
        shared = erb_share_slice(current, share_size, self.rng, strategy=share_strategy)
        shared.meta = shared.meta  # provenance kept
        self.seen_erb_ids.add(shared.meta.erb_id)
        return shared, loss

    # -- evaluation ------------------------------------------------------------
    def evaluate(
        self, env: LandmarkEnv, n_episodes: int = 8, max_steps: Optional[int] = None
    ) -> float:
        """Greedy rollout from deterministic starts; mean final distance."""
        rng = np.random.default_rng(1234)
        locs = env.start_locs(n_episodes, rng)
        for _ in range(max_steps or self.cfg.max_episode_steps):
            q = np.asarray(
                self.q_values(self.params, env.observe(locs), env.norm_loc(locs))
            )
            locs, _, done = env.step(locs, q.argmax(-1).astype(np.int32))
            if done.all():
                break
        return float(env.dist(locs).mean())
