"""DQN agent: epsilon-greedy exploration, target network, fused TD loss,
and the ADFLL round API (collect -> train on mixed replay -> share ERB).

Since the fleet-engine refactor the agent is a thin *view* over a
:class:`~repro.rl.fleet.FleetEngine` slot: its params / target params /
optimizer state live in the engine's stacked :class:`~repro.rl.fleet.FleetState`,
and training rounds are scan-fused jobs (one dispatch per flush instead
of one per step). The public API — ``act`` / ``collect`` /
``train_steps`` / ``train_round`` / ``mix_params`` / ``evaluate`` — is
unchanged, so Agents X/Y/M and existing tests keep working. The legacy
per-step dispatch path survives as ``backend="stepwise"`` (the
``fleet_throughput`` benchmark baseline; numerically within float-fusion
ULPs of the fused program).
"""

from __future__ import annotations

from collections import Counter
from collections.abc import Sequence
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.adfll_dqn import DQNConfig
from repro.core.erb import ERB, TaskTag, erb_add, erb_init, erb_share_slice
from repro.core.plane import WeightSnapshot, mix_params, new_snap_id
from repro.core.replay import SelectiveReplaySampler
from repro.optim.adamw import adamw_init, adamw_update
from repro.rl.dqn import dqn_apply, dqn_init
from repro.rl.env import LandmarkEnv
from repro.rl.fleet import (
    FleetEngine,
    TrainFuture,
    collect_fleet,
    make_dqn_loss_fn,
    make_dqn_opt_cfg,
)

_DQN_STEPS_CACHE: dict[tuple[DQNConfig, bool], tuple] = {}
_DQN_TRACES: Counter = Counter()


def dqn_step_traces(cfg: DQNConfig, *, use_pallas: bool = False) -> int:
    """How many times the (cached) per-step train function of this config
    has been retraced — the no-recompilation tests assert this stays at 1
    across any number of same-config agents."""
    return _DQN_TRACES[(cfg, bool(use_pallas), "train")]


def make_dqn_steps(cfg: DQNConfig, *, use_pallas: bool = False):
    """Returns (q_values, train_fn, opt_cfg) — both jitted, cached per
    (config, use_pallas): N same-config agents share one compilation."""
    cache_key = (cfg, bool(use_pallas))
    hit = _DQN_STEPS_CACHE.get(cache_key)
    if hit is not None:
        return hit

    @jax.jit
    def q_values(params, obs, loc):
        _DQN_TRACES[(cfg, bool(use_pallas), "q")] += 1
        return dqn_apply(cfg, params, obs, loc)

    opt_cfg = make_dqn_opt_cfg(cfg)
    loss_fn = make_dqn_loss_fn(cfg, use_pallas)

    @jax.jit
    def train_fn(params, target_params, opt_state, batch):
        _DQN_TRACES[(cfg, bool(use_pallas), "train")] += 1
        loss, grads = jax.value_and_grad(loss_fn)(params, target_params, batch)
        params, opt_state, _ = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, loss

    steps = (q_values, train_fn, opt_cfg)
    _DQN_STEPS_CACHE[cache_key] = steps
    return steps


@dataclass
class DQNAgent:
    """One ADFLL participant (also used standalone for Agents X/Y/M).

    ``backend="fleet"`` (default): state lives in a fleet slot — either
    a shared ``engine`` (the ADFLL system passes one so the whole fleet
    trains in batched flushes) or a private single-slot engine.
    ``backend="stepwise"``: the legacy one-dispatch-per-step path.
    """

    agent_id: int
    cfg: DQNConfig
    seed: int = 0
    speed: float = 1.0  # relative hardware speed (sim time)
    use_pallas: bool = False
    backend: str = "fleet"  # "fleet" | "stepwise"
    engine: FleetEngine | None = None

    def __post_init__(self):
        if self.backend not in ("fleet", "stepwise"):
            raise ValueError(f"unknown backend: {self.backend!r}")
        self.q_values, self._train_fn, opt_cfg = make_dqn_steps(
            self.cfg, use_pallas=self.use_pallas
        )
        if self.backend == "fleet":
            if self.engine is None:
                self.engine = FleetEngine(self.cfg, use_pallas=self.use_pallas)
            elif self.engine.cfg != self.cfg:
                raise ValueError("shared FleetEngine built for a different config")
            self.slot = self.engine.add_slot(self.seed)
        else:
            self.engine = None
            key = jax.random.PRNGKey(self.seed)
            self._params = dqn_init(key, self.cfg)
            self._target_params = self._params
            self._opt_state = adamw_init(opt_cfg, self._params)
        self.rng = np.random.default_rng(abs(self.seed + 1000 * self.agent_id))
        self.step_count = 0
        self.personal_erbs: list[ERB] = []
        self.seen_erb_ids: set = set()
        self.seen_snap_ids: set = set()
        self.rounds_done = 0
        self.sampler = SelectiveReplaySampler(use_pallas=self.use_pallas)

    # -- state views (fleet slot or local buffers) ---------------------------
    @property
    def params(self):
        if self.engine is not None:
            return self.engine.get_params(self.slot)
        return self._params

    @params.setter
    def params(self, value):
        if self.engine is not None:
            self.engine.set_params(self.slot, value)
        else:
            self._params = value

    @property
    def target_params(self):
        if self.engine is not None:
            return self.engine.get_target(self.slot)
        return self._target_params

    @target_params.setter
    def target_params(self, value):
        if self.engine is not None:
            self.engine.set_target(self.slot, value)
        else:
            self._target_params = value

    @property
    def opt_state(self):
        if self.engine is not None:
            return self.engine.get_opt(self.slot)
        return self._opt_state

    @opt_state.setter
    def opt_state(self, value):
        if self.engine is not None:
            self.engine.set_opt(self.slot, value)
        else:
            self._opt_state = value

    # -- acting ----------------------------------------------------------
    def epsilon(self) -> float:
        c = self.cfg
        frac = min(1.0, self.step_count / max(1, c.eps_decay_steps))
        return c.eps_start + frac * (c.eps_end - c.eps_start)

    def act(self, env: LandmarkEnv, locs: np.ndarray, eps: float) -> np.ndarray:
        q = np.asarray(
            self.q_values(self.params, env.observe(locs), env.norm_loc(locs))
        )
        greedy = q.argmax(-1)
        rand = self.rng.integers(0, self.cfg.n_actions, size=len(locs))
        coin = self.rng.random(len(locs)) < eps
        return np.where(coin, rand, greedy).astype(np.int32)

    # -- experience collection ---------------------------------------------
    def collect(self, env: LandmarkEnv, erb: ERB, n_episodes: int) -> ERB:
        if self.engine is not None:
            # route through the stacked collection program — bit-identical
            # to the loop below (same q-values, same rng stream order),
            # and cohort drivers batch many agents into the same dispatch
            collect_fleet([self], [env], [erb], n_episodes)
            return erb
        c = self.cfg
        locs = env.start_locs(n_episodes, self.rng)
        alive = np.ones(n_episodes, bool)
        for _ in range(c.max_episode_steps):
            if not alive.any():
                break
            eps = self.epsilon()
            acts = self.act(env, locs, eps)
            new, r, done = env.step(locs, acts)
            idx = np.where(alive)[0]
            batch = {
                "obs": env.observe(locs[idx]),
                "loc": env.norm_loc(locs[idx]),
                "action": acts[idx],
                "reward": r[idx],
                "next_obs": env.observe(new[idx]),
                "next_loc": env.norm_loc(new[idx]),
                "done": done[idx].astype(np.float32),
            }
            erb_add(erb, batch)
            locs = new
            alive &= ~done
        return erb

    # -- learning ------------------------------------------------------------
    def _submit_steps(
        self, n_steps: int, current: ERB | None, incoming: Sequence[ERB]
    ) -> TrainFuture:
        """Plan n minibatches (host index selection, same rng stream as
        the stepwise path) and queue them as one scan-fused fleet job."""
        plans = [
            self.sampler.plan(
                self.rng,
                self.cfg.batch_size,
                current,
                personal=self.personal_erbs,
                incoming=incoming,
            )
            for _ in range(n_steps)
        ]
        self.step_count += n_steps
        return self.engine.submit(self.slot, plans)

    def train_steps(
        self, n_steps: int, current: ERB | None, incoming: Sequence[ERB] = ()
    ) -> float:
        if self.engine is not None:
            future = self._submit_steps(n_steps, current, incoming)
            self.engine.flush()
            return future.loss if future.loss is not None else 0.0
        last = 0.0
        for _ in range(n_steps):
            batch = self.sampler.sample(
                self.rng,
                self.cfg.batch_size,
                current,
                personal=self.personal_erbs,
                incoming=incoming,
            )
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self._params, self._opt_state, loss = self._train_fn(
                self._params, self._target_params, self._opt_state, batch
            )
            self.step_count += 1
            if self.step_count % self.cfg.target_update == 0:
                self._target_params = self._params
            last = float(loss)
        return last

    # -- weight plane (beyond-paper: FedAsync-style mixing) -------------------
    def snapshot_params(self, sim_time: float = 0.0) -> WeightSnapshot:
        """Package current params for the weight plane (marked seen so the
        agent never pulls its own snapshot back)."""
        snap = WeightSnapshot(
            new_snap_id(), self.agent_id, self.rounds_done, sim_time, self.params
        )
        self.seen_snap_ids.add(snap.snap_id)
        return snap

    def mix_params(
        self, incoming: Sequence[WeightSnapshot], alphas: Sequence[float]
    ) -> int:
        """Fold peer snapshots into our params with staleness-discounted
        rates: ``p <- (1-a_k) p + a_k w_k`` (stalest first). Compressed
        snapshots (``CompressedWeightSnapshot``) are transparent here:
        ``mix_params`` dequantizes them on apply. The target network
        keeps its own cadence (next periodic sync picks up the mixed
        params). Returns the number of snapshots consumed."""
        snaps = [s for s in incoming if s.agent_id != self.agent_id]
        for s in incoming:
            self.seen_snap_ids.add(s.snap_id)
        if not snaps:
            return 0
        alphas = [
            a
            for s, a in zip(incoming, alphas, strict=True)
            if s.agent_id != self.agent_id
        ]
        self.params = mix_params(self.params, snaps, alphas)
        return len(snaps)

    # -- ADFLL round (paper A.3) ----------------------------------------------
    def new_round_erb(self, task: TaskTag, erb_capacity: int) -> ERB:
        """The empty current-round buffer (tagged with this agent's id and
        round index) — split out so cohort drivers can pre-collect."""
        return erb_init(
            erb_capacity,
            self.cfg.box_size,
            task=task,
            source_agent=self.agent_id,
            round_idx=self.rounds_done,
        )

    def begin_round(
        self,
        env: LandmarkEnv,
        task: TaskTag,
        incoming: Sequence[ERB],
        *,
        erb_capacity: int,
        share_size: int,
        train_steps: int,
        collect_episodes: int = 24,
        share_strategy: str = "uniform",
        current: ERB | None = None,
    ) -> tuple[ERB, TrainFuture]:
        """Collect on the round's task and *submit* the round's training
        (current + personal + incoming replay) to the fleet engine
        without forcing execution. Returns (shared ERB, loss future) —
        the shared slice never depends on the round's own updates, so the
        system can keep scheduling while jobs accumulate into one batched
        flush. On the stepwise backend the future resolves immediately.

        ``current`` accepts a pre-collected round ERB (see
        :func:`repro.rl.fleet.collect_fleet`): cohort drivers collect the
        whole round's experience in one stacked program, then hand each
        agent its buffer here — skipping the per-agent collect while
        keeping every subsequent rng draw (sample plans, share slice) in
        the per-agent order."""
        if current is None:
            current = self.new_round_erb(task, erb_capacity)
            self.collect(env, current, collect_episodes)
        for e in incoming:
            self.seen_erb_ids.add(e.meta.erb_id)
        if self.engine is not None:
            future = self._submit_steps(train_steps, current, incoming)
        else:
            future = TrainFuture()
            future.resolve(self.train_steps(train_steps, current, incoming))
        self.personal_erbs.append(current)
        self.rounds_done += 1
        shared = erb_share_slice(current, share_size, self.rng, strategy=share_strategy)
        self.seen_erb_ids.add(shared.meta.erb_id)
        return shared, future

    def train_round(
        self,
        env: LandmarkEnv,
        task: TaskTag,
        incoming: Sequence[ERB],
        *,
        erb_capacity: int,
        share_size: int,
        train_steps: int,
        collect_episodes: int = 24,
        share_strategy: str = "uniform",
    ) -> tuple[ERB, float]:
        """Collect on the round's task, then train on
        current + personal + incoming replay. Returns (shared ERB, loss)."""
        shared, future = self.begin_round(
            env,
            task,
            incoming,
            erb_capacity=erb_capacity,
            share_size=share_size,
            train_steps=train_steps,
            collect_episodes=collect_episodes,
            share_strategy=share_strategy,
        )
        if self.engine is not None:
            self.engine.flush()
        return shared, future.loss

    # -- evaluation ------------------------------------------------------------
    def evaluate(
        self, env: LandmarkEnv, n_episodes: int = 8, max_steps: int | None = None
    ) -> float:
        """Greedy rollout from deterministic starts; mean final distance."""
        rng = np.random.default_rng(1234)
        locs = env.start_locs(n_episodes, rng)
        for _ in range(max_steps or self.cfg.max_episode_steps):
            q = np.asarray(
                self.q_values(self.params, env.observe(locs), env.norm_loc(locs))
            )
            locs, _, done = env.step(locs, q.argmax(-1).astype(np.int32))
            if done.all():
                break
        return float(env.dist(locs).mean())
