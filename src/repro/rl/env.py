"""3D landmark-localization environment (paper Appendix A.1).

Agent = 3D bounding box; 6 actions (+/- x, y, z); reward = decrease in
Euclidean distance to the target landmark; episode terminates on
proximity or step budget. Vectorized over parallel episodes (numpy host
side; the Q-network forward is the jitted part).
"""

from __future__ import annotations

from collections.abc import Sequence
from dataclasses import dataclass, field

import numpy as np

from repro.configs.adfll_dqn import DQNConfig

# actions: 0:+x 1:-x 2:+y 3:-y 4:+z 5:-z  (acting on [z,y,x] index order)
_DELTA = np.array(
    [[0, 0, 1], [0, 0, -1], [0, 1, 0], [0, -1, 0], [1, 0, 0], [-1, 0, 0]], np.int32
)


def apply_actions(
    locs: np.ndarray, actions: np.ndarray, n, step_size: int
) -> np.ndarray:
    """Move ``locs`` [B,3] by ``actions`` [B] and clip to the volume.

    The landmark-free half of :meth:`LandmarkEnv.step` — the serving
    plane moves requests through volumes whose landmark it does not
    know, so the kinematics must not require one. ``n`` is the volume
    side: a scalar, or [B] per-row sides when the batch mixes volumes.
    """
    hi = np.asarray(n, np.int32) - 1
    if hi.ndim:
        hi = hi[:, None]
    return np.clip(locs + step_size * _DELTA[actions], 0, hi).astype(np.int32)


def observe_many(
    envs: Sequence["LandmarkEnv"], locs: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Per-request observation batch over *heterogeneous* environments.

    ``envs[i]`` supplies row ``i``'s crop and normalized location —
    unlike :meth:`LandmarkEnv.observe`, which batches many locations in
    *one* volume. Returns ``(obs [B, box], norm_loc [B, 3])``; this is
    the host half of a serving tick (each request owns its own volume).
    """
    obs = np.stack(
        [env.observe(loc[None])[0] for env, loc in zip(envs, locs, strict=True)]
    )
    norm = np.stack(
        [env.norm_loc(loc) for env, loc in zip(envs, locs, strict=True)]
    ).astype(np.float32)
    return obs, norm


@dataclass
class LandmarkEnv:
    volume: np.ndarray  # [n,n,n] f32
    landmark: np.ndarray  # [3] float (zyx)
    cfg: DQNConfig
    # pad-once cache: np.pad of the full volume on *every* observe call
    # dominated the host-side round cost before the batched gather below
    _padded: np.ndarray | None = field(default=None, repr=False, compare=False)

    @property
    def n(self) -> int:
        return self.volume.shape[0]

    def observe(self, locs: np.ndarray) -> np.ndarray:
        """locs [B,3] int -> crops [B, bx,by,bz] centered at locs
        (zero-padded at boundaries). One batched fancy-index gather from
        a cached zero-padded volume — no per-row Python loop."""
        bx, by, bz = self.cfg.box_size
        half = np.array([bx // 2, by // 2, bz // 2])
        pad = max(bx, by, bz)
        if self._padded is None:
            self._padded = np.pad(self.volume, pad)
        c = locs + pad - half  # [B,3] window starts
        iz = c[:, 0, None] + np.arange(bx)  # [B,bx]
        iy = c[:, 1, None] + np.arange(by)  # [B,by]
        ix = c[:, 2, None] + np.arange(bz)  # [B,bz]
        out = self._padded[
            iz[:, :, None, None], iy[:, None, :, None], ix[:, None, None, :]
        ]
        return np.ascontiguousarray(out, dtype=np.float32)

    def norm_loc(self, locs: np.ndarray) -> np.ndarray:
        return locs.astype(np.float32) / (self.n - 1)

    def dist(self, locs: np.ndarray) -> np.ndarray:
        return np.linalg.norm(locs.astype(np.float32) - self.landmark, axis=-1)

    def start_locs(self, batch: int, rng: np.random.Generator) -> np.ndarray:
        lo, hi = self.n // 4, 3 * self.n // 4
        return rng.integers(lo, hi, size=(batch, 3)).astype(np.int32)

    def step(
        self, locs: np.ndarray, actions: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """-> (new_locs, reward, done)."""
        new = apply_actions(locs, actions, self.n, self.cfg.step_size)
        r = self.dist(locs) - self.dist(new)
        done = self.dist(new) < 1.5
        return new, r.astype(np.float32), done
