"""3D landmark-localization environment (paper Appendix A.1).

Agent = 3D bounding box; 6 actions (+/- x, y, z); reward = decrease in
Euclidean distance to the target landmark; episode terminates on
proximity or step budget. Vectorized over parallel episodes (numpy host
side; the Q-network forward is the jitted part).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

from repro.configs.adfll_dqn import DQNConfig

# actions: 0:+x 1:-x 2:+y 3:-y 4:+z 5:-z  (acting on [z,y,x] index order)
_DELTA = np.array(
    [[0, 0, 1], [0, 0, -1], [0, 1, 0], [0, -1, 0], [1, 0, 0], [-1, 0, 0]], np.int32
)


@dataclass
class LandmarkEnv:
    volume: np.ndarray  # [n,n,n] f32
    landmark: np.ndarray  # [3] float (zyx)
    cfg: DQNConfig
    # pad-once cache: np.pad of the full volume on *every* observe call
    # dominated the host-side round cost before the batched gather below
    _padded: Optional[np.ndarray] = field(default=None, repr=False, compare=False)

    @property
    def n(self) -> int:
        return self.volume.shape[0]

    def observe(self, locs: np.ndarray) -> np.ndarray:
        """locs [B,3] int -> crops [B, bx,by,bz] centered at locs
        (zero-padded at boundaries). One batched fancy-index gather from
        a cached zero-padded volume — no per-row Python loop."""
        bx, by, bz = self.cfg.box_size
        half = np.array([bx // 2, by // 2, bz // 2])
        pad = max(bx, by, bz)
        if self._padded is None:
            self._padded = np.pad(self.volume, pad)
        c = locs + pad - half  # [B,3] window starts
        iz = c[:, 0, None] + np.arange(bx)  # [B,bx]
        iy = c[:, 1, None] + np.arange(by)  # [B,by]
        ix = c[:, 2, None] + np.arange(bz)  # [B,bz]
        out = self._padded[
            iz[:, :, None, None], iy[:, None, :, None], ix[:, None, None, :]
        ]
        return np.ascontiguousarray(out, dtype=np.float32)

    def norm_loc(self, locs: np.ndarray) -> np.ndarray:
        return locs.astype(np.float32) / (self.n - 1)

    def dist(self, locs: np.ndarray) -> np.ndarray:
        return np.linalg.norm(locs.astype(np.float32) - self.landmark, axis=-1)

    def start_locs(self, batch: int, rng: np.random.Generator) -> np.ndarray:
        lo, hi = self.n // 4, 3 * self.n // 4
        return rng.integers(lo, hi, size=(batch, 3)).astype(np.int32)

    def step(
        self, locs: np.ndarray, actions: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """-> (new_locs, reward, done)."""
        step = self.cfg.step_size
        new = np.clip(locs + step * _DELTA[actions], 0, self.n - 1)
        r = self.dist(locs) - self.dist(new)
        done = self.dist(new) < 1.5
        return new.astype(np.int32), r.astype(np.float32), done
