"""Vectorized fleet engine: stacked-agent pytrees, scan-fused training,
and device-resident replay.

The ADFLL simulator used to execute its fleet one agent at a time: a
fresh ``jax.jit`` per agent, one dispatch per training step, and a
blocking ``float(loss)`` host sync after every update — N agents x K
steps = N*K dispatches per round of rounds. This module turns that into
*one* compiled program stepping many agents at once:

* :class:`FleetState` — every agent's params / target params / optimizer
  state / PRNG key / step counter as one stacked pytree with a leading
  agent axis.
* :func:`make_fleet_steps` — a module-level, (config, mesh)-keyed cache
  of the compiled fleet program. The train chunk is ``lax.scan``-fused
  over the K inner steps of a round and ``vmap``-ed over the agent axis,
  so a flush of J pending rounds is a single dispatch. Buffers are
  donated on accelerators (donation is a no-op on CPU).
* Fleet-axis sharding: given a 1-D device mesh
  (:func:`repro.models.sharding.make_fleet_mesh`), the stacked agent
  axis is partitioned across devices (MaxText-style ``jax.sharding``
  annotations: state and indices sharded on the agent axis, replay pool
  replicated) and the chunk is jitted with explicit in/out shardings —
  per-agent work is embarrassingly parallel, so the compiler places each
  shard's slots on its device with no cross-slot collectives and
  throughput scales with the device count. The engine pads its resident
  slot count to a mesh-divisible pow2 bucket (dead slots are inert
  copies, never read), and a flush that covers the whole bucket skips
  the gather/scatter entirely: the resident state flows through the
  donated chunk end to end.
* :func:`collect_fleet` — the *collection* phase batched the same way: a
  stacked greedy-rollout program (:class:`CollectSteps`) computes every
  cohort agent's q-values for its own episode batch in ONE vmapped
  dispatch per environment step, replacing per-agent ``q_values``
  round-trips. Each lane applies its agent's params to its own ``[B]``
  batch — the identical slot program — so stacked collection is
  bit-identical to per-agent acting.
* Device-resident replay: ERBs are cached on device as flat ``[size, F]``
  float32 matrices; the host :class:`~repro.core.replay.SelectiveReplaySampler`
  shrinks to pool/index *selection* (its ``plan()`` half), and batch
  materialization happens inside the compiled chunk through the
  ``replay_gather`` Pallas kernel — one stacked host->device index
  transfer per scan chunk instead of one batch transfer per step.
* :class:`FleetEngine` — the host-side orchestrator: slots, lazy job
  queue, flush-on-read semantics. ``DQNAgent`` is a thin view over a
  slot; ``ADFLLSystem`` submits rounds and lets reads force batched
  flushes.

Numerics: the per-slot math of the fleet chunk is bitwise invariant to
the number of agents batched together (vmap slots are independent and
XLA:CPU compiles the slot program identically for any leading axis — see
``tests/test_fleet.py``), which is what makes the fleet-vs-sequential
bit-equivalence guarantee testable. The *legacy* per-step dispatch path
(``DQNAgent(backend="stepwise")``) differs from the fused program by
float-fusion ULPs, so it is kept only as a baseline and for
benchmarking.
"""

from __future__ import annotations

from collections import OrderedDict
from collections.abc import Callable, Sequence
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.adfll_dqn import DQNConfig
from repro.core.erb import ERB, erb_add, erb_flatten, flat_width
from repro.kernels.fused_td.ops import td_loss
from repro.kernels.replay_gather.ops import replay_gather
from repro.models.sharding import FleetSharding
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.rl.dqn import dqn_apply, dqn_init
from repro.telemetry import NULL


@jax.tree_util.register_pytree_node_class
@dataclass
class FleetState:
    """Stacked per-agent training state, leading axis = agent slot."""

    params: Any  # [N, ...] stacked DQN parameter pytree
    target: Any  # [N, ...] stacked target-network pytree
    opt: Any  # [N, ...] stacked AdamW state ({m, v, count})
    rng: jax.Array  # [N, 2] uint32 per-slot PRNG keys
    count: jax.Array  # [N] int32 per-slot step counters (target sync)

    def tree_flatten(self):
        return (self.params, self.target, self.opt, self.rng, self.count), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    @property
    def n_slots(self) -> int:
        return int(self.count.shape[0])


def make_dqn_opt_cfg(cfg: DQNConfig) -> AdamWConfig:
    """The DQN optimizer settings — one definition for the fleet chunk
    and the legacy per-step path (they must stay numerically twinned)."""
    return AdamWConfig(
        lr=cfg.lr, weight_decay=0.0, clip_norm=10.0, warmup_steps=0, total_steps=10**9
    )


def make_dqn_loss_fn(cfg: DQNConfig, use_pallas: bool):
    """The TD loss on a minibatch dict — shared by the fleet chunk and
    the legacy per-step path."""

    def loss_fn(params, target_params, batch):
        q = dqn_apply(cfg, params, batch["obs"], batch["loc"])
        q_sel = jnp.take_along_axis(q, batch["action"][:, None], 1)
        q_next = dqn_apply(cfg, target_params, batch["next_obs"], batch["next_loc"])
        q_next = jax.lax.stop_gradient(q_next)
        return td_loss(
            q_sel,
            q_next,
            batch["reward"][:, None],
            batch["done"][:, None],
            cfg.gamma,
            use_pallas,
        )

    return loss_fn


class FleetSteps:
    """The compiled fleet program for one (config, use_pallas, mesh) triple.

    ``train_chunk(state_slice, pool, idx) -> (state_slice, losses)`` where
    ``state_slice`` is a :class:`FleetState` of the participating slots,
    ``pool`` is the flat ``[R, F]`` device replay pool shared by the
    chunk, and ``idx`` is the ``[K, N, B]`` int32 global row-index tensor
    (the one host->device transfer of a flush). ``n_traces`` counts
    retraces — the no-recompilation tests assert it stays at 1 across
    same-config agents.

    ``train_chunk_stats`` is the observatory variant: the same scan with
    the same update math, additionally carrying a small stacked stats
    pytree (per-step per-slot loss / mean |TD error| / max |Q| / grad
    global-norm, plus a per-slot params-finite flag) through the scan —
    accumulated device-side and drained only at the flush boundary, so
    enabling the observatory adds no extra host syncs.  It is compiled
    lazily on first use: engines without an observatory never trace it.

    With a ``mesh`` (1-D agent-axis device mesh), both chunks are jitted
    with explicit in/out shardings: state leaves and the ``[K, N, B]``
    index tensor partitioned on the agent axis, the replay pool
    replicated. The slot program has no cross-slot data flow, so the
    compiler runs each device's shard independently — agents-per-device
    throughput scaling with bitwise-identical per-slot math (the same
    N-invariance that backs the fleet-vs-sequential guarantee; asserted
    against a single-device run in ``tests/test_fleet.py``).
    """

    def __init__(self, cfg: DQNConfig, use_pallas: bool, mesh=None):
        self.cfg = cfg
        self.use_pallas = use_pallas
        self.mesh = mesh
        self.sharding = FleetSharding(mesh) if mesh is not None else None
        self.opt_cfg = make_dqn_opt_cfg(cfg)
        self.n_traces = 0
        box = cfg.box_size
        obs_f = box[0] * box[1] * box[2]
        feat = flat_width(box)

        def split_rows(rows):
            """[B, F] flat rows -> batch dict (FLAT_FIELDS column order)."""
            b = rows.shape[0]
            o = 0
            out = {}
            for key, width in (
                ("obs", obs_f),
                ("loc", 3),
                ("action", 1),
                ("reward", 1),
                ("next_obs", obs_f),
                ("next_loc", 3),
                ("done", 1),
            ):
                v = rows[:, o : o + width]
                o += width
                if key in ("obs", "next_obs"):
                    v = v.reshape(b, *box)
                elif key in ("action", "reward", "done"):
                    v = v[:, 0]
                if key == "action":
                    v = v.astype(jnp.int32)
                out[key] = v
            return out

        loss_fn = make_dqn_loss_fn(cfg, use_pallas)

        def slot_step(params, target, opt, count, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, target, batch)
            params, opt, _ = adamw_update(self.opt_cfg, params, grads, opt)
            count = count + 1
            sync = (count % cfg.target_update) == 0
            target = jax.tree_util.tree_map(
                lambda t, p: jnp.where(sync, p, t), target, params
            )
            return params, target, opt, count, loss

        def loss_fn_stats(params, target_params, batch):
            # the same primal graph as loss_fn, with observational
            # scalars as a non-differentiated aux output
            q = dqn_apply(cfg, params, batch["obs"], batch["loc"])
            q_sel = jnp.take_along_axis(q, batch["action"][:, None], 1)
            q_next = dqn_apply(cfg, target_params, batch["next_obs"], batch["next_loc"])
            q_next = jax.lax.stop_gradient(q_next)
            loss = td_loss(
                q_sel,
                q_next,
                batch["reward"][:, None],
                batch["done"][:, None],
                cfg.gamma,
                use_pallas,
            )
            td_target = batch["reward"][:, None] + cfg.gamma * (
                1.0 - batch["done"][:, None]
            ) * jnp.max(q_next, axis=-1, keepdims=True)
            td_abs = jnp.mean(jnp.abs(jax.lax.stop_gradient(q_sel) - td_target))
            q_max = jnp.max(jnp.abs(jax.lax.stop_gradient(q)))
            return loss, (td_abs, q_max)

        def slot_step_stats(params, target, opt, count, batch):
            (loss, aux), grads = jax.value_and_grad(loss_fn_stats, has_aux=True)(
                params, target, batch
            )
            params, opt, _ = adamw_update(self.opt_cfg, params, grads, opt)
            count = count + 1
            sync = (count % cfg.target_update) == 0
            target = jax.tree_util.tree_map(
                lambda t, p: jnp.where(sync, p, t), target, params
            )
            gnorm = jnp.sqrt(
                sum(jnp.sum(g * g) for g in jax.tree_util.tree_leaves(grads))
            )
            td_abs, q_max = aux
            return params, target, opt, count, loss, td_abs, q_max, gnorm

        def chunk(state: FleetState, pool, idx):
            self.n_traces += 1  # trace-time side effect: counts retraces

            def body(carry, idx_k):
                p, t, o, c = carry
                n, b = idx_k.shape
                rows = replay_gather(
                    pool,
                    idx_k.reshape(-1),
                    jnp.ones((n * b,), jnp.float32),
                    mode="auto",  # compiled kernel on TPU, XLA gather on CPU
                )
                batch = jax.vmap(split_rows)(rows.reshape(n, b, feat))
                p, t, o, c, loss = jax.vmap(slot_step)(p, t, o, c, batch)
                return (p, t, o, c), loss

            carry = (state.params, state.target, state.opt, state.count)
            (p, t, o, c), losses = jax.lax.scan(body, carry, idx)
            rng = jax.vmap(jax.random.fold_in)(state.rng, c)
            return FleetState(p, t, o, rng, c), losses

        def chunk_stats(state: FleetState, pool, idx):
            self.n_traces += 1  # trace-time side effect: counts retraces

            def body(carry, idx_k):
                p, t, o, c = carry
                n, b = idx_k.shape
                rows = replay_gather(
                    pool,
                    idx_k.reshape(-1),
                    jnp.ones((n * b,), jnp.float32),
                    mode="auto",
                )
                batch = jax.vmap(split_rows)(rows.reshape(n, b, feat))
                p, t, o, c, loss, td, qm, gn = jax.vmap(slot_step_stats)(
                    p, t, o, c, batch
                )
                return (p, t, o, c), (loss, td, qm, gn)

            carry = (state.params, state.target, state.opt, state.count)
            (p, t, o, c), (losses, td, qm, gn) = jax.lax.scan(body, carry, idx)
            rng = jax.vmap(jax.random.fold_in)(state.rng, c)
            finite = jnp.ones((c.shape[0],), bool)
            for leaf in jax.tree_util.tree_leaves(p):
                finite = finite & jnp.all(
                    jnp.isfinite(leaf.reshape(leaf.shape[0], -1)), axis=1
                )
            stats = {
                "loss": losses,  # [K, N]
                "td_abs": td,  # [K, N]
                "q_max": qm,  # [K, N]
                "grad_norm": gn,  # [K, N]
                "params_finite": finite,  # [N]
            }
            return FleetState(p, t, o, rng, c), stats

        # donated stacked buffers: in-place update on accelerators
        # (donation is unimplemented on CPU; avoid the warning spam there)
        donate = () if jax.default_backend() == "cpu" else (0,)
        if self.sharding is None:
            self.train_chunk: Callable = jax.jit(chunk, donate_argnums=donate)
        else:
            fs = self.sharding
            self.train_chunk = jax.jit(
                chunk,
                donate_argnums=donate,
                in_shardings=(fs.stacked, fs.replicated, fs.indices),
                out_shardings=(fs.stacked, fs.indices),
            )
        self._chunk_stats_fn = chunk_stats
        self._donate = donate
        self._train_chunk_stats: Callable | None = None

    @property
    def train_chunk_stats(self) -> Callable:
        """The stats-carrying chunk, jitted on first use (engines without
        an observatory never pay its trace/compile)."""
        if self._train_chunk_stats is None:
            if self.sharding is None:
                self._train_chunk_stats = jax.jit(
                    self._chunk_stats_fn, donate_argnums=self._donate
                )
            else:
                fs = self.sharding
                stats_out = {
                    "loss": fs.indices,  # [K, N]
                    "td_abs": fs.indices,
                    "q_max": fs.indices,
                    "grad_norm": fs.indices,
                    "params_finite": fs.stacked,  # [N]
                }
                self._train_chunk_stats = jax.jit(
                    self._chunk_stats_fn,
                    donate_argnums=self._donate,
                    in_shardings=(fs.stacked, fs.replicated, fs.indices),
                    out_shardings=(fs.stacked, stats_out),
                )
        return self._train_chunk_stats

    def init_slot(self, seed: int) -> FleetState:
        """A 1-slot :class:`FleetState` seeded exactly like the legacy
        ``DQNAgent.__post_init__`` (``dqn_init(PRNGKey(seed))``)."""
        key = jax.random.PRNGKey(seed)
        params = dqn_init(key, self.cfg)
        opt = adamw_init(self.opt_cfg, params)

        def one(x):
            return jax.tree_util.tree_map(lambda v: jnp.asarray(v)[None], x)

        return FleetState(
            params=one(params),
            target=one(params),
            opt=one(opt),
            rng=jax.random.fold_in(key, 1)[None],
            count=jnp.zeros((1,), jnp.int32),
        )


_FLEET_STEPS_CACHE: dict[tuple, FleetSteps] = {}


def make_fleet_steps(cfg: DQNConfig, *, use_pallas: bool = False, mesh=None) -> FleetSteps:
    """(config, mesh)-keyed cache of the compiled fleet program: N
    same-config agents (or engines) share one traced/compiled
    ``train_chunk``. ``jax.sharding.Mesh`` is hashable, so meshed and
    single-device engines coexist without retracing each other."""
    key = (cfg, bool(use_pallas), mesh)
    steps = _FLEET_STEPS_CACHE.get(key)
    if steps is None:
        steps = FleetSteps(cfg, bool(use_pallas), mesh)
        _FLEET_STEPS_CACHE[key] = steps
    return steps


class CollectSteps:
    """The compiled stacked greedy-rollout q-value program of one config.

    ``qvals(stacked, obs, loc) -> q`` maps an ``[A, ...]`` stacked
    parameter pytree and ``[A, B, *box]`` / ``[A, B, 3]`` per-agent
    observation batches to ``[A, B, n_actions]`` q-values: one vmapped
    dispatch computes every cohort agent's greedy preferences for the
    step, replacing A per-agent ``q_values`` round-trips during
    collection. Each lane is ``dqn_apply`` on that agent's own ``[B]``
    batch — the exact per-agent program — so the stacked q-values are
    bitwise identical to per-agent acting (asserted in
    ``tests/test_fleet.py``), and epsilon-greedy sampling stays on the
    host consuming each agent's own rng stream in the per-agent order.

    With a ``mesh``, all three operands are sharded on the leading agent
    axis, so collection scales with devices like the train chunk.
    ``n_traces`` counts retraces — one compile per distinct ``(A, B)``
    bucket (cohorts pad the agent axis to pow2 buckets).
    """

    def __init__(self, cfg: DQNConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        self.n_traces = 0

        def qvals(stacked, obs, loc):
            self.n_traces += 1  # trace-time side effect: counts retraces
            return jax.vmap(lambda p, o, l: dqn_apply(cfg, p, o, l))(
                stacked, obs, loc
            )

        if mesh is None:
            self.qvals: Callable = jax.jit(qvals)
        else:
            fs = FleetSharding(mesh)
            self.qvals = jax.jit(
                qvals,
                in_shardings=(fs.stacked, fs.stacked, fs.stacked),
                out_shardings=fs.stacked,
            )


_COLLECT_STEPS_CACHE: dict[tuple, CollectSteps] = {}


def make_collect_steps(cfg: DQNConfig, *, mesh=None) -> CollectSteps:
    """(config, mesh)-keyed cache of the stacked collection program."""
    key = (cfg, mesh)
    steps = _COLLECT_STEPS_CACHE.get(key)
    if steps is None:
        steps = CollectSteps(cfg, mesh)
        _COLLECT_STEPS_CACHE[key] = steps
    return steps


def collect_fleet(agents, envs, erbs, n_episodes: int) -> None:
    """Collect one round of experience for a cohort of fleet agents with
    the stacked act program — one vmapped q-value dispatch per
    environment step for the whole cohort.

    ``agents[i]`` rolls ``n_episodes`` episodes in ``envs[i]``, appending
    transitions to ``erbs[i]``. Bit-identical to calling
    ``DQNAgent.collect`` per agent: each vmap lane runs the agent's own
    slot program on its own batch (bitwise-equal q-values), and every
    epsilon-greedy draw (`start_locs`, action integers, exploration
    coins) comes from that agent's own ``np.random.Generator`` in the
    identical order. Agents whose episodes all finish early stop
    consuming their rng and stop writing their ERB, exactly like the
    per-agent loop's early ``break``.
    """
    if not agents:
        return
    engine = agents[0].engine
    cfg = agents[0].cfg
    steps = make_collect_steps(cfg, mesh=engine.mesh)
    n = len(agents)
    n_min = engine.mesh.size if engine.mesh is not None else 1
    a_pad = max(_pow2(n), n_min)
    slots = [a.slot for a in agents] + [agents[0].slot] * (a_pad - n)
    stacked = engine.padded_slot_params(slots)
    box = cfg.box_size
    b = n_episodes
    locs = np.stack([env.start_locs(b, a.rng) for a, env in zip(agents, envs)])
    alive = np.ones((n, b), bool)
    obs_buf = np.zeros((a_pad, b, *box), np.float32)
    loc_buf = np.zeros((a_pad, b, 3), np.float32)
    for _ in range(cfg.max_episode_steps):
        live = [i for i in range(n) if alive[i].any()]
        if not live:
            break
        for i in live:
            obs_buf[i] = envs[i].observe(locs[i])
            loc_buf[i] = envs[i].norm_loc(locs[i])
        q = np.asarray(steps.qvals(stacked, jnp.asarray(obs_buf), jnp.asarray(loc_buf)))
        for i in live:
            agent, env, erb = agents[i], envs[i], erbs[i]
            eps = agent.epsilon()
            greedy = q[i].argmax(-1)
            rand = agent.rng.integers(0, cfg.n_actions, size=b)
            coin = agent.rng.random(b) < eps
            acts = np.where(coin, rand, greedy).astype(np.int32)
            new, r, done = env.step(locs[i], acts)
            idx = np.where(alive[i])[0]
            batch = {
                # obs_buf[i] is env.observe(locs[i]) — reuse the staged
                # rows instead of re-cropping for the ERB append
                "obs": obs_buf[i][idx],
                "loc": loc_buf[i][idx],
                "action": acts[idx],
                "reward": r[idx],
                "next_obs": env.observe(new[idx]),
                "next_loc": env.norm_loc(new[idx]),
                "done": done[idx].astype(np.float32),
            }
            erb_add(erb, batch)
            locs[i] = new
            alive[i] &= ~done


class ActSteps:
    """The compiled batched greedy-act program of one config.

    ``act(stacked, slot, obs, loc) -> (actions, q)`` where ``stacked``
    is a parameter pytree with one leading stacked axis (fleet slots, or
    the serving plane's flattened version x agent grid), ``slot`` is the
    per-request [B] int32 row into that axis, and ``obs``/``loc`` are
    the [B, *box] / [B, 3] observation batch. Each request runs as an
    independent ``vmap`` lane gathering its own parameter rows, so the
    per-request math is bitwise invariant to the batch it shares a
    dispatch with — the same slot-independence that backs the fleet
    train chunk's N-invariance (``tests/test_fleet.py``) makes batched
    serving bit-identical to single-request serving.

    ``n_traces`` counts retraces; one compile per distinct batch-size
    bucket, so a service that pads to pow2 buckets stops retracing once
    its buckets are warm (asserted by the serve tests and surfaced by
    ``launch.serve --fleet`` as ``recompiles_after_warmup``).
    """

    def __init__(self, cfg: DQNConfig):
        self.cfg = cfg
        self.n_traces = 0

        def one(stacked, slot, obs, loc):
            p = jax.tree_util.tree_map(lambda x: x[slot], stacked)
            return dqn_apply(cfg, p, obs[None], loc[None])[0]

        def act(stacked, slot, obs, loc):
            self.n_traces += 1  # trace-time side effect: counts retraces
            q = jax.vmap(one, in_axes=(None, 0, 0, 0))(stacked, slot, obs, loc)
            return jnp.argmax(q, axis=-1).astype(jnp.int32), q

        self.act: Callable = jax.jit(act)

    def warmup(self, stacked, batch_sizes: Sequence[int]) -> None:
        """Compile every bucket entrypoint up front (zero-filled inputs;
        the results are discarded)."""
        box = self.cfg.box_size
        for b in batch_sizes:
            slot = jnp.zeros((b,), jnp.int32)
            obs = jnp.zeros((b, *box), jnp.float32)
            loc = jnp.zeros((b, 3), jnp.float32)
            jax.block_until_ready(self.act(stacked, slot, obs, loc))


_ACT_STEPS_CACHE: dict[DQNConfig, ActSteps] = {}


def make_act_steps(cfg: DQNConfig) -> ActSteps:
    """Config-keyed cache of the batched act program (one compile per
    batch bucket shared by every service/evaluator of this config)."""
    steps = _ACT_STEPS_CACHE.get(cfg)
    if steps is None:
        steps = ActSteps(cfg)
        _ACT_STEPS_CACHE[cfg] = steps
    return steps


class TrainFuture:
    """Resolution handle of a submitted training job: ``loss`` is the
    last-step TD loss once the job's chunk has flushed."""

    __slots__ = ("done", "loss", "_cbs")

    def __init__(self):
        self.done = False
        self.loss: float | None = None
        self._cbs: list[Callable[[float], None]] = []

    def on_done(self, cb: Callable[[float], None]) -> None:
        if self.done:
            cb(self.loss)
        else:
            self._cbs.append(cb)

    def resolve(self, loss: float) -> None:
        self.done = True
        self.loss = float(loss)
        cbs, self._cbs = self._cbs, []
        for cb in cbs:
            cb(self.loss)


class _Job:
    """One pending round of training for a slot: the ERBs it reads and
    the per-step (erb-position, row) selection, shuffle already applied."""

    __slots__ = ("slot", "n_steps", "erbs", "eidx", "rows", "future")

    def __init__(self, slot, n_steps, erbs, eidx, rows, future):
        self.slot = slot
        self.n_steps = n_steps
        self.erbs: list[ERB] = erbs
        self.eidx: np.ndarray = eidx  # [K, B] int32 position into self.erbs
        self.rows: np.ndarray = rows  # [K, B] int32 local row index
        self.future: TrainFuture = future


def _pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length()


class FleetEngine:
    """Host-side orchestrator of one stacked fleet.

    Slots are added per agent; training rounds are *submitted* as jobs
    (pure index plans — no data moves) and executed lazily: any read or
    write of a slot's state forces a flush, and a flush trains **all**
    pending jobs in one scan-fused, vmapped dispatch. Futures resolve in
    submission order, so deferred bookkeeping (round records) lands in
    the same order as sequential execution.

    The resident slot axis is padded to a pow2, mesh-divisible
    ``capacity``: rows past ``n_slots`` are *dead* — inert copies that
    are never read and get overwritten in place when a slot is added, so
    growth (and churn re-adds) no longer reshapes the stacked arrays or
    forces a flush while capacity is spare. With a ``mesh`` (a 1-D
    agent-axis device mesh from
    :func:`repro.models.sharding.make_fleet_mesh`), the resident state is
    committed to agent-axis shardings and flushes that cover the whole
    bucket pass it straight through the donated sharded chunk — no
    gather, no scatter, device-resident end to end.
    """

    def __init__(
        self,
        cfg: DQNConfig,
        *,
        mesh=None,
        use_pallas: bool = False,
        erb_cache_size: int = 128,
        erb_cache_bytes: int = 256 * 1024**2,
        pool_bucket_floor: int = 128,
    ):
        self.cfg = cfg
        self.use_pallas = bool(use_pallas)
        self.mesh = mesh
        if mesh is not None and (mesh.size & (mesh.size - 1)):
            raise ValueError("fleet mesh size must be a power of two")
        self.sharding = FleetSharding(mesh) if mesh is not None else None
        self.steps = make_fleet_steps(cfg, use_pallas=use_pallas, mesh=mesh)
        self.state: FleetState | None = None
        self.n_slots = 0
        self.capacity = 0  # resident rows (pow2, mesh-divisible; >= n_slots)
        self.erb_cache_size = erb_cache_size
        self.erb_cache_bytes = erb_cache_bytes
        self.pool_bucket_floor = pool_bucket_floor
        self._feat = flat_width(cfg.box_size)
        self._pending: list[_Job] = []
        self._pending_slots: set = set()
        self._erb_cache: OrderedDict[tuple[str, int], jax.Array] = OrderedDict()
        self._erb_cache_nbytes = 0
        self._views: dict[int, FleetState] = {}
        # flush statistics (fleet_throughput reports these)
        self.n_flushes = 0
        self.n_steps_trained = 0
        self.flush_sizes: list[int] = []
        # observability: the owning system replaces these after
        # construction (ADFLLSystem / ServeSession) — NULL costs nothing.
        # With an observatory attached, flushes run the stats-carrying
        # chunk and drain per-agent learning dynamics at the same
        # boundary as the loss sync.
        self.telemetry = NULL
        self.sim_clock: Callable[[], float] | None = None
        self.observatory = None

    # -- slots ---------------------------------------------------------------
    def add_slot(self, seed: int) -> int:
        slot = self.n_slots
        slot_state = self.steps.init_slot(seed)
        if slot < self.capacity:
            # reuse a dead row in place: live rows are untouched, so jobs
            # already queued for other slots keep batching (no flush)
            self.state = jax.tree_util.tree_map(
                lambda s, v: s.at[slot].set(v[0]), self.state, slot_state
            )
        else:
            if self.state is not None:
                self.flush()  # resident axis grows: retire pending jobs first
            n_min = self.mesh.size if self.mesh is not None else 1
            new_cap = max(_pow2(slot + 1), n_min)
            # the dead tail holds copies of the fresh slot: inert rows,
            # never read, overwritten on reuse
            tiled = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (new_cap - slot, *x.shape[1:])),
                slot_state,
            )
            if self.state is None:
                self.state = tiled
            else:
                self.state = jax.tree_util.tree_map(
                    lambda s, t: jnp.concatenate([s, t], axis=0), self.state, tiled
                )
            self.capacity = new_cap
        if self.sharding is not None:
            self.state = self.sharding.place(self.state)
        self._views.pop(slot, None)
        self.n_slots = slot + 1
        return slot

    # -- state access (flush-on-read/write) -----------------------------------
    def ensure_flushed(self, slot: int | None = None) -> None:
        """Flush all pending jobs iff ``slot`` has one (or any, if None)."""
        if slot is None:
            if self._pending:
                self.flush()
        elif slot in self._pending_slots:
            self.flush()

    def _view(self, slot: int) -> FleetState:
        v = self._views.get(slot)
        if v is None:
            v = jax.tree_util.tree_map(lambda x: x[slot], self.state)
            self._views[slot] = v
        return v

    def get_params(self, slot: int):
        self.ensure_flushed(slot)
        return self._view(slot).params

    def stacked_params(self):
        """Flush-on-read snapshot of *every live* slot's params as one
        stacked [N, ...] pytree — the serving plane's publish path
        (:class:`repro.serve.ParamPublisher` reads this between ticks).
        Dead padding rows never leak: the slice stops at ``n_slots``."""
        self.ensure_flushed()
        if self.n_slots == self.capacity:
            return self.state.params
        return jax.tree_util.tree_map(lambda x: x[: self.n_slots], self.state.params)

    def padded_slot_params(self, slots: Sequence[int]):
        """Stacked params of ``slots`` (repeats allowed — collection pads
        cohorts with duplicates of the first slot), flushing only the
        touched slots' pending work, same laziness as ``get_params``.
        When the cohort covers the whole resident bucket in order, the
        resident (already mesh-committed) arrays are returned as-is."""
        for s in set(slots):
            self.ensure_flushed(s)
        if list(slots) == list(range(self.capacity)):
            return self.state.params
        g = jnp.asarray(np.asarray(slots, np.int32))
        gathered = jax.tree_util.tree_map(
            lambda x: jnp.take(x, g, axis=0), self.state.params
        )
        # the gather commits its output replicated; re-place so the stacked
        # tree matches the collect program's explicit in_shardings
        return self.sharding.place(gathered) if self.sharding else gathered

    def get_target(self, slot: int):
        self.ensure_flushed(slot)
        return self._view(slot).target

    def get_opt(self, slot: int):
        self.ensure_flushed(slot)
        return self._view(slot).opt

    def _set_field(self, slot: int, field: str, value) -> None:
        self.ensure_flushed(slot)
        updated = jax.tree_util.tree_map(
            lambda s, v: s.at[slot].set(jnp.asarray(v)),
            getattr(self.state, field),
            value,
        )
        parts = {
            f: getattr(self.state, f)
            for f in ("params", "target", "opt", "rng", "count")
        }
        parts[field] = updated
        self.state = FleetState(**parts)
        if self.sharding is not None:
            self.state = self.sharding.place(self.state)
        self._views.pop(slot, None)

    def set_params(self, slot: int, params) -> None:
        self._set_field(slot, "params", params)

    def set_target(self, slot: int, target) -> None:
        self._set_field(slot, "target", target)

    def set_opt(self, slot: int, opt) -> None:
        self._set_field(slot, "opt", opt)

    # -- replay pool ----------------------------------------------------------
    def _flat_erb(self, erb: ERB) -> jax.Array:
        """Device-resident [size, F] matrix of an ERB (LRU-cached; keyed
        by (erb_id, version) so host-side ring appends invalidate; bounded
        by entry count *and* total bytes — at paper-scale buffers the byte
        budget binds first)."""
        key = (erb.meta.erb_id, erb.version)
        hit = self._erb_cache.get(key)
        if hit is not None:
            self._erb_cache.move_to_end(key)
            self.telemetry.count("fleet.erb_cache.hits", 1)
            return hit
        self.telemetry.count("fleet.erb_cache.misses", 1)
        flat = jnp.asarray(erb_flatten(erb))
        self._erb_cache[key] = flat
        self._erb_cache_nbytes += flat.nbytes
        while len(self._erb_cache) > 1 and (
            len(self._erb_cache) > self.erb_cache_size
            or self._erb_cache_nbytes > self.erb_cache_bytes
        ):
            _, evicted = self._erb_cache.popitem(last=False)
            self._erb_cache_nbytes -= evicted.nbytes
            self.telemetry.count("fleet.erb_cache.evictions", 1)
        return flat

    # -- job queue ------------------------------------------------------------
    def submit(self, slot: int, plans: Sequence) -> TrainFuture:
        """Queue one job: K minibatch :class:`~repro.core.replay.ReplayPlan`s
        for ``slot``. Returns a future resolving to the last-step loss."""
        if slot in self._pending_slots:
            self.flush()  # one in-flight round per slot
        future = TrainFuture()
        n_steps = len(plans)
        if n_steps == 0:
            future.resolve(0.0)
            return future
        batch = plans[0].batch_size
        erbs: list[ERB] = []
        positions: dict[str, int] = {}
        eidx = np.empty((n_steps, batch), np.int32)
        rows = np.empty((n_steps, batch), np.int32)
        for k, plan in enumerate(plans):
            e_parts, r_parts = [], []
            for erb, ridx in plan.picks:
                pos = positions.get(erb.meta.erb_id)
                if pos is None:
                    pos = len(erbs)
                    positions[erb.meta.erb_id] = pos
                    erbs.append(erb)
                e_parts.append(np.full(len(ridx), pos, np.int32))
                r_parts.append(np.asarray(ridx, np.int32))
            # permuting indices before the gather == permuting rows after
            eidx[k] = np.concatenate(e_parts)[plan.perm]
            rows[k] = np.concatenate(r_parts)[plan.perm]
        self._pending.append(_Job(slot, n_steps, erbs, eidx, rows, future))
        self._pending_slots.add(slot)
        return future

    def flush(self) -> None:
        """Train every pending job in one dispatch (per distinct K)."""
        if not self._pending:
            return
        jobs, self._pending = self._pending, []
        self._pending_slots = set()
        # chunk consecutive jobs of equal K so futures resolve in
        # submission order (one K per ADFLL run; mixed only in tests)
        i = 0
        while i < len(jobs):
            j = i + 1
            while j < len(jobs) and jobs[j].n_steps == jobs[i].n_steps:
                j += 1
            self._flush_group(jobs[i:j])
            i = j

    def _flush_group(self, jobs: list[_Job]) -> None:
        tel = self.telemetry
        wall0 = tel.wall() if tel.enabled else 0.0
        traces0 = self.steps.n_traces
        n_real = len(jobs)
        k_steps = jobs[0].n_steps
        batch = jobs[0].eidx.shape[1]
        # one shared device pool: the union of every job's ERBs
        offsets: dict[str, int] = {}
        parts: list[jax.Array] = []
        total = 0
        for job in jobs:
            for erb in job.erbs:
                if erb.meta.erb_id not in offsets:
                    offsets[erb.meta.erb_id] = total
                    total += erb.size
                    parts.append(self._flat_erb(erb))
        # bucket pool rows and job count (powers of two, mesh-divisible)
        # to bound the number of compiled (K, N, R) shape variants
        r_pad = max(self.pool_bucket_floor, _pow2(total))
        if r_pad > total:
            parts.append(jnp.zeros((r_pad - total, self._feat), jnp.float32))
        pool = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
        n_min = self.mesh.size if self.mesh is not None else 1
        n_pad = max(_pow2(n_real), n_min)
        slots = [job.slot for job in jobs]
        # whole-bucket fast path: the jobs cover every live slot in order,
        # so the resident state IS the chunk operand — no gather in, no
        # scatter out, and the donated buffers flow through the flush end
        # to end (padding lanes train on pool row 0; those rows are dead
        # slots, never read, overwritten on slot reuse)
        resident = (
            n_real == self.n_slots
            and slots == list(range(n_real))
            and n_pad == self.capacity
        )
        idx = np.zeros((k_steps, n_pad, batch), np.int32)
        for jpos, job in enumerate(jobs):
            base = np.array([offsets[e.meta.erb_id] for e in job.erbs], np.int32)
            idx[:, jpos, :] = base[job.eidx] + job.rows
        if resident:
            sub = self.state
        else:
            padded = slots + [slots[0]] * (n_pad - n_real)  # inert duplicates
            gather = jnp.asarray(padded)
            sub = jax.tree_util.tree_map(
                lambda x: jnp.take(x, gather, axis=0), self.state
            )
            if self.sharding is not None:
                # the gather commits its output replicated; re-place so
                # the operand matches the chunk's explicit in_shardings
                sub = self.sharding.place(sub)
        obs = self.observatory
        stats = None
        if obs is None:
            new, losses = self.steps.train_chunk(sub, pool, jnp.asarray(idx))
        else:
            new, stats = self.steps.train_chunk_stats(sub, pool, jnp.asarray(idx))
            losses = stats["loss"]
        if resident:
            self.state = new
        else:
            real = jnp.asarray(slots)
            self.state = jax.tree_util.tree_map(
                lambda s, ns: s.at[real].set(ns[:n_real]), self.state, new
            )
            if self.sharding is not None:
                self.state = self.sharding.place(self.state)
        self._views.clear()
        losses_np = np.asarray(losses)  # the flush's one host sync
        if obs is not None and stats is not None:
            # drained at the same boundary — no extra mid-scan syncs,
            # just more values riding the flush's host transfer
            stats_np = {k: np.asarray(v) for k, v in stats.items()}
            sim_t = self.sim_clock() if self.sim_clock is not None else 0.0
            obs.on_flush(slots, stats_np, n_real, sim_t)
        self.n_flushes += 1
        self.n_steps_trained += n_real * k_steps
        self.flush_sizes.append(n_real)
        if tel.enabled:
            wall1 = tel.wall()
            compiled = self.steps.n_traces - traces0
            tel.span(
                "fleet.flush",
                "fleet",
                wall0,
                wall1,
                clock="wall",
                jobs=n_real,
                k_steps=k_steps,
                batch=batch,
                pool_rows=int(r_pad),
                devices=n_min,
                resident=resident,
                compiled=compiled,
            )
            if compiled:
                tel.instant("fleet.compile", "fleet", wall1, clock="wall")
                tel.count("fleet.compiles", compiled)
            if self.sim_clock is not None:
                # the same flush pinned to simulated time, so trace views
                # can correlate host cost with scheduler progress
                tel.instant("fleet.flush", "fleet", self.sim_clock(), jobs=n_real)
            tel.count("fleet.flushes", 1)
            tel.count("fleet.steps_trained", n_real * k_steps)
            tel.observe("fleet.flush.jobs", n_real)
            tel.observe("fleet.flush.wall_s", wall1 - wall0)
        for jpos, job in enumerate(jobs):
            job.future.resolve(float(losses_np[-1, jpos]))


__all__ = [
    "ActSteps",
    "CollectSteps",
    "FleetEngine",
    "FleetState",
    "FleetSteps",
    "TrainFuture",
    "collect_fleet",
    "make_act_steps",
    "make_collect_steps",
    "make_fleet_steps",
]
