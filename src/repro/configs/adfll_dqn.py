"""ADFLL DQN agent config — the paper's own model (Appendix A.1).

The 3D DQN is not part of the transformer zoo; it registers a separate
lightweight config consumed by ``repro.rl``. Defaults reproduce the paper's
deployment experiment at CPU-tractable scale (the real system used 45^3
crops; we default to 24^3 synthetic volumes).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class DQNConfig:
    volume_shape: tuple[int, int, int] = (24, 24, 24)
    box_size: tuple[int, int, int] = (8, 8, 8)
    n_actions: int = 6  # +/- x, y, z
    frame_history: int = 1  # chain of locations in the state
    conv_features: tuple[int, ...] = (8, 16, 32)
    hidden: tuple[int, ...] = (128, 64)
    gamma: float = 0.9
    lr: float = 1e-3
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_steps: int = 500
    target_update: int = 50  # steps between target-net syncs
    batch_size: int = 32
    max_episode_steps: int = 48
    step_size: int = 1  # voxels per action


@dataclass(frozen=True)
class ADFLLConfig:
    """System-level config for the deployment experiment (Fig. 2)."""

    n_agents: int = 4
    n_hubs: int = 3
    # hub assignment per agent (paper: A1->H1, A2->H2, A3/A4->H3)
    agent_hub: tuple[int, ...] = (0, 1, 2, 2)
    # relative training speed (paper: DGX-1 V100 agents ~2.5x faster than T4)
    agent_speed: tuple[float, ...] = (1.0, 1.0, 2.5, 2.5)
    hub_sync_period: float = 1.0  # simulated time between hub syncs
    dropout: float = 0.0  # communication dropout probability
    rounds: int = 3
    erb_capacity: int = 2048
    erb_share_size: int = 512  # experiences shared per round
    replay_mix: tuple[float, float, float] = (0.5, 0.25, 0.25)
    # fractions: (current task, personal past, incoming foreign)
    train_steps_per_round: int = 150
    seed: int = 0
    # -- execution engine ---------------------------------------------------
    # "fleet": rounds are submitted to the vectorized fleet engine and
    # execute lazily as batched scan-fused dispatches (the default);
    # "fleet-eager": same engine, flushed after every round (sequential
    # driving — bit-identical to "fleet", used by the equivalence tests);
    # "stepwise": the legacy one-dispatch-per-step path (benchmark
    # baseline; within float-fusion ULPs of the fused engine).
    engine: str = "fleet"
    # devices joining the fleet mesh (the stacked agent axis is sharded
    # across them): 0 = single-device (no mesh), -1 = every local device,
    # N = up to N — rounded down to a power of two; per-slot math is
    # bitwise invariant to the mesh, so reports match the 0 setting.
    fleet_devices: int = 0
    # task curriculum: "roundrobin" (the paper's rotation), "blocked"
    # (one task per cohort of n_agents draws before advancing), or
    # "shuffled" (seeded permutation of each full pass over the tasks)
    task_curriculum: str = "roundrobin"
    # -- topology (beyond-paper: hub-less gossip, BrainTorrent-style) ------
    # "hub": agents <-> hubs (the paper); "gossip": peer-to-peer anti-entropy,
    # no hub in the loop; "hybrid": both transports at once.
    topology: str = "hub"
    gossip_sampler: str = "random"  # ring | random | full | timevary
    gossip_fanout: int = 2  # peers per agent per round
    gossip_period: float = 0.5  # sim time between anti-entropy rounds
    # -- link model / bandwidth accounting ---------------------------------
    # every agent-link message costs latency + bytes/rate of simulated time
    # and may drop; the defaults are free+lossless (paper-faithful timing).
    link_latency: float = 0.0
    link_rate: float = float("inf")  # bytes per unit of simulated time
    link_drop: float = 0.0  # per-message gossip drop probability
    # -- sharing planes (beyond-paper: FedAsync-style weight plane) --------
    # which planes ride the topology: ("erb",), ("weights",), or both
    share_planes: tuple[str, ...] = ("erb",)
    # weight-plane wire compression: "none" (full float32 pytrees),
    # "int8" (dense quantized snapshots, ~4x), or "topk" (int8 top-k
    # deltas with sender-side error feedback, >=4x and usually ~15x)
    weight_compression: str = "none"
    weight_topk_frac: float = 0.05  # fraction of coords kept per delta
    mix_alpha: float = 0.6  # base mixing rate for peer weights
    staleness_flag: str = "poly"  # constant | hinge | poly
    # "time" measures staleness on the shared scheduler clock (robust to
    # heterogeneous agent speeds); "round" is FedAsync-literal counters
    staleness_clock: str = "time"
    staleness_hinge_a: float = 10.0
    staleness_hinge_b: float = 4.0
    staleness_poly_a: float = 0.5
    weight_max_versions: int = 2  # snapshots kept per agent per hub


DQN_CONFIG = DQNConfig()
ADFLL_CONFIG = ADFLLConfig()
