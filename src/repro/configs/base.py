"""Config system: architecture configs, input shapes, and the registry.

Every assigned architecture gets a module in ``repro/configs/<id>.py`` that
builds a :class:`ModelConfig` with the exact published hyper-parameters and
registers it under its id.  ``reduced()`` derives the CPU-smoke variant
(2 layers, d_model<=512, <=4 experts) from the same config so the smoke test
exercises the identical code path as the full dry-run.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

# ---------------------------------------------------------------------------
# Block kinds
# ---------------------------------------------------------------------------
ATTN = "attn"  # (GQA / MHA) attention mixer
MLA = "mla"  # DeepSeek multi-head latent attention mixer
MAMBA = "mamba"  # Mamba-1 selective SSM mixer
SLSTM = "slstm"  # xLSTM sLSTM block (scalar memory, strictly recurrent)
MLSTM = "mlstm"  # xLSTM mLSTM block (matrix memory, parallelizable)

FFN_DENSE = "dense"
FFN_MOE = "moe"
FFN_NONE = "none"


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0  # routed experts
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_jitter: float = 0.0


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 => direct q projection (DeepSeek-V2-Lite)
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 => ceil(d_model / 16)
    chunk: int = 64  # remat chunk for the selective scan


@dataclass(frozen=True)
class XLSTMConfig:
    # mLSTM: matrix-memory heads, projection factor for the up projection.
    mlstm_proj_factor: float = 2.0
    # sLSTM: post-block gated FFN factor (xLSTM paper uses 4/3 * d).
    slstm_ffn_factor: float = 4.0 / 3.0
    conv_width: int = 4
    chunk: int = 64


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // n_heads
    # --- block layout -----------------------------------------------------
    # Repeating pattern of (mixer, ffn) kinds. The pattern tiles over
    # n_layers - first_k_dense; the first first_k_dense layers are unrolled
    # (attn + dense FFN), DeepSeek style.
    pattern: tuple[tuple[str, str], ...] = ((ATTN, FFN_DENSE),)
    first_k_dense: int = 0
    first_k_dense_d_ff: int = 0
    # --- attention ---------------------------------------------------------
    qkv_bias: bool = False
    sliding_window: int | None = None
    rope: str = "rope"  # rope | mrope | none
    rope_theta: float = 10_000.0
    attn_logit_softcap: float = 0.0
    # --- sub-configs --------------------------------------------------------
    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: MLAConfig | None = None
    ssm: SSMConfig = field(default_factory=SSMConfig)
    xlstm: XLSTMConfig = field(default_factory=XLSTMConfig)
    # --- io ------------------------------------------------------------------
    # "tokens": int32 token ids; "embeds": precomputed frontend embeddings
    # (audio codec frames / vision patches) — the one allowed stub.
    input_kind: str = "tokens"
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # t,h,w splits of head_dim/2
    # --- misc ----------------------------------------------------------------
    mlp_variant: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # Sub-quadratic decode path exists (SSM / hybrid / sliding window)?
    subquadratic: bool = False
    # citation for the config numbers
    source: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def layer_pattern(self) -> tuple[tuple[str, str], ...]:
        """Full per-layer (mixer, ffn) list, prefix + tiled pattern."""
        body = self.n_layers - self.first_k_dense
        p = len(self.pattern)
        if body % p != 0:
            raise ValueError(f"{self.name}: pattern period {p} !| {body}")
        prefix = ((ATTN, FFN_DENSE),) * self.first_k_dense
        return prefix + self.pattern * (body // p)

    @property
    def n_groups(self) -> int:
        return (self.n_layers - self.first_k_dense) // len(self.pattern)

    def reduced(self) -> "ModelConfig":
        """CPU smoke variant of the same family: 2 pattern periods,
        d_model<=512, <=4 experts, short rope."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        moe = self.moe
        if moe.n_experts:
            moe = replace(
                moe,
                n_experts=min(4, moe.n_experts),
                top_k=min(2, moe.top_k),
                n_shared_experts=min(1, moe.n_shared_experts),
                d_ff_expert=min(128, moe.d_ff_expert),
            )
        mla = self.mla
        if mla is not None:
            mla = replace(
                mla,
                kv_lora_rank=64,
                rope_head_dim=16,
                nope_head_dim=32,
                v_head_dim=32,
                q_lora_rank=(32 if mla.q_lora_rank else 0),
            )
        # compress long patterns (e.g. jamba's 8-layer period) to the unique
        # (mixer, ffn) combos so the smoke variant stays <=4 layers while
        # still exercising every block kind of the family
        pattern = tuple(dict.fromkeys(self.pattern))[:4]
        n_layers = self.first_k_dense + len(pattern) * max(1, 2 // len(pattern))
        return replace(
            self,
            name=self.name + "-smoke",
            pattern=pattern,
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            first_k_dense_d_ff=min(self.first_k_dense_d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            head_dim=(d_model // n_heads),
            sliding_window=(64 if self.sliding_window else None),
            moe=moe,
            mla=mla,
            ssm=replace(self.ssm, d_state=8, chunk=16),
            mrope_sections=tuple(
                s * (d_model // n_heads) // self.resolved_head_dim or 1
                for s in self.mrope_sections
            ),
            dtype="float32",
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).reduced()
    return _REGISTRY[name]


def list_configs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False

ASSIGNED = (
    "h2o-danube-3-4b",
    "jamba-1.5-large-398b",
    "xlstm-125m",
    "musicgen-medium",
    "qwen2.5-14b",
    "moonshot-v1-16b-a3b",
    "deepseek-v2-lite-16b",
    "qwen3-moe-235b-a22b",
    "starcoder2-15b",
    "qwen2-vl-2b",
)


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    import importlib

    for mod in (
        "h2o_danube3",
        "jamba15_large",
        "xlstm125m",
        "musicgen_medium",
        "qwen25_14b",
        "moonshot_16b",
        "deepseek_v2_lite",
        "qwen3_moe_235b",
        "starcoder2_15b",
        "qwen2_vl_2b",
        "adfll_dqn",
    ):
        importlib.import_module(f"repro.configs.{mod}")


def param_count(cfg: ModelConfig) -> tuple[int, int]:
    """(total params, active params per token) — analytic, for roofline."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    total = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    active = total
    for mixer, ffn in cfg.layer_pattern:
        if mixer == ATTN:
            m = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
        elif mixer == MLA:
            a = cfg.mla
            q_dim = a.nope_head_dim + a.rope_head_dim
            m = (
                d * (a.q_lora_rank or 0)
                + (a.q_lora_rank or d) * cfg.n_heads * q_dim
                + d * (a.kv_lora_rank + a.rope_head_dim)
                + a.kv_lora_rank * cfg.n_heads * (a.nope_head_dim + a.v_head_dim)
                + cfg.n_heads * a.v_head_dim * d
            )
        elif mixer == MAMBA:
            di = cfg.ssm.expand * d
            dtr = cfg.ssm.dt_rank or -(-d // 16)
            m = (
                d * 2 * di
                + di * cfg.ssm.d_conv
                + di * (dtr + 2 * cfg.ssm.d_state)
                + dtr * di
                + di * cfg.ssm.d_state
                + di
                + di * d
            )
        elif mixer == MLSTM:
            di = int(cfg.xlstm.mlstm_proj_factor * d)
            m = d * 2 * di + di * cfg.xlstm.conv_width + 3 * di * di + 3 * di + di * d
        elif mixer == SLSTM:
            dff = int(cfg.xlstm.slstm_ffn_factor * d)
            m = 4 * d * d + 4 * d + 2 * d * dff
        else:
            raise ValueError(mixer)
        total += m
        active += m
        if ffn == FFN_DENSE:
            f = 3 * d * cfg.d_ff if cfg.mlp_variant == "swiglu" else 2 * d * cfg.d_ff
            total += f
            active += f
        elif ffn == FFN_MOE:
            fe = 3 * d * cfg.moe.d_ff_expert
            total += (
                fe * (cfg.moe.n_experts + cfg.moe.n_shared_experts)
                + d * cfg.moe.n_experts
            )
            active += (
                fe * (cfg.moe.top_k + cfg.moe.n_shared_experts)
                + d * cfg.moe.n_experts
            )
    return total, active
