"""h2o-danube3-4b — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]"""

from repro.configs.base import ATTN, FFN_DENSE, ModelConfig, register

register(
    ModelConfig(
        name="h2o-danube-3-4b",
        family="dense",
        n_layers=24,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        head_dim=120,
        d_ff=10240,
        vocab_size=32000,
        pattern=((ATTN, FFN_DENSE),),
        sliding_window=4096,  # mistral-style SWA => sub-quadratic decode
        subquadratic=True,
        rope="rope",
        rope_theta=10_000.0,
        source="arXiv:2401.16818 (H2O-Danube); SWA per mistral lineage",
    )
)
