"""DeepSeek-V2-Lite (16B, 2.4B active) — MLA attention (kv_lora_rank=512) +
fine-grained MoE: 2 shared + 64 routed top-6, first layer dense.
[arXiv:2405.04434]"""

from repro.configs.base import FFN_MOE, MLA, MLAConfig, ModelConfig, MoEConfig, register

register(
    ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,  # MLA: all heads read the shared latent
        head_dim=128,
        d_ff=10944,  # dense FFN width (first layer)
        vocab_size=102400,
        pattern=((MLA, FFN_MOE),),
        first_k_dense=1,
        first_k_dense_d_ff=10944,
        moe=MoEConfig(n_experts=64, top_k=6, n_shared_experts=2, d_ff_expert=1408),
        mla=MLAConfig(
            kv_lora_rank=512,
            q_lora_rank=0,
            rope_head_dim=64,
            nope_head_dim=128,
            v_head_dim=128,
        ),
        rope="rope",
        rope_theta=10_000.0,
        source="arXiv:2405.04434 (DeepSeek-V2-Lite)",
    )
)
