"""Qwen2-VL-2B — VLM language decoder with M-RoPE (multimodal rotary) and
dynamic-resolution vision. [arXiv:2409.12191]

Vision frontend (ViT + merger) is STUBBED per assignment: ``input_specs``
feeds precomputed patch+token embeddings [B, S, d_model] plus 3D (t,h,w)
M-RoPE position ids [3, B, S].
"""

from repro.configs.base import ATTN, FFN_DENSE, ModelConfig, register

register(
    ModelConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151936,
        pattern=((ATTN, FFN_DENSE),),
        input_kind="embeds",
        qkv_bias=True,
        rope="mrope",
        mrope_sections=(16, 24, 24),  # t,h,w split of head_dim/2 = 64
        rope_theta=1_000_000.0,
        source="arXiv:2409.12191 (Qwen2-VL-2B)",
    )
)
