"""MusicGen-medium — decoder-only transformer over EnCodec tokens.
[arXiv:2306.05284]

Modality frontend (EnCodec + codebook interleave) is STUBBED per assignment:
``input_specs`` feeds precomputed frame embeddings of shape [B, S, d_model];
the LM head predicts the 2048-entry codebook.
"""

from repro.configs.base import ATTN, FFN_DENSE, ModelConfig, register

register(
    ModelConfig(
        name="musicgen-medium",
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,  # MHA
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        pattern=((ATTN, FFN_DENSE),),
        input_kind="embeds",
        mlp_variant="gelu",
        norm="layernorm",
        rope="none",  # musicgen uses learned/sinusoidal pos; stubbed
        source="arXiv:2306.05284 (MusicGen medium, 1.5B decoder)",
    )
)
