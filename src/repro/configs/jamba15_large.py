"""Jamba-1.5-Large (398B) — hybrid Mamba + attention 7:1 interleave with MoE.
[arXiv:2403.19887] (Jamba) / Jamba-1.5 model card.

One attention layer per 8-layer Jamba block; MoE FFN every other layer
(16 experts, top-2), dense FFN otherwise.
"""

from repro.configs.base import (
    ATTN,
    FFN_DENSE,
    FFN_MOE,
    MAMBA,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    register,
)

# 8-layer Jamba block: mamba x3, attn at index 3 (paper places the attention
# layer mid-block), mamba x4; MoE on every other FFN.
_PATTERN = (
    (MAMBA, FFN_MOE),
    (MAMBA, FFN_DENSE),
    (MAMBA, FFN_MOE),
    (ATTN, FFN_DENSE),
    (MAMBA, FFN_MOE),
    (MAMBA, FFN_DENSE),
    (MAMBA, FFN_MOE),
    (MAMBA, FFN_DENSE),
)

register(
    ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        pattern=_PATTERN,
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
        subquadratic=True,  # mamba state + 1/8 attn layers
        rope="none",  # jamba uses no positional encoding
        source="arXiv:2403.19887; ai21labs/AI21-Jamba-1.5-Large",
    )
)
