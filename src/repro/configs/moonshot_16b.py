"""Moonlight-16B-A3B (moonshot-v1-16b-a3b) — DeepSeek-V3-style MoE:
64 routed experts top-6 + 2 shared. [hf:moonshotai/Moonlight-16B-A3B]"""

from repro.configs.base import ATTN, FFN_MOE, ModelConfig, MoEConfig, register

register(
    ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="dense",  # assignment tag; architecture is MoE
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,  # MHA per assignment (GQA kv=16)
        head_dim=128,
        d_ff=11264,  # dense FFN width of the first-k-dense prefix
        vocab_size=163840,
        pattern=((ATTN, FFN_MOE),),
        first_k_dense=1,
        first_k_dense_d_ff=11264,
        moe=MoEConfig(n_experts=64, top_k=6, n_shared_experts=2, d_ff_expert=1408),
        rope="rope",
        rope_theta=50_000.0,
        source="hf:moonshotai/Moonlight-16B-A3B",
    )
)
