"""Qwen3-235B-A22B — 94-layer MoE, 128 experts top-8.
[hf:Qwen/Qwen3-235B-A22B via Qwen3-30B-A3B assignment]"""

from repro.configs.base import ATTN, FFN_MOE, ModelConfig, MoEConfig, register

register(
    ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        head_dim=128,
        d_ff=1536,  # expert width (qwen3-moe has no dense FFN)
        vocab_size=151936,
        pattern=((ATTN, FFN_MOE),),
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
        rope="rope",
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen3-235B-A22B",
    )
)
