"""StarCoder2-15B — dense GQA decoder, RoPE, layernorm + gelu MLP with bias.
[arXiv:2402.19173]"""

from repro.configs.base import ATTN, FFN_DENSE, ModelConfig, register

register(
    ModelConfig(
        name="starcoder2-15b",
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        pattern=((ATTN, FFN_DENSE),),
        mlp_variant="gelu",
        norm="layernorm",
        qkv_bias=True,
        rope="rope",
        rope_theta=100_000.0,
        source="arXiv:2402.19173 (StarCoder2-15B)",
    )
)
