"""xLSTM-125M — alternating mLSTM / sLSTM blocks. [arXiv:2405.04517]

d_ff=0: xLSTM blocks carry their own projections (mLSTM pre-up-projection
x2, sLSTM post gated FFN x4/3).
"""

from repro.configs.base import (
    FFN_NONE,
    MLSTM,
    SLSTM,
    ModelConfig,
    XLSTMConfig,
    register,
)

register(
    ModelConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        head_dim=192,
        d_ff=0,
        vocab_size=50304,
        pattern=((MLSTM, FFN_NONE), (SLSTM, FFN_NONE)),
        xlstm=XLSTMConfig(mlstm_proj_factor=2.0, slstm_ffn_factor=4.0 / 3.0),
        subquadratic=True,  # recurrent state, O(1) decode
        rope="none",
        source="arXiv:2405.04517 (xLSTM), 125M scale",
    )
)
