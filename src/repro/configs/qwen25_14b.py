"""Qwen2.5-14B — dense GQA decoder with QKV bias. [hf:Qwen/Qwen2.5-14B]"""

from repro.configs.base import ATTN, FFN_DENSE, ModelConfig, register

register(
    ModelConfig(
        name="qwen2.5-14b",
        family="dense",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=13824,
        vocab_size=152064,
        pattern=((ATTN, FFN_DENSE),),
        qkv_bias=True,
        rope="rope",
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen2.5-14B (family card via Qwen2.5-0.5B assignment)",
    )
)
