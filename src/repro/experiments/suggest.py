"""Near-match suggestions for CLI name lookups.

Shared by the ``repro.experiments`` and ``repro.sweeps`` CLIs: an
unknown ``--scenario``/``--sweep`` name exits nonzero with the closest
registered names instead of a raw ``KeyError`` traceback.
"""

from __future__ import annotations

import difflib
from collections.abc import Iterable


def close_matches(name: str, known: Iterable[str], *, n: int = 3) -> list[str]:
    """The registered names closest to ``name`` (possibly empty)."""
    known = sorted(known)
    matches = difflib.get_close_matches(name, known, n=n, cutoff=0.5)
    if not matches:  # fall back to prefix/substring hits
        matches = [k for k in known if name in k or k.startswith(name[:3])][:n]
    return matches


def unknown_name_message(kind: str, name: str, known: Iterable[str]) -> str:
    """One-line diagnostic: what was unknown, what was probably meant."""
    matches = close_matches(name, known)
    hint = (
        "did you mean: " + ", ".join(matches) + "?"
        if matches
        else "see --list for registered names"
    )
    return f"unknown {kind} {name!r}; {hint}"


__all__ = ["close_matches", "unknown_name_message"]
