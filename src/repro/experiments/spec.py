"""Frozen, declarative scenario descriptions.

A :class:`ScenarioSpec` captures *everything* an experiment needs —
system kind, task set, patient pool, the full
:class:`~repro.configs.adfll_dqn.ADFLLConfig` (topology, share planes,
compression, speeds, hub layout), a churn schedule, per-link
heterogeneous rates (site assignments + intra/inter links), and the
evaluation protocol — so a benchmark is a registry lookup plus
reporting, never bespoke wiring.

``spec.seed`` is the single source of truth for randomness: the runner
mirrors it into ``sys.seed`` before construction, and every stream in
the system derives from it.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.core.experiment import ChurnEvent, HubFailure
from repro.core.gossip import LinkModel
from repro.population.spec import PopulationSpec
from repro.serve.traffic import TrafficSpec

SYSTEMS = ("adfll", "fedavg", "all_knowing", "partial", "sequential", "serve")
TASK_SETS = ("paper8", "all")


@dataclass(frozen=True)
class ScenarioSpec:
    """One named, fully declarative experiment."""

    name: str
    system: str = "adfll"  # one of SYSTEMS
    description: str = ""
    # -- problem -----------------------------------------------------------
    task_set: str = "paper8"  # "paper8" (deployment suite) | "all" (24 envs)
    n_tasks: int | None = None  # truncate the training task list
    n_patients: int = 40  # patient pool size (80:20 split)
    dqn: DQNConfig = field(default_factory=DQNConfig)
    sys: ADFLLConfig = field(default_factory=ADFLLConfig)
    seed: int = 0
    # -- scenario dynamics -------------------------------------------------
    churn: tuple[ChurnEvent, ...] = ()  # timed add/remove events
    hub_failures: tuple[HubFailure, ...] = ()  # timed hub deaths (Table 2)
    population: PopulationSpec | None = None  # declarative fleet dynamics
    agent_sites: tuple[int, ...] = ()  # per-agent site ids (hetero links)
    hub_sites: tuple[int, ...] = ()  # per-hub site ids
    intra_link: LinkModel | None = None  # fast same-site link
    inter_link: LinkModel | None = None  # slow cross-site link
    serve_traffic: TrafficSpec | None = None  # system="serve" workload
    # -- evaluation --------------------------------------------------------
    eval_tasks: int | None = None  # eval on first N tasks (None = all)
    eval_patients: int | None = 4  # held-out patients per task
    eval_episodes: int = 4  # greedy rollouts per patient
    eval_at_churn: bool = True  # probe the error at each churn event
    # -- fast (CI) variant -------------------------------------------------
    fast_train_steps: int = 10
    fast_eval_tasks: int | None = None
    fast_population_scale: float = 1.0  # shrink cohorts for CI (1.0 = full)

    def __post_init__(self):
        if self.system not in SYSTEMS:
            raise ValueError(f"unknown system: {self.system!r}")
        if self.task_set not in TASK_SETS:
            raise ValueError(f"unknown task_set: {self.task_set!r}")
        if self.agent_sites and (self.intra_link is None and self.inter_link is None):
            raise ValueError("agent_sites given without intra/inter links")
        if self.hub_failures and self.sys.topology == "gossip":
            raise ValueError("hub_failures given but topology='gossip' has no hubs")
        if self.serve_traffic is not None and self.system != "serve":
            raise ValueError(
                f"serve_traffic given but system={self.system!r} is not 'serve'"
            )
        if self.population is not None:
            if self.system != "adfll":
                raise ValueError(
                    f"population given but system={self.system!r} is not 'adfll'"
                )
            if self.churn or self.hub_failures:
                raise ValueError(
                    "population and churn/hub_failures are exclusive: express "
                    "everything in the PopulationSpec (see PopulationSpec.from_churn)"
                )
            if not self.population.cohorts:
                raise ValueError("scenario population has no cohorts (no agents)")
            if self.population.hub_outages and self.sys.topology == "gossip":
                raise ValueError("hub_outages given but topology='gossip' has no hubs")
        if not 0.0 < self.fast_population_scale <= 1.0:
            raise ValueError(
                f"fast_population_scale not in (0, 1]: {self.fast_population_scale}"
            )

    # -- derived variants --------------------------------------------------
    def with_seed(self, seed: int) -> "ScenarioSpec":
        """Re-seed the whole scenario (spec and system config stay in
        lockstep — there is exactly one seed)."""
        return replace(self, seed=seed, sys=replace(self.sys, seed=seed))

    def fast(self) -> "ScenarioSpec":
        """The CI-sized variant: fewer train steps, optionally fewer
        evaluation tasks and a shrunken population; everything else
        identical."""
        steps = min(self.sys.train_steps_per_round, self.fast_train_steps)
        eval_tasks = (
            self.fast_eval_tasks
            if self.fast_eval_tasks is not None
            else self.eval_tasks
        )
        pop = self.population
        if pop is not None:
            pop = pop.scaled(self.fast_population_scale)
        return replace(
            self,
            sys=replace(self.sys, train_steps_per_round=steps),
            eval_tasks=eval_tasks,
            population=pop,
        )


__all__ = ["SYSTEMS", "TASK_SETS", "ScenarioSpec"]
