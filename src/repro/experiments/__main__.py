"""CLI for the scenario registry.

    PYTHONPATH=src python -m repro.experiments --list
    PYTHONPATH=src python -m repro.experiments --scenario paper_fig2 [--fast]
    PYTHONPATH=src python -m repro.experiments \
        --scenario churn_addition_fig4 --scenario gossip_hetero \
        --fast --json BENCH_experiments.json

``--json`` writes the ``check_regression``-compatible shape (one
``configs`` entry per scenario), so CI can gate scenario runs exactly
like the classic benchmarks.
"""

from __future__ import annotations

import argparse
import sys
from dataclasses import replace

from repro.experiments.registry import get_scenario, list_scenarios
from repro.experiments.runner import resolve, run, write_json
from repro.experiments.suggest import unknown_name_message


def _per_scenario(path: str | None, name: str, n_scenarios: int) -> str | None:
    """Insert the scenario name before the extension for multi-scenario runs."""
    if path is None or n_scenarios <= 1:
        return path
    stem, dot, ext = path.rpartition(".")
    return f"{stem}.{name}.{ext}" if dot else f"{path}.{name}"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.experiments")
    ap.add_argument(
        "--list", action="store_true", help="list registered scenarios and exit"
    )
    ap.add_argument(
        "--scenario",
        action="append",
        default=[],
        metavar="NAME",
        help="scenario to run (repeatable)",
    )
    ap.add_argument(
        "--fast", action="store_true", help="reduced step counts (CI sanity)"
    )
    ap.add_argument(
        "--seed", type=int, default=None, help="override the scenario seed"
    )
    ap.add_argument(
        "--engine",
        choices=("fleet", "fleet-eager", "stepwise"),
        default=None,
        help="override the ADFLL execution engine (default: the scenario's)",
    )
    ap.add_argument(
        "--devices",
        type=int,
        default=None,
        metavar="N",
        help=(
            "shard the fleet's agent axis across a device mesh of up to N "
            "local devices (-1 = all; rounded down to a power of two). "
            "Per-slot math is bitwise invariant to the mesh, so reports "
            "match single-device runs. On CPU combine with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N."
        ),
    )
    ap.add_argument(
        "--mesh",
        action="store_true",
        help="shorthand for --devices -1 (every local device)",
    )
    ap.add_argument(
        "--json",
        type=str,
        default=None,
        metavar="OUT",
        help="write results as JSON (BENCH_*.json for CI gating)",
    )
    ap.add_argument(
        "--trace",
        type=str,
        default=None,
        metavar="PATH",
        help=(
            "capture a telemetry trace per scenario (Perfetto JSON; .jsonl "
            "for the flat format). With several --scenario flags the "
            "scenario name is inserted before the extension."
        ),
    )
    ap.add_argument(
        "--dashboard",
        type=str,
        default=None,
        metavar="PATH",
        help=(
            "render the run's observatory dashboard (self-contained HTML) "
            "per scenario; multi-scenario name insertion as for --trace"
        ),
    )
    args = ap.parse_args(argv)

    if args.list or not args.scenario:
        print(f"{'scenario':<24} {'system':<12} description")
        for spec in list_scenarios():
            print(f"{spec.name:<24} {spec.system:<12} {spec.description}")
        return 0

    known = [s.name for s in list_scenarios()]
    for name in args.scenario:
        try:
            get_scenario(name)
        except KeyError:
            print(unknown_name_message("scenario", name, known), file=sys.stderr)
            return 2

    reports = []
    for name in args.scenario:
        spec = resolve(name, fast=args.fast, seed=args.seed)
        if args.engine is not None:
            spec = replace(spec, sys=replace(spec.sys, engine=args.engine))
        devices = -1 if args.mesh and args.devices is None else args.devices
        if devices is not None:
            spec = replace(spec, sys=replace(spec.sys, fleet_devices=devices))
        trace_path = _per_scenario(args.trace, name, len(args.scenario))
        dashboard_path = _per_scenario(args.dashboard, name, len(args.scenario))
        report = run(spec, trace_path=trace_path, dashboard_path=dashboard_path)
        reports.append(report)
        if trace_path is not None:
            print(f"wrote trace {trace_path}")
        if dashboard_path is not None:
            print(f"wrote dashboard {dashboard_path}")
        curve = " -> ".join(
            f"{p.mean_err:.2f}@{p.t:.1f}(n={p.n_agents})" for p in report.eval_curve
        )
        print(
            f"{report.scenario},mean_dist_err={report.mean_dist_err:.3f},"
            f"best_agent_err={report.best_agent_err:.3f},"
            f"sim_makespan={report.makespan:.2f},n_rounds={report.n_rounds},"
            f"total_bytes={report.total_bytes}"
        )
        print(f"derived,{report.scenario},eval_curve={curve}")
    if args.json:
        write_json(args.json, reports, fast=args.fast)
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
