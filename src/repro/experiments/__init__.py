"""Declarative scenario/experiment API.

One ``System`` protocol, frozen ``ScenarioSpec`` descriptions, a named
registry, and a runner — every benchmark is scenario selection plus
reporting:

    from repro import experiments
    report = experiments.run("paper_fig2", fast=True)
    print(report.mean_dist_err, report.makespan)

or from the shell:

    python -m repro.experiments --list
    python -m repro.experiments --scenario gossip_hetero --fast
"""

from repro.core.experiment import (  # noqa: F401
    ChurnEvent,
    CommLog,
    EvalPoint,
    ExperimentHooks,
    HistoryRecorder,
    HubFailure,
    Report,
    RoundRecord,
)
from repro.core.gossip import LinkModel, SiteLinks  # noqa: F401
from repro.experiments.protocol import SupportsChurn, System  # noqa: F401
from repro.experiments.registry import (  # noqa: F401
    get_scenario,
    list_scenarios,
    register,
)
from repro.experiments.runner import build, resolve, run, write_json  # noqa: F401
from repro.experiments.spec import ScenarioSpec  # noqa: F401
from repro.experiments.systems import BaselineSystem  # noqa: F401
from repro.population import (  # noqa: F401
    Cohort,
    Departure,
    Diurnal,
    HubOutage,
    PopulationSpec,
    Sessions,
    Trace,
)
