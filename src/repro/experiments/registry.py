"""Named scenario registry: every benchmark is a registry entry.

Scenarios are frozen :class:`~repro.experiments.spec.ScenarioSpec`
values keyed by name.  The built-ins cover the paper's experiments
(``paper_fig2`` + the Table-1 baseline rows, the Fig. 4/5 churn
ablations as declarative churn schedules) and the beyond-paper ones
(sharing-plane and topology ablations, synchronous FedAvg, and the
heterogeneous-link gossip scenario from the ROADMAP).  Adding a future
experiment means registering a spec — not writing a new script.
"""

from __future__ import annotations

from dataclasses import replace

from repro.configs.adfll_dqn import ADFLLConfig, DQNConfig
from repro.core.experiment import ChurnEvent, HubFailure
from repro.core.gossip import LinkModel
from repro.experiments.spec import ScenarioSpec
from repro.population import Cohort, Diurnal, PopulationSpec, Sessions
from repro.serve.traffic import TrafficSpec

_REGISTRY: dict[str, ScenarioSpec] = {}


def register(spec: ScenarioSpec) -> ScenarioSpec:
    """Add a scenario (rejects silent overwrites)."""
    if spec.name in _REGISTRY:
        raise ValueError(f"scenario already registered: {spec.name!r}")
    _REGISTRY[spec.name] = spec
    return spec


def get_scenario(name: str) -> ScenarioSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown scenario {name!r}; registered: {known}") from None


def list_scenarios() -> list[ScenarioSpec]:
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


# ---------------------------------------------------------------------------
# built-in scenarios
# ---------------------------------------------------------------------------

# Table 1 / Fig 3 deployment scale (CPU-tractable).
_DEPLOY_DQN = DQNConfig(
    volume_shape=(20, 20, 20),
    box_size=(8, 8, 8),
    conv_features=(4, 8),
    hidden=(64,),
    max_episode_steps=24,
    batch_size=32,
    eps_decay_steps=300,
    target_update=40,
)
_DEPLOY_SYS = ADFLLConfig(
    rounds=3,
    train_steps_per_round=80,
    erb_capacity=2048,
    erb_share_size=256,
    hub_sync_period=0.2,
)

# Fig 4/5 churn-ablation scale.
_CHURN_DQN = DQNConfig(
    volume_shape=(16, 16, 16),
    box_size=(6, 6, 6),
    conv_features=(4, 8),
    hidden=(48,),
    max_episode_steps=16,
    batch_size=24,
    eps_decay_steps=200,
)

# Plane/topology-ablation scale (CI-sized).
_TINY_DQN = DQNConfig(
    volume_shape=(16, 16, 16),
    box_size=(6, 6, 6),
    conv_features=(4,),
    hidden=(32,),
    max_episode_steps=12,
    batch_size=16,
    eps_decay_steps=100,
)


def _ablation_sys(**overrides) -> ADFLLConfig:
    base = dict(
        rounds=2,
        train_steps_per_round=30,
        erb_capacity=512,
        erb_share_size=64,
        hub_sync_period=0.25,
        gossip_period=0.25,
        mix_alpha=0.6,
        staleness_flag="poly",
        staleness_poly_a=0.5,
    )
    base.update(overrides)
    return ADFLLConfig(**base)


# a priced link (4 MiB per sim-unit) for the topology rows
_PRICED = dict(link_latency=0.002, link_rate=float(2**22))

register(
    ScenarioSpec(
        name="paper_fig2",
        system="adfll",
        description="Table 1 / Fig 3 deployment: 4 async agents, 3 hubs, "
        "heterogeneous V100/T4 speeds, 8 task-environments",
        dqn=_DEPLOY_DQN,
        sys=_DEPLOY_SYS,
        n_patients=40,
        fast_train_steps=20,
    )
)

register(
    ScenarioSpec(
        name="baseline_all_knowing",
        system="all_knowing",
        description="Agent X: all datasets at once, one round over the union",
        dqn=_DEPLOY_DQN,
        sys=_DEPLOY_SYS,
        seed=100,
        n_patients=40,
        fast_train_steps=20,
    )
)

register(
    ScenarioSpec(
        name="baseline_partial",
        system="partial",
        description="Agent Y: a single dataset, a single round",
        dqn=_DEPLOY_DQN,
        sys=_DEPLOY_SYS,
        seed=200,
        n_patients=40,
        fast_train_steps=20,
    )
)

register(
    ScenarioSpec(
        name="baseline_sequential",
        system="sequential",
        description="Agent M: sequential lifelong learner, personal replay only",
        dqn=_DEPLOY_DQN,
        sys=_DEPLOY_SYS,
        seed=300,
        n_patients=40,
        fast_train_steps=20,
    )
)

register(
    ScenarioSpec(
        name="fedavg_sync",
        system="fedavg",
        description="Conventional synchronous FedAvg over DQN weights "
        "(central server, global barrier)",
        task_set="paper8",
        n_tasks=4,
        n_patients=16,
        dqn=_TINY_DQN,
        sys=_ablation_sys(n_agents=3, train_steps_per_round=40),
        seed=400,
        fast_train_steps=8,
    )
)

register(
    ScenarioSpec(
        name="churn_addition_fig4",
        system="adfll",
        description="Fig 4: agents join 4 -> 8 -> 12 -> 16 under 75% "
        "dropout; late joiners catch up from the hub database",
        task_set="all",
        n_patients=40,
        dqn=_CHURN_DQN,
        sys=ADFLLConfig(
            n_agents=4,
            n_hubs=3,
            agent_hub=(),
            agent_speed=(),
            rounds=4,
            dropout=0.75,
            train_steps_per_round=40,
            erb_capacity=1024,
            erb_share_size=128,
            hub_sync_period=0.5,
        ),
        churn=(
            ChurnEvent(at=1.6, action="add", count=4),
            ChurnEvent(at=3.2, action="add", count=4),
            ChurnEvent(at=4.8, action="add", count=4),
        ),
        eval_tasks=8,
        fast_eval_tasks=4,
        fast_train_steps=15,
    )
)

register(
    ScenarioSpec(
        name="churn_deletion_fig5",
        system="adfll",
        description="Fig 5: agents leave 24 -> 12 -> 6 -> 3 -> 1 under 75% "
        "dropout; knowledge survives in the hub database",
        task_set="all",
        n_patients=40,
        dqn=_CHURN_DQN,
        sys=ADFLLConfig(
            n_agents=24,
            n_hubs=3,
            agent_hub=(),
            agent_speed=(),
            rounds=5,
            dropout=0.75,
            train_steps_per_round=30,
            erb_capacity=1024,
            erb_share_size=128,
            hub_sync_period=0.5,
        ),
        churn=(
            ChurnEvent(at=1.8, action="remove", count=12),
            ChurnEvent(at=3.6, action="remove", count=6),
            ChurnEvent(at=5.4, action="remove", count=3),
            ChurnEvent(at=7.2, action="remove", count=2),
        ),
        eval_tasks=8,
        fast_eval_tasks=4,
        fast_train_steps=12,
    )
)

register(
    ScenarioSpec(
        name="gossip_hetero",
        system="adfll",
        description="Hub-less gossip over two sites with per-link "
        "heterogeneous rates: fast intra-site, slow cross-site",
        task_set="paper8",
        n_tasks=4,
        n_patients=16,
        dqn=_TINY_DQN,
        sys=_ablation_sys(
            n_agents=6,
            agent_hub=(),
            agent_speed=(1.0, 1.0, 2.5, 1.0, 1.0, 2.5),
            topology="gossip",
            gossip_sampler="random",
            gossip_fanout=2,
            share_planes=("erb", "weights"),
            **_PRICED,
        ),
        agent_sites=(0, 0, 0, 1, 1, 1),
        intra_link=LinkModel(latency=0.0005, rate=float(2**24)),
        inter_link=LinkModel(latency=0.01, rate=float(2**20)),
        fast_train_steps=10,
    )
)

# -- Table 2: hub failure mid-training --------------------------------------
# Round durations are simulated (independent of train_steps), so t=1.5
# is mid-training in both the full and the --fast variants.
register(
    ScenarioSpec(
        name="paper_table2_hub_failure",
        system="adfll",
        description="Table 2: hub 3 (serving two agents) dies mid-training; "
        "orphans re-home to the surviving hubs, whose databases retain "
        "the shared knowledge",
        dqn=_DEPLOY_DQN,
        sys=_DEPLOY_SYS,
        n_patients=40,
        seed=500,
        hub_failures=(HubFailure(at=1.5, hub_id=2),),
        fast_train_steps=20,
    )
)

register(
    ScenarioSpec(
        name="paper_table2_total_failure",
        system="adfll",
        description="Table 2 (worst case): every hub dies mid-training; "
        "pure-hub agents lose all sharing and finish on local data alone",
        dqn=_DEPLOY_DQN,
        sys=_DEPLOY_SYS,
        n_patients=40,
        seed=510,
        hub_failures=(
            HubFailure(at=1.5, hub_id=0),
            HubFailure(at=1.5, hub_id=1),
            HubFailure(at=1.5, hub_id=2),
        ),
        fast_train_steps=20,
    )
)

register(
    ScenarioSpec(
        name="paper_table2_hybrid_failover",
        system="adfll",
        description="Table 2 failover: every hub dies mid-training but the "
        "hybrid topology keeps replicating both planes peer-to-peer",
        dqn=_DEPLOY_DQN,
        sys=replace(
            _DEPLOY_SYS,
            topology="hybrid",
            gossip_sampler="random",
            gossip_fanout=2,
            gossip_period=0.25,
        ),
        n_patients=40,
        seed=520,
        hub_failures=(
            HubFailure(at=1.5, hub_id=0),
            HubFailure(at=1.5, hub_id=1),
            HubFailure(at=1.5, hub_id=2),
        ),
        fast_train_steps=20,
    )
)

# -- online inference plane: train-while-serve session ----------------------
register(
    ScenarioSpec(
        name="serve_localization",
        system="serve",
        description="Online inference plane: continuous-batching "
        "localization serving with a mid-session param hot swap "
        "(train-while-serve)",
        task_set="paper8",
        n_tasks=4,
        n_patients=16,
        dqn=_TINY_DQN,
        sys=_ablation_sys(n_agents=2, rounds=2, train_steps_per_round=20),
        serve_traffic=TrafficSpec(
            n_requests=32, max_batch=8, n_version_slots=2, max_staleness=1
        ),
        seed=600,
        eval_patients=2,
        eval_episodes=2,
        fast_train_steps=8,
    )
)

# -- population dynamics (trace-driven fleet simulation) --------------------
register(
    ScenarioSpec(
        name="hospital_diurnal",
        system="adfll",
        description="Hospital-network diurnal load: two sites of gossiping "
        "hospitals on opposite day/night shifts; availability-aware "
        "anti-entropy only reaches the site that is awake",
        task_set="paper8",
        n_tasks=4,
        n_patients=16,
        dqn=_TINY_DQN,
        sys=_ablation_sys(
            rounds=3,
            topology="gossip",
            gossip_sampler="random",
            gossip_fanout=2,
        ),
        population=PopulationSpec(
            cohorts=(
                Cohort(
                    name="site_a",
                    n_agents=3,
                    availability=Diurnal(
                        period=2.0, on_fraction=0.6, phase=0.0, jitter=0.1
                    ),
                ),
                Cohort(
                    name="site_b",
                    n_agents=3,
                    availability=Diurnal(
                        period=2.0, on_fraction=0.6, phase=1.0, jitter=0.1
                    ),
                ),
            ),
        ),
        seed=700,
        eval_patients=2,
        eval_episodes=2,
        fast_train_steps=8,
    )
)

register(
    ScenarioSpec(
        name="flash_crowd",
        system="adfll",
        description="Flash-crowd onboarding: 4 incumbent agents, then 200 "
        "more join over a staggered mid-run wave and catch up from the "
        "hub databases",
        task_set="paper8",
        n_tasks=4,
        n_patients=16,
        dqn=_TINY_DQN,
        sys=_ablation_sys(rounds=2, n_hubs=3),
        population=PopulationSpec(
            cohorts=(
                Cohort(name="incumbents", n_agents=4),
                Cohort(
                    name="crowd",
                    n_agents=200,
                    arrive_at=1.0,
                    arrive_spread=1.5,
                ),
            ),
        ),
        seed=710,
        eval_patients=2,
        eval_episodes=2,
        fast_train_steps=8,
        fast_population_scale=0.1,  # 4 + 200 agents -> 1 + 20 in CI
    )
)

register(
    ScenarioSpec(
        name="long_tail_stragglers",
        system="adfll",
        description="Long-tail stragglers: one cohort with a lognormal "
        "step-time tail (some machines far slower than the median) and "
        "heavy-tailed connectivity sessions",
        task_set="paper8",
        n_tasks=4,
        n_patients=16,
        dqn=_TINY_DQN,
        sys=_ablation_sys(rounds=3),
        population=PopulationSpec(
            cohorts=(
                Cohort(
                    name="fleet",
                    n_agents=8,
                    speed_sigma=0.75,
                    availability=Sessions(
                        mean_on=1.5,
                        mean_off=0.5,
                        distribution="lognormal",
                        sigma=1.0,
                    ),
                ),
            ),
        ),
        seed=720,
        eval_patients=2,
        eval_episodes=2,
        fast_train_steps=8,
    )
)

# -- sharing-plane ablation (ERB vs weights vs hybrid) ----------------------
for _plane_name, _planes in (
    ("plane_erb_only", ("erb",)),
    ("plane_weight_only", ("weights",)),
    ("plane_hybrid", ("erb", "weights")),
):
    register(
        ScenarioSpec(
            name=_plane_name,
            system="adfll",
            description=f"Sharing-plane ablation row: share_planes={_planes}",
            task_set="paper8",
            n_tasks=4,
            n_patients=16,
            dqn=_TINY_DQN,
            sys=_ablation_sys(share_planes=_planes),
            fast_train_steps=10,
        )
    )

# -- topology ablation (hub vs gossip vs hybrid, + compressed weights) ------
for _topo_name, _topo_overrides in (
    ("topo_hub", dict(topology="hub")),
    (
        "topo_gossip",
        dict(topology="gossip", gossip_sampler="random", gossip_fanout=2),
    ),
    (
        "topo_hybrid",
        dict(topology="hybrid", gossip_sampler="random", gossip_fanout=2),
    ),
    (
        "topo_gossip_topk",
        dict(
            topology="gossip",
            gossip_sampler="random",
            gossip_fanout=2,
            weight_compression="topk",
            weight_topk_frac=0.05,
        ),
    ),
):
    register(
        ScenarioSpec(
            name=_topo_name,
            system="adfll",
            description=f"Topology ablation row over a priced link: {_topo_name}",
            task_set="paper8",
            n_tasks=4,
            n_patients=16,
            dqn=_TINY_DQN,
            sys=_ablation_sys(
                share_planes=("erb", "weights"), **_PRICED, **_topo_overrides
            ),
            fast_train_steps=10,
        )
    )


__all__ = ["get_scenario", "list_scenarios", "register"]
