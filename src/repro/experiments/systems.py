"""Baseline trainers wrapped as single-agent ``System`` implementations.

The paper's Table-1 comparison rows — Agent X (all-knowing), Agent Y
(partially-knowing), Agent M (sequential lifelong) — are plain training
functions in :mod:`repro.core.federated`.  :class:`BaselineSystem` lifts
each into the :class:`~repro.experiments.protocol.System` protocol so
the runner (and the deployment benchmark) drives them exactly like
``ADFLLSystem`` and ``CentralAggregationSystem``.

:class:`ServeSystem` does the same for the online inference plane
(:mod:`repro.serve`): its ``run()`` is a train-while-serve session over
synthetic traffic, and its ``evaluate()`` answers queries *through the
continuous-batching service* instead of a local rollout loop — so the
scenario gates the serving path itself.
"""

from __future__ import annotations

from collections.abc import Sequence

import numpy as np

from repro.configs.adfll_dqn import DQNConfig
from repro.core.erb import TaskTag
from repro.core.experiment import Report
from repro.core.federated import (
    evaluate_on_tasks,
    train_all_knowing,
    train_partial,
    train_sequential_ll,
)
from repro.serve.queue import ServeRequest
from repro.serve.traffic import TrafficSpec
from repro.telemetry import Telemetry

_LABELS = {
    "all_knowing": "AgentX",
    "partial": "AgentY",
    "sequential": "AgentM",
}


class BaselineSystem:
    """Agent X / Y / M as a single-agent system.

    ``kind`` selects the trainer; ``steps`` is the per-task (X), total
    (Y), or per-round (M) step budget — matching the historical
    benchmark wiring, all three consume the scenario's
    ``train_steps_per_round``.
    """

    def __init__(
        self,
        kind: str,
        dqn_cfg: DQNConfig,
        tasks: Sequence[TaskTag],
        patients: Sequence[int],
        *,
        steps: int = 150,
        erb_capacity: int = 2048,
        seed: int = 0,
    ):
        if kind not in _LABELS:
            raise ValueError(f"unknown baseline kind: {kind!r}")
        self.kind = kind
        self.label = _LABELS[kind]
        self.dqn_cfg = dqn_cfg
        self.tasks = list(tasks)
        self.patients = list(patients)
        self.steps = steps
        self.erb_capacity = erb_capacity
        self.seed = seed
        self.agent = None

    def run(self) -> Report:
        if self.kind == "all_knowing":
            self.agent = train_all_knowing(
                self.dqn_cfg,
                self.tasks,
                self.patients,
                steps_per_task=self.steps,
                erb_capacity=self.erb_capacity,
                seed=self.seed,
            )
            n_rounds = 1
        elif self.kind == "partial":
            self.agent = train_partial(
                self.dqn_cfg,
                self.tasks[0],
                self.patients,
                steps=self.steps,
                erb_capacity=self.erb_capacity,
                seed=self.seed,
            )
            n_rounds = 1
        else:
            self.agent = train_sequential_ll(
                self.dqn_cfg,
                self.tasks,
                self.patients,
                steps_per_round=self.steps,
                erb_capacity=self.erb_capacity,
                seed=self.seed,
            )
            n_rounds = len(self.tasks)
        return Report(system=self.kind, seed=self.seed, n_rounds=n_rounds)

    def evaluate(
        self,
        tasks: Sequence[TaskTag],
        patients: Sequence[int],
        *,
        max_patients: int | None = 4,
        n_episodes: int = 4,
    ) -> dict[str, dict[str, float]]:
        if self.agent is None:
            raise RuntimeError("evaluate() before run(): the agent is untrained")
        return {
            self.label: evaluate_on_tasks(
                self.agent,
                tasks,
                patients,
                self.dqn_cfg,
                max_patients=max_patients,
                n_episodes=n_episodes,
            )
        }


class ServeSystem:
    """The online inference plane as a scenario system.

    ``run()`` builds a fleet + publisher + service session and drives
    traffic waves interleaved with train+publish rounds (every session
    exercises a hot swap); the serve-side metrics land in
    ``Report.extra["serve"]``.  ``evaluate()`` routes held-out queries
    through the *same* continuous-batching service, so the scenario's
    ``mean_dist_err`` measures served accuracy, not offline rollouts.
    """

    label = "Serve"

    def __init__(
        self,
        dqn_cfg: DQNConfig,
        tasks: Sequence[TaskTag],
        patients: Sequence[int],
        *,
        traffic: TrafficSpec | None = None,
        n_agents: int = 2,
        n_waves: int = 2,
        train_steps: int = 20,
        seed: int = 0,
        telemetry: Telemetry | None = None,
    ):
        self.dqn_cfg = dqn_cfg
        self.tasks = list(tasks)
        self.patients = list(patients)
        self.traffic = traffic if traffic is not None else TrafficSpec()
        self.n_agents = n_agents
        self.n_waves = n_waves
        self.train_steps = train_steps
        self.seed = seed
        self.telemetry = telemetry
        self.session = None

    def run(self) -> Report:
        from repro.serve.driver import build_session, run_session

        self.session = build_session(
            self.dqn_cfg,
            n_agents=self.n_agents,
            traffic=self.traffic,
            seed=self.seed,
            tasks=self.tasks,
            patients=self.patients,
            telemetry=self.telemetry,
        )
        serve_report = run_session(
            self.session,
            self.traffic,
            n_waves=self.n_waves,
            train_steps=self.train_steps,
        )
        report = Report(
            system="serve",
            seed=self.seed,
            n_rounds=(self.n_waves - 1) * self.n_agents,
        )
        # snapshot now: evaluate() keeps serving through the same
        # service, which would otherwise mutate these counters
        report.extra["serve"] = serve_report.summary()
        if self.telemetry is not None and self.telemetry.enabled:
            report.extra["telemetry"] = self.telemetry.summary()
        return report

    def evaluate(
        self,
        tasks: Sequence[TaskTag],
        patients: Sequence[int],
        *,
        max_patients: int | None = 4,
        n_episodes: int = 4,
    ) -> dict[str, dict[str, float]]:
        if self.session is None:
            raise RuntimeError("evaluate() before run(): no live service")
        from repro.rl.synth import make_volume

        service = self.session.service
        n = self.dqn_cfg.volume_shape[0]
        rng = np.random.default_rng(self.seed + 1)
        lo, hi = n // 4, 3 * n // 4
        errs: dict[str, float] = {}
        for task in tasks:
            pats = list(patients)[: max_patients or None]
            requests = []
            for patient in pats:
                vol, lm = make_volume(task, patient, n=n)
                for _ in range(n_episodes):
                    requests.append(
                        ServeRequest(
                            volume=vol,
                            start=rng.integers(lo, hi, size=3).astype(np.int32),
                            agent_id=int(rng.integers(0, self.n_agents)),
                            landmark=lm,
                        )
                    )
            ids = [service.submit(r) for r in requests]
            service.drain()
            errs[task.name] = float(
                np.mean([service.results[i].dist_err for i in ids])
            )
        return {self.label: errs}


__all__ = ["BaselineSystem", "ServeSystem"]
