"""Baseline trainers wrapped as single-agent ``System`` implementations.

The paper's Table-1 comparison rows — Agent X (all-knowing), Agent Y
(partially-knowing), Agent M (sequential lifelong) — are plain training
functions in :mod:`repro.core.federated`.  :class:`BaselineSystem` lifts
each into the :class:`~repro.experiments.protocol.System` protocol so
the runner (and the deployment benchmark) drives them exactly like
``ADFLLSystem`` and ``CentralAggregationSystem``.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence

from repro.configs.adfll_dqn import DQNConfig
from repro.core.erb import TaskTag
from repro.core.experiment import Report
from repro.core.federated import (
    evaluate_on_tasks,
    train_all_knowing,
    train_partial,
    train_sequential_ll,
)

_LABELS = {
    "all_knowing": "AgentX",
    "partial": "AgentY",
    "sequential": "AgentM",
}


class BaselineSystem:
    """Agent X / Y / M as a single-agent system.

    ``kind`` selects the trainer; ``steps`` is the per-task (X), total
    (Y), or per-round (M) step budget — matching the historical
    benchmark wiring, all three consume the scenario's
    ``train_steps_per_round``.
    """

    def __init__(
        self,
        kind: str,
        dqn_cfg: DQNConfig,
        tasks: Sequence[TaskTag],
        patients: Sequence[int],
        *,
        steps: int = 150,
        erb_capacity: int = 2048,
        seed: int = 0,
    ):
        if kind not in _LABELS:
            raise ValueError(f"unknown baseline kind: {kind!r}")
        self.kind = kind
        self.label = _LABELS[kind]
        self.dqn_cfg = dqn_cfg
        self.tasks = list(tasks)
        self.patients = list(patients)
        self.steps = steps
        self.erb_capacity = erb_capacity
        self.seed = seed
        self.agent = None

    def run(self) -> Report:
        if self.kind == "all_knowing":
            self.agent = train_all_knowing(
                self.dqn_cfg,
                self.tasks,
                self.patients,
                steps_per_task=self.steps,
                erb_capacity=self.erb_capacity,
                seed=self.seed,
            )
            n_rounds = 1
        elif self.kind == "partial":
            self.agent = train_partial(
                self.dqn_cfg,
                self.tasks[0],
                self.patients,
                steps=self.steps,
                erb_capacity=self.erb_capacity,
                seed=self.seed,
            )
            n_rounds = 1
        else:
            self.agent = train_sequential_ll(
                self.dqn_cfg,
                self.tasks,
                self.patients,
                steps_per_round=self.steps,
                erb_capacity=self.erb_capacity,
                seed=self.seed,
            )
            n_rounds = len(self.tasks)
        return Report(system=self.kind, seed=self.seed, n_rounds=n_rounds)

    def evaluate(
        self,
        tasks: Sequence[TaskTag],
        patients: Sequence[int],
        *,
        max_patients: Optional[int] = 4,
        n_episodes: int = 4,
    ) -> Dict[str, Dict[str, float]]:
        if self.agent is None:
            raise RuntimeError("evaluate() before run(): the agent is untrained")
        return {
            self.label: evaluate_on_tasks(
                self.agent,
                tasks,
                patients,
                self.dqn_cfg,
                max_patients=max_patients,
                n_episodes=n_episodes,
            )
        }


__all__ = ["BaselineSystem"]
