"""Build and execute scenarios: ``run(spec) -> Report``.

The runner is the only place a system is ever constructed from a
scenario: it resolves the named spec, mirrors ``spec.seed`` into the
system config (one seed, every stream derived), wires churn schedules,
per-link heterogeneous rates, and evaluation probes through the
lifecycle-hook machinery, runs the system, and folds the final
evaluation into the :class:`~repro.core.experiment.Report`.
"""

from __future__ import annotations

import json
from collections.abc import Sequence
from dataclasses import dataclass, replace

import numpy as np

from repro.core.experiment import EvalPoint, ExperimentHooks, Report
from repro.core.federated import ADFLLSystem, CentralAggregationSystem
from repro.experiments.protocol import SupportsChurn, System
from repro.experiments.registry import get_scenario
from repro.experiments.spec import ScenarioSpec
from repro.experiments.systems import BaselineSystem, ServeSystem
from repro.rl.synth import all_tasks, paper_eight_tasks, patient_split
from repro.telemetry import Telemetry, write_dashboard, write_trace

SpecLike = str | ScenarioSpec


def resolve(
    spec: SpecLike, *, fast: bool = False, seed: int | None = None
) -> ScenarioSpec:
    """Name -> registered spec, plus the seed/fast variants."""
    if isinstance(spec, str):
        spec = get_scenario(spec)
    if seed is not None:
        spec = spec.with_seed(seed)
    if fast:
        spec = spec.fast()
    return spec


@dataclass
class _Built:
    spec: ScenarioSpec
    system: System
    tasks: list
    eval_tasks: list
    train_patients: list
    test_patients: list
    curve: list[EvalPoint]


def _tasks_for(spec: ScenarioSpec) -> list:
    tasks = list(paper_eight_tasks() if spec.task_set == "paper8" else all_tasks())
    if spec.n_tasks is not None:
        tasks = tasks[: spec.n_tasks]
    return tasks


def _build(
    spec: ScenarioSpec,
    hooks: Sequence[ExperimentHooks],
    telemetry: Telemetry | None = None,
) -> _Built:
    tasks = _tasks_for(spec)
    eval_tasks = tasks if spec.eval_tasks is None else tasks[: spec.eval_tasks]
    train_p, test_p = patient_split(spec.n_patients)
    sys_cfg = replace(spec.sys, seed=spec.seed)  # one seed, every stream
    curve: list[EvalPoint] = []

    if spec.system == "adfll":
        if spec.population is not None:
            # every agent arrives through a cohort (arrive_at=0 cohorts
            # are the incumbents): the system starts empty
            sys_cfg = replace(sys_cfg, n_agents=0, agent_hub=(), agent_speed=())
        system: System = ADFLLSystem(
            sys_cfg, spec.dqn, tasks, train_p, hooks=tuple(hooks), telemetry=telemetry
        )
        if spec.agent_sites:
            system.network.configure_sites(
                dict(enumerate(spec.agent_sites)),
                hub_site=dict(enumerate(spec.hub_sites)),
                intra=spec.intra_link,
                inter=spec.inter_link,
            )
        if spec.churn or spec.hub_failures or spec.population is not None:
            _schedule_probes(system, spec, eval_tasks, test_p, curve)
        if spec.churn:
            assert isinstance(system, SupportsChurn)
            system.schedule_churn(spec.churn)
        if spec.hub_failures:
            system.schedule_hub_failures(spec.hub_failures)
        if spec.population is not None:
            system.apply_population(spec.population)
    elif spec.system == "fedavg":
        if spec.churn or spec.agent_sites or spec.hub_failures:
            raise ValueError(
                f"{spec.name}: {spec.system} supports no churn/sites/hub failures"
            )
        system = CentralAggregationSystem(
            sys_cfg.n_agents,
            spec.dqn,
            tasks,
            train_p,
            rounds=sys_cfg.rounds,
            steps=sys_cfg.train_steps_per_round,
            erb_capacity=sys_cfg.erb_capacity,
            seed=spec.seed,
        )
    elif spec.system == "serve":
        if spec.churn or spec.agent_sites or spec.hub_failures:
            raise ValueError(
                f"{spec.name}: {spec.system} supports no churn/sites/hub failures"
            )
        system = ServeSystem(
            spec.dqn,
            tasks,
            train_p,
            traffic=spec.serve_traffic,
            n_agents=sys_cfg.n_agents,
            n_waves=max(2, sys_cfg.rounds),  # >= one hot swap per session
            train_steps=sys_cfg.train_steps_per_round,
            seed=spec.seed,
            telemetry=telemetry,
        )
    else:  # single-agent baselines
        if spec.churn or spec.agent_sites or spec.hub_failures:
            raise ValueError(
                f"{spec.name}: {spec.system} supports no churn/sites/hub failures"
            )
        system = BaselineSystem(
            spec.system,
            spec.dqn,
            tasks,
            train_p,
            steps=sys_cfg.train_steps_per_round,
            erb_capacity=sys_cfg.erb_capacity,
            seed=spec.seed,
        )
    return _Built(spec, system, tasks, eval_tasks, train_p, test_p, curve)


def _schedule_probes(
    system: ADFLLSystem,
    spec: ScenarioSpec,
    eval_tasks: list,
    test_patients: list,
    curve: list[EvalPoint],
) -> None:
    """Evaluation probes at each churn/hub-failure time (before the
    event applies: scheduler ties break by insertion order, and these
    are registered first), feeding the report's forgetting/recovery
    curve."""
    if not spec.eval_at_churn:
        return

    def probe(sched, t: float) -> None:
        point = _eval_point(system, spec, eval_tasks, test_patients, t)
        curve.append(point)
        system._emit("on_eval", point)

    times = {ev.at for ev in spec.churn} | {ev.at for ev in spec.hub_failures}
    if spec.population is not None:
        # probe at each membership event; t=0 is just the incumbents
        # arriving — there is nothing to evaluate before them
        times |= {t for t in spec.population.event_times() if t > 0.0}
    for at in sorted(times):
        system.sched.at(at, probe, tag="eval_probe")


def _eval_point(
    system: System,
    spec: ScenarioSpec,
    eval_tasks: list,
    test_patients: list,
    t: float,
) -> EvalPoint:
    errors = system.evaluate(
        eval_tasks,
        test_patients,
        max_patients=spec.eval_patients,
        n_episodes=spec.eval_episodes,
    )
    per_agent = {
        label: float(np.mean(list(errs.values()))) for label, errs in errors.items()
    }
    mean = float(np.mean(list(per_agent.values()))) if per_agent else float("nan")
    return EvalPoint(t=t, n_agents=len(per_agent), mean_err=mean, per_agent=per_agent)


def build(
    spec: SpecLike,
    *,
    fast: bool = False,
    seed: int | None = None,
    hooks: Sequence[ExperimentHooks] = (),
) -> System:
    """Construct (but do not run) the system a scenario describes."""
    return _build(resolve(spec, fast=fast, seed=seed), hooks).system


def run(
    spec: SpecLike,
    *,
    fast: bool = False,
    seed: int | None = None,
    hooks: Sequence[ExperimentHooks] = (),
    json_path: str | None = None,
    trace_path: str | None = None,
    dashboard_path: str | None = None,
    telemetry: Telemetry | None = None,
) -> Report:
    """Execute one scenario end to end and return its :class:`Report`.

    ``trace_path`` captures the run's telemetry (Perfetto JSON, or JSONL
    when the suffix is ``.jsonl``) — any scenario becomes traceable
    without code changes.  ``dashboard_path`` renders the same telemetry
    (plus the observatory's learning / propagation / health series) into
    a self-contained HTML page.  Telemetry is observe-only: with or
    without it the run's numbers are bit-identical.
    """
    rspec = resolve(spec, fast=fast, seed=seed)
    if telemetry is None and (trace_path is not None or dashboard_path is not None):
        telemetry = Telemetry(enabled=True)
    b = _build(rspec, hooks, telemetry)
    report = b.system.run()
    report.scenario = rspec.name
    report.seed = rspec.seed
    report.task_errors = b.system.evaluate(
        b.eval_tasks,
        b.test_patients,
        max_patients=rspec.eval_patients,
        n_episodes=rspec.eval_episodes,
    )
    means = report.agent_means()
    vals = list(means.values())  # empty if churn removed every agent
    report.mean_dist_err = float(np.mean(vals)) if vals else float("nan")
    report.best_agent_err = float(np.min(vals)) if vals else float("nan")
    report.eval_patients = rspec.eval_patients
    report.eval_episodes = rspec.eval_episodes
    final = EvalPoint(
        t=report.makespan,
        n_agents=len(means),
        mean_err=report.mean_dist_err,
        per_agent=means,
    )
    report.eval_curve = [*b.curve, final]
    if trace_path is not None and telemetry is not None:
        # after evaluate(): serve scenarios keep emitting through it
        write_trace(telemetry, trace_path)
    if dashboard_path is not None and telemetry is not None:
        trace = {
            "events": list(telemetry.tracer.events),
            "metrics": telemetry.registry.summary(),
        }
        write_dashboard(
            dashboard_path, trace, title=f"Fleet observatory — {rspec.name}"
        )
    if json_path:
        write_json(json_path, [report], fast=fast)
    return report


def write_json(path: str, reports: Sequence[Report], *, fast: bool = False) -> None:
    """One ``BENCH_*.json`` in the shape ``check_regression`` gates on."""
    payload = {
        "benchmark": "experiments",
        "fast": bool(fast),
        "configs": {r.scenario: r.summary() for r in reports},
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)


__all__ = ["build", "resolve", "run", "write_json"]
