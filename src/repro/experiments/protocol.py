"""The ``System`` protocol: one interface for every learning system.

``ADFLLSystem``, ``CentralAggregationSystem``, and the Table-1 baseline
trainers (wrapped as single-agent systems in
:mod:`repro.experiments.systems`) all conform structurally — no
inheritance required:

* ``run() -> Report`` executes the system to completion and returns the
  run-side accounting (:class:`~repro.core.experiment.Report`).
* ``evaluate(tasks, patients, ...)`` maps agent labels to per-task mean
  terminal distance errors.

Systems with dynamic membership additionally satisfy
:class:`SupportsChurn` (``add_agent`` / ``remove_agent`` /
``schedule_churn``); the runner checks for it before wiring a scenario's
churn schedule.
"""

from __future__ import annotations

from collections.abc import Sequence
from typing import Protocol, runtime_checkable

from repro.core.experiment import ChurnEvent, Report


@runtime_checkable
class System(Protocol):
    """What every experiment system exposes to the runner."""

    def run(self) -> Report: ...

    def evaluate(
        self,
        tasks: Sequence,
        patients: Sequence[int],
        *,
        max_patients: int | None = 4,
        n_episodes: int = 4,
    ) -> dict[str, dict[str, float]]: ...


@runtime_checkable
class SupportsChurn(Protocol):
    """Systems whose membership can change while they run."""

    def add_agent(
        self,
        *,
        speed: float = 1.0,
        hub_id: int | None = None,
        at: float | None = None,
    ) -> int: ...

    def remove_agent(self, agent_id: int) -> None: ...

    def schedule_churn(self, events: Sequence[ChurnEvent]) -> None: ...


__all__ = ["SupportsChurn", "System"]
