"""jit'd public wrapper: [B,S,H,D] layout -> kernel layout and back."""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@partial(
    jax.jit,
    static_argnames=(
        "causal",
        "window",
        "scale",
        "softcap",
        "block_q",
        "block_k",
        "interpret",
    ),
)
def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window=None,
    scale=None,
    softcap: float = 0.0,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = True,
):
    """q [B,S,Hq,D], k/v [B,S,Hkv,D*] -> [B,S,Hq,Dv]."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    dv = v.shape[-1]

    # pad S to a block multiple (padded kv rows are causally masked; padded
    # q rows are sliced away) — keeps the kernel free of tail masking.
    pad = (-s) % math.lcm(min(block_q, s), min(block_k, s))
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, padw)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
    sp = s + pad

    def to_bhsd(x):
        return jnp.moveaxis(x, 2, 1).reshape(b * x.shape[2], sp, x.shape[-1])

    # interleave kv heads so q head h maps to kv head h // g within a batch
    out = flash_attention_bhsd(
        to_bhsd(q),
        to_bhsd(k),
        to_bhsd(v),
        causal=causal,
        window=window,
        scale=scale,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
    )
    return jnp.moveaxis(out.reshape(b, hq, sp, dv), 1, 2)[:, :s]
