"""Pure-jnp oracle for the flash attention kernel (naive full-matrix)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def attention_ref(
    q, k, v, *, causal: bool = True, window=None, scale=None, softcap: float = 0.0
):
    """q [B,S,Hq,D], k/v [B,S,Hkv,D*] -> [B,S,Hq,Dv]. Materializes SxS."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = d**-0.5
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(F32), kr.astype(F32))
    scores = scores * scale
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(F32))
    return out.astype(q.dtype)
