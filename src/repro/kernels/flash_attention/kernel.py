"""FlashAttention-2 style fused attention — Pallas TPU kernel.

Grid: (batch*q_heads, n_q_blocks, n_kv_blocks); the kv dimension is the
innermost (sequential) grid axis, so the online-softmax state lives in VMEM
scratch across kv steps. GQA is expressed in the k/v BlockSpec index maps
(query head h reads kv head h // group_size) — no kv replication in HBM.

Sliding-window and causal masking are applied with block-level iota; fully
masked blocks short-circuit via ``pl.when`` (on real TPU the MXU work is
skipped; under interpret=True it is merely branch-masked).

VMEM budget per step: q/k/v blocks (block_q + 2 block_k) x head_dim plus
(block_q x head_dim) f32 accumulator — callers pick block sizes so this
stays within ~16 MB (ops.py defaults: 256/512 x 128).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,  # refs
    m_scr,
    l_scr,
    acc_scr,  # scratch
    *,
    scale: float,
    causal: bool,
    window,
    softcap: float,
    block_q: int,
    block_k: int,
    n_kv: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # block is fully masked iff every k position is after every q position
    # (causal) or before the window of every q position.
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window is not None:
        run = jnp.logical_and(run, q_start - (k_start + block_k - 1) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(F32)  # [bq, d]
        k = k_ref[0].astype(F32)  # [bk, d]
        v = v_ref[0].astype(F32)  # [bk, dv]
        s = (
            jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32
            )
            * scale
        )
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + p.sum(-1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=F32
        )

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l_sum = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l_sum).astype(o_ref.dtype)


def flash_attention_bhsd(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window=None,
    scale=None,
    softcap: float = 0.0,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = True,
):
    """q [BH, S, D], k/v [BH_kv, S, D*] (BH = BH_kv * group). -> [BH, S, Dv]."""
    bh, s, d = q.shape
    bh_kv = k.shape[0]
    dv = v.shape[-1]
    g = bh // bh_kv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    nq = math.ceil(s / block_q)
    nk = math.ceil(s / block_k)
    if scale is None:
        scale = d**-0.5

    kern = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        n_kv=nk,
    )
    return pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j, g=g: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, dv), lambda b, i, j, g=g: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), F32),
            pltpu.VMEM((block_q, 1), F32),
            pltpu.VMEM((block_q, dv), F32),
        ],
        interpret=interpret,
    )(q, k, v)
