"""Fused RMSNorm — Pallas TPU kernel.

Every zoo block enters through an RMSNorm; fusing the mean-square
reduction, rsqrt and scale into one VMEM pass removes two HBM round trips
of the [*, d_model] activation. Grid over row blocks; the full feature dim
stays resident in VMEM (d_model <= 8192 -> <=4 MB f32 per block row set).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(F32)  # [bb, d]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * scale_ref[...].astype(F32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(
    x, scale, *, eps: float = 1e-5, block_rows: int = 128, interpret: bool = True
):
    """x [N, d], scale [d] -> [N, d]."""
    n, d = x.shape
    block_rows = min(block_rows, n)
    while n % block_rows:
        block_rows -= 1
    nb = n // block_rows
    kern = functools.partial(_kernel, eps=eps)
    return pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, scale)
