"""jit'd wrapper: accepts [..., d] and flattens leading dims."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm as _kernel


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x, scale, *, eps: float = 1e-5, block_rows: int = 128, interpret: bool = True
):
    lead = x.shape[:-1]
    y = _kernel(
        x.reshape(-1, x.shape[-1]),
        scale,
        eps=eps,
        block_rows=block_rows,
        interpret=interpret,
    )
    return y.reshape(*lead, x.shape[-1])
