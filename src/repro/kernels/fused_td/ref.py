"""Pure-jnp oracle for fused_td."""

from __future__ import annotations

import jax.numpy as jnp


def fused_td_ref(q_sel, q_next, reward, done, *, gamma: float):
    best = jnp.max(q_next.astype(jnp.float32), -1, keepdims=True)
    target = reward + gamma * (1.0 - done) * best
    delta = q_sel.astype(jnp.float32) - target
    absd = jnp.abs(delta)
    loss = jnp.where(absd <= 1.0, 0.5 * delta * delta, absd - 0.5)
    dq = jnp.clip(delta, -1.0, 1.0)
    return loss, dq
