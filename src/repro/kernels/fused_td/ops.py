"""jit'd wrapper with custom_vjp so the fused dq drives the DQN backward."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_td.kernel import fused_td as _kernel
from repro.kernels.fused_td.ref import fused_td_ref


@partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def td_loss(q_sel, q_next, reward, done, gamma: float = 0.99, use_pallas: bool = True):
    """Mean Huber TD loss. Differentiable in q_sel (target is stopped)."""
    loss, _ = (
        _kernel(q_sel, q_next, reward, done, gamma=gamma)
        if use_pallas
        else fused_td_ref(q_sel, q_next, reward, done, gamma=gamma)
    )
    return jnp.mean(loss)


def _fwd(q_sel, q_next, reward, done, gamma, use_pallas):
    loss, dq = (
        _kernel(q_sel, q_next, reward, done, gamma=gamma)
        if use_pallas
        else fused_td_ref(q_sel, q_next, reward, done, gamma=gamma)
    )
    return jnp.mean(loss), (dq, q_sel.shape[0])


def _bwd(gamma, use_pallas, res, g):
    dq, b = res
    return (g * dq / b, None, None, None)


td_loss.defvjp(_fwd, _bwd)
