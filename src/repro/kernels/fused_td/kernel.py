"""Fused TD-target + Huber loss — Pallas TPU kernel.

The elementwise tail of the DQN update, fused into a single VMEM pass:

    target = r + gamma * (1 - done) * max_a Q'(s', a)    [target net]
    delta  = Q(s, a_sel) - stop_grad(target)
    loss   = 0.5 delta^2            if |delta| <= 1
             |delta| - 0.5          otherwise
    dq     = dloss/dQ(s, a_sel) = clip(delta, -1, 1)

Returns (loss, dq) per sample; the caller wires dq into the Q-network
backward pass (custom_vjp in ops.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(qsel_ref, qnext_ref, r_ref, done_ref, loss_ref, dq_ref, *, gamma: float):
    qnext = qnext_ref[...]  # [bb, A]
    best = jnp.max(qnext, axis=-1, keepdims=True)  # [bb, 1]
    r = r_ref[...]
    done = done_ref[...]
    target = r + gamma * (1.0 - done) * best
    delta = qsel_ref[...] - target
    absd = jnp.abs(delta)
    loss_ref[...] = jnp.where(absd <= 1.0, 0.5 * delta * delta, absd - 0.5)
    dq_ref[...] = jnp.clip(delta, -1.0, 1.0)


def fused_td(
    q_sel,
    q_next,
    reward,
    done,
    *,
    gamma: float,
    block_b: int = 128,
    interpret: bool = True,
):
    """q_sel [B,1], q_next [B,A], reward [B,1], done [B,1] ->
    (loss [B,1], dq [B,1])."""
    b, a = q_next.shape
    block_b = min(block_b, b)
    nb = b // block_b
    assert nb * block_b == b, (b, block_b)
    kern = functools.partial(_kernel, gamma=gamma)
    return pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, a), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, 1), F32),
            jax.ShapeDtypeStruct((b, 1), F32),
        ],
        interpret=interpret,
    )(q_sel.astype(F32), q_next.astype(F32), reward.astype(F32), done.astype(F32))
