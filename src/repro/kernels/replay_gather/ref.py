"""Pure-jnp oracle for replay_gather."""

from __future__ import annotations


def replay_gather_ref(buffer, indices, weights):
    return buffer[indices] * weights.astype(buffer.dtype)[:, None]
