"""ERB batched gather — Pallas TPU kernel.

The ADFLL sampling hot path: gather a minibatch of experience rows from an
HBM-resident replay buffer by precomputed indices, scaling each row by its
(renormalized) importance weight. On TPU this is bandwidth-bound; the
idiomatic formulation is a ``PrefetchScalarGridSpec`` — the index vector is
scalar-prefetched so the BlockSpec index_map can route each grid step's HBM
-> VMEM copy straight to the requested buffer row (no gather op in the
kernel body at all; the DMA engine does the work).

Grid: one step per (row-block); each step copies ``block_rows`` buffer rows
into VMEM, applies the weight, and writes the output block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(idx_ref, w_ref, buf_ref, out_ref):
    # buf_ref block: [1, feat] — the row selected by the index_map.
    i = pl.program_id(0)
    out_ref[0, :] = buf_ref[0, :] * w_ref[i]


def replay_gather(buffer, indices, weights, *, interpret: bool = True):
    """buffer [cap, feat], indices [batch] int32, weights [batch] f32
    -> [batch, feat] (buffer rows scaled by weights)."""
    cap, feat = buffer.shape
    batch = indices.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # indices, weights
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, feat), lambda i, idx_ref, w_ref: (idx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, feat), lambda i, idx_ref, w_ref: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch, feat), buffer.dtype),
        interpret=interpret,
    )(indices, weights.astype(buffer.dtype), buffer)
