"""jit'd wrapper for the ERB gather kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.replay_gather.kernel import replay_gather as _kernel


@partial(jax.jit, static_argnames=("interpret",))
def replay_gather(buffer, indices, weights, *, interpret: bool = True):
    """Gather + weight replay rows: buffer [cap,F], indices [B], weights [B]
    -> [B, F]."""
    return _kernel(buffer, indices, weights, interpret=interpret)
