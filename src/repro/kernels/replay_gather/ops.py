"""jit'd wrapper for the ERB gather kernel.

``mode`` selects the lowering:

* ``"interpret"`` — the Pallas kernel under the Pallas interpreter
  (default; kernel-correctness tests and debugging. The interpreter is a
  per-grid-step simulator — orders of magnitude slower than XLA's native
  gather, never use it on a hot path).
* ``"compiled"`` — the Pallas kernel compiled for the backend (TPU).
* ``"ref"`` — the pure-XLA oracle (`replay_gather_ref`), bit-identical
  output.
* ``"auto"`` — what hot paths (the fleet engine's device-resident batch
  materialization) should pass: the compiled kernel on TPU, the XLA
  oracle everywhere else.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.replay_gather.kernel import replay_gather as _kernel
from repro.kernels.replay_gather.ref import replay_gather_ref


@partial(jax.jit, static_argnames=("mode",))
def replay_gather(buffer, indices, weights, *, mode: str = "interpret"):
    """Gather + weight replay rows: buffer [cap,F], indices [B], weights [B]
    -> [B, F]."""
    if mode == "auto":
        mode = "compiled" if jax.default_backend() == "tpu" else "ref"
    if mode == "ref":
        return replay_gather_ref(buffer, indices, weights)
    if mode not in ("interpret", "compiled"):
        raise ValueError(f"unknown replay_gather mode: {mode!r}")
    return _kernel(buffer, indices, weights, interpret=mode == "interpret")
