from repro.checkpoint.ckpt import restore_pytree, save_pytree  # noqa: F401
