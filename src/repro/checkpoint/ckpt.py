"""Pytree checkpointing: flattened-path .npz, no external deps.

Keys encode the tree path; restore rebuilds against a reference structure
(so dtype/shape drift fails loudly rather than silently).
"""

from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np

_SEP = "|"


def _flatten(tree) -> dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))
    # atomicity: np.savez appends .npz if missing; normalize
    if not path.endswith(".npz") and os.path.exists(path + ".npz"):
        os.replace(path + ".npz", path)


def restore_pytree(path: str, like: Any) -> Any:
    data = np.load(path)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, ref in flat_like:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        arr = data[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {ref.shape}")
        leaves.append(arr.astype(ref.dtype))
    struct = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(struct, leaves)
