from repro.testing.hypothesis_fallback import (given, install,  # noqa: F401
                                               settings)
