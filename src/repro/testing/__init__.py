from repro.testing.hypothesis_fallback import given, install, settings  # noqa: F401
