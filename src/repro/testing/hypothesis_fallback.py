"""A tiny, dependency-free stand-in for the ``hypothesis`` API we use.

The tier-1 suite property-tests the ADFLL safety claims with hypothesis.
Real hypothesis (shrinking, coverage-guided generation, the database) is
strictly better and is declared in the dev requirements — but hermetic
environments without it must still be able to *collect and run* the
suite.  ``tests/conftest.py`` calls :func:`install` only when the real
package is missing, registering this module under ``sys.modules
['hypothesis']`` before any test module imports it.

Only the surface the suite uses is implemented:

* ``@given(**kwargs)`` with keyword strategies
* ``@settings(max_examples=..., deadline=...)`` (either decorator order)
* ``strategies.integers / floats / lists / sampled_from / booleans``

Generation is deterministic: example ``i`` draws from ``random.Random``
seeded with ``i``, and the first examples probe interval endpoints, so
failures reproduce exactly across runs (no shrinking, but the seed index
is reported in the failure message).
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
from collections.abc import Callable, Sequence
from typing import Any

_DEFAULT_MAX_EXAMPLES = 50


class SearchStrategy:
    """Base strategy: ``example(rng, i)`` draws the i-th example."""

    def example(self, rng: random.Random, i: int) -> Any:
        raise NotImplementedError

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return _Mapped(self, fn)


class _Mapped(SearchStrategy):
    def __init__(self, base: SearchStrategy, fn: Callable[[Any], Any]):
        self.base, self.fn = base, fn

    def example(self, rng: random.Random, i: int) -> Any:
        return self.fn(self.base.example(rng, i))


class _Integers(SearchStrategy):
    def __init__(self, min_value: int, max_value: int):
        self.lo, self.hi = int(min_value), int(max_value)

    def example(self, rng: random.Random, i: int) -> int:
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _Floats(SearchStrategy):
    def __init__(self, min_value: float, max_value: float):
        self.lo, self.hi = float(min_value), float(max_value)

    def example(self, rng: random.Random, i: int) -> float:
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return rng.uniform(self.lo, self.hi)


class _Lists(SearchStrategy):
    def __init__(
        self, elements: SearchStrategy, min_size: int = 0, max_size: int = 10
    ):
        self.elements = elements
        self.min_size, self.max_size = int(min_size), int(max_size)

    def example(self, rng: random.Random, i: int) -> list[Any]:
        n = self.min_size if i == 0 else rng.randint(self.min_size, self.max_size)
        return [
            self.elements.example(rng, 2 + rng.randrange(1 << 16)) for _ in range(n)
        ]


class _SampledFrom(SearchStrategy):
    def __init__(self, options: Sequence[Any]):
        self.options = list(options)

    def example(self, rng: random.Random, i: int) -> Any:
        if i < len(self.options):
            return self.options[i]
        return rng.choice(self.options)


def integers(min_value: int = 0, max_value: int = 100) -> SearchStrategy:
    return _Integers(min_value, max_value)


def floats(
    min_value: float = 0.0, max_value: float = 1.0, **_kw: Any
) -> SearchStrategy:
    return _Floats(min_value, max_value)


def lists(
    elements: SearchStrategy, min_size: int = 0, max_size: int = 10, **_kw: Any
) -> SearchStrategy:
    return _Lists(elements, min_size, max_size)


def sampled_from(options: Sequence[Any]) -> SearchStrategy:
    return _SampledFrom(options)


def booleans() -> SearchStrategy:
    return _SampledFrom([False, True])


def settings(
    max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline: Any = None, **_kw: Any
):
    """Records max_examples on the (possibly already @given-wrapped) fn."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strategies_kw: SearchStrategy):
    """Keyword-strategy @given. Runs each example eagerly, no shrinking."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(
                wrapper,
                "_fallback_max_examples",
                getattr(fn, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES),
            )
            for i in range(n):
                rng = random.Random(i)
                drawn = {k: s.example(rng, i) for k, s in sorted(strategies_kw.items())}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as exc:
                    raise AssertionError(f"falsifying example #{i}: {drawn!r}") from exc

        # hide strategy-filled params from pytest's fixture resolution
        sig = inspect.signature(fn)
        remaining = [
            p for name, p in sig.parameters.items() if name not in strategies_kw
        ]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        del wrapper.__wrapped__
        return wrapper

    return deco


def install() -> None:
    """Register this module as ``hypothesis`` (call only when the real
    package is absent)."""
    if "hypothesis" in sys.modules:
        return
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.__is_repro_fallback__ = True
    st = types.ModuleType("hypothesis.strategies")
    for name in (
        "integers",
        "floats",
        "lists",
        "sampled_from",
        "booleans",
        "SearchStrategy",
    ):
        setattr(st, name, globals()[name])
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
