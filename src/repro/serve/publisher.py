"""Train-while-serve publish path: snapshot fleet params as versions.

A :class:`ParamPublisher` sits between a *training*
:class:`~repro.rl.fleet.FleetEngine` and a serving
:class:`~repro.serve.service.LocalizationService`. ``publish()`` forces
the engine's flush-on-read path (pending scan-fused jobs retire first,
so a snapshot never observes a half-applied round) and stamps the
stacked ``[N, ...]`` parameter pytree with a monotonically increasing
version. The service pulls ``latest`` between ticks and hot-swaps it
into a free slot of its version ring — in-flight requests keep the
version they were admitted on (FedAsync-style bounded staleness, per
PAPERS.md, applied to the inference plane).
"""

from __future__ import annotations

import time
from collections.abc import Callable
from dataclasses import dataclass
from typing import Any

import jax

from repro.rl.fleet import FleetEngine


@dataclass(frozen=True)
class ParamVersion:
    """One published snapshot of the fleet's stacked params."""

    version: int  # monotonic, starts at 0
    params: Any  # [N, ...] stacked parameter pytree
    n_agents: int
    published_at: float  # wall clock (time.perf_counter)
    train_steps: int = 0  # engine steps trained when snapshotted


class ParamPublisher:
    """Versioned snapshots out of a live training engine.

    ``source`` is a :class:`FleetEngine` (the normal train-while-serve
    wiring) or any zero-arg callable returning a stacked ``[N, ...]``
    params pytree (tests publish hand-built pytrees this way).
    """

    def __init__(self, source: FleetEngine | Callable[[], Any]):
        self._engine = source if isinstance(source, FleetEngine) else None
        self._fn = None if self._engine is not None else source
        self._latest: ParamVersion | None = None
        self._next_version = 0

    @property
    def latest(self) -> ParamVersion | None:
        """Most recently published version (None before first publish)."""
        return self._latest

    @property
    def version(self) -> int:
        """Version number of ``latest`` (-1 before first publish)."""
        return -1 if self._latest is None else self._latest.version

    def publish(self) -> ParamVersion:
        """Snapshot the source now and advance the version counter."""
        if self._engine is not None:
            params = self._engine.stacked_params()
            n_agents = self._engine.n_slots
            steps = self._engine.n_steps_trained
        else:
            params = self._fn()
            n_agents = int(jax.tree_util.tree_leaves(params)[0].shape[0])
            steps = 0
        pv = ParamVersion(
            version=self._next_version,
            params=params,
            n_agents=n_agents,
            published_at=time.perf_counter(),
            train_steps=steps,
        )
        self._next_version += 1
        self._latest = pv
        return pv


__all__ = ["ParamPublisher", "ParamVersion"]
