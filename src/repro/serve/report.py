"""Serving-side run accounting: per-request latencies, queue depth,
ticks, swaps — what ``benchmarks/serve_latency.py`` gates in CI.

A :class:`ServeReport` is the inference-plane sibling of the training
:class:`~repro.core.experiment.Report`: the service appends one
:class:`RequestRecord` per completed request and samples queue depth
every tick; ``summary()`` flattens everything into the
``check_regression``-compatible metric dict.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np


@dataclass
class RequestRecord:
    """One completed localization request."""

    request_id: int
    agent_id: int
    version: int  # param version the whole rollout ran on
    n_ticks: int  # service ticks spent in a batch slot
    latency_s: float  # submit -> completion wall time
    queued_s: float  # submit -> admission wall time
    final_loc: Any = None  # [3] int voxel location
    dist_err: float | None = None  # vs known landmark (synthetic only)


@dataclass
class ServeReport:
    """What ``LocalizationService.drain()`` returns."""

    requests: list[RequestRecord] = field(default_factory=list)
    n_ticks: int = 0
    wall_time_s: float = 0.0
    queue_depth: list[int] = field(default_factory=list)  # sampled per tick
    batch_sizes: list[int] = field(default_factory=list)  # bucket per tick
    n_swaps: int = 0  # param versions hot-swapped in
    n_deferred_swaps: int = 0  # installs blocked by in-flight requests
    n_stall_ticks: int = 0  # admission paused by the staleness bound
    versions_served: dict[int, int] = field(default_factory=dict)
    act_traces_start: int = 0  # compiled-bucket counter before serving
    act_traces_end: int = 0  # ... and after (equal => no recompiles)

    @property
    def n_requests(self) -> int:
        return len(self.requests)

    @property
    def recompiles(self) -> int:
        """Retraces during serving (post-warmup this must be 0)."""
        return self.act_traces_end - self.act_traces_start

    def _latencies_ms(self) -> np.ndarray:
        return np.array([r.latency_s * 1e3 for r in self.requests], np.float64)

    def percentile_ms(self, q: float) -> float:
        lat = self._latencies_ms()
        return float(np.percentile(lat, q)) if len(lat) else float("nan")

    def summary(self) -> dict[str, Any]:
        """Flat JSON-able metrics (the ``configs`` entry CI gates on)."""
        lat = self._latencies_ms()
        ticks = np.array([r.n_ticks for r in self.requests], np.float64)
        errs = [r.dist_err for r in self.requests if r.dist_err is not None]
        rps = self.n_requests / self.wall_time_s if self.wall_time_s else 0.0
        return {
            "n_requests": self.n_requests,
            "requests_per_sec": rps,
            "p50_latency_ms": float(np.percentile(lat, 50)) if len(lat) else None,
            "p99_latency_ms": float(np.percentile(lat, 99)) if len(lat) else None,
            "mean_latency_ms": float(lat.mean()) if len(lat) else None,
            "ticks_per_request": float(ticks.mean()) if len(ticks) else None,
            "n_ticks": self.n_ticks,
            "mean_queue_depth": (
                float(np.mean(self.queue_depth)) if self.queue_depth else 0.0
            ),
            "max_queue_depth": max(self.queue_depth, default=0),
            "n_swaps": self.n_swaps,
            "n_deferred_swaps": self.n_deferred_swaps,
            "n_stall_ticks": self.n_stall_ticks,
            "versions_served": {str(k): v for k, v in self.versions_served.items()},
            "recompiles": self.recompiles,
            "mean_dist_err": float(np.mean(errs)) if errs else None,
            "wall_time_s": self.wall_time_s,
        }


__all__ = ["RequestRecord", "ServeReport"]
