"""Continuous-batching localization service over a served fleet.

The serving loop is a sequence of *ticks*. Each tick:

1. **swap** — if the :class:`~repro.serve.publisher.ParamPublisher` has
   a newer version, hot-swap it into a free slot of the version ring
   (in-flight requests keep the slot they pinned at admission); when the
   service would fall more than ``max_staleness`` versions behind and
   the swap is still blocked by in-flight work, admission pauses until
   the ring frees up — the staleness bound.
2. **admit** — pop queued requests into free batch slots (FIFO) up to
   ``max_batch``; each pins the newest installed version.
3. **act** — one compiled vmapped program
   (:class:`~repro.rl.fleet.ActSteps`) computes every active request's
   greedy move: observations staged host-side per request into a pooled
   per-bucket transfer buffer (one allocation per bucket for the
   service's lifetime — no fresh stack/concatenate arrays per tick), the
   batch padded to the next power-of-two bucket so the set of compiled
   entrypoints is fixed after warmup (SHARK-Engine's batch-size-bucketed
   ``GenerateServiceV1`` idiom, SNIPPETS.md Snippet 3).
4. **retire** — requests that oscillate onto a visited voxel (or exhaust
   their step budget) leave their slot; new requests are admitted into
   the freed slots next tick, with no recompilation.

Params live as one flat ``[V*N, ...]`` device pytree (version-ring slot
major, fleet agent minor); a request's program row is
``vslot * n_agents + agent_id``. Because every request runs as an
independent vmap lane gathering its own row, batched results are
bit-identical to single-request serving — tested, and the property that
makes continuous batching safe to enable everywhere.
"""

from __future__ import annotations

import time
from collections.abc import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.adfll_dqn import DQNConfig
from repro.rl.env import apply_actions
from repro.rl.fleet import _pow2, make_act_steps
from repro.serve.publisher import ParamPublisher, ParamVersion
from repro.serve.queue import RequestQueue, ServeRequest, ServeResult, _Ticket
from repro.serve.report import RequestRecord, ServeReport
from repro.telemetry import NULL, Telemetry


class LocalizationService:
    """Front a fleet's params with a request queue and batched ticks."""

    def __init__(
        self,
        cfg: DQNConfig,
        *,
        publisher: ParamPublisher | None = None,
        params=None,
        max_batch: int = 16,
        n_version_slots: int = 2,
        max_staleness: int = 0,
        warmup: bool = True,
        telemetry: Telemetry | None = None,
    ):
        self.telemetry = telemetry if telemetry is not None else NULL
        if (publisher is None) == (params is None):
            raise ValueError("exactly one of publisher= or params= is required")
        if publisher is None:
            publisher = ParamPublisher(lambda: params)
        if publisher.latest is None:
            publisher.publish()
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if n_version_slots < 1:
            raise ValueError(f"n_version_slots must be >= 1, got {n_version_slots}")
        self.cfg = cfg
        self.publisher = publisher
        self.max_batch = int(max_batch)
        self.n_version_slots = int(n_version_slots)
        self.max_staleness = int(max_staleness)
        self.steps = make_act_steps(cfg)
        pv = publisher.latest
        self.n_agents = pv.n_agents
        # pow2 batch buckets: one compiled entrypoint each, fixed after
        # warmup (admission never exceeds max_batch)
        self.buckets: list[int] = []
        b = 1
        while b < self.max_batch:
            self.buckets.append(b)
            b *= 2
        self.buckets.append(_pow2(self.max_batch))
        # version ring as one flat [V*N, ...] pytree (slot-major): a
        # swap rewrites one slot's rows, shapes never change, so a swap
        # never recompiles anything
        v = self.n_version_slots
        self._vparams = jax.tree_util.tree_map(
            lambda x: jnp.tile(x, (v,) + (1,) * (x.ndim - 1)), pv.params
        )
        self._slot_version: list[int | None] = [None] * v
        self._slot_active = [0] * v
        self._newest_slot = 0
        self._slot_version[0] = pv.version
        # pooled host staging: one (obs, norm, slot, locs, vol) buffer set
        # per batch bucket, reused every tick — observation staging writes
        # into resident arrays instead of allocating fresh
        # stack/concatenate intermediates per tick
        self._staging: dict[int, tuple[np.ndarray, ...]] = {}
        # request plane
        self.queue = RequestQueue()
        self.active: list[_Ticket] = []
        self.results: dict[int, ServeResult] = {}
        self._next_request_id = 0
        self.report = ServeReport()
        if warmup:
            self.steps.warmup(self._vparams, self.buckets)
        self.report.act_traces_start = self.steps.n_traces
        self.report.act_traces_end = self.steps.n_traces

    # -- params ------------------------------------------------------------
    @property
    def current_version(self) -> int:
        """Version number new admissions pin."""
        return self._slot_version[self._newest_slot]

    def install(self, pv: ParamVersion) -> bool:
        """Hot-swap a published version into the next ring slot; False
        (deferred) while that slot still serves in-flight requests."""
        if pv.n_agents != self.n_agents:
            raise ValueError(
                f"published fleet has {pv.n_agents} agents, "
                f"service built for {self.n_agents}"
            )
        cur = self.current_version
        if cur is not None and pv.version <= cur:
            return False  # stale or duplicate publish
        target = (self._newest_slot + 1) % self.n_version_slots
        if self._slot_active[target] > 0:
            self.report.n_deferred_swaps += 1
            if self.telemetry.enabled:
                self.telemetry.instant(
                    "serve.swap.deferred",
                    "serve",
                    self.telemetry.wall(),
                    clock="wall",
                    version=pv.version,
                )
                self.telemetry.count("serve.swaps.deferred", 1)
            return False
        n = self.n_agents
        self._vparams = jax.tree_util.tree_map(
            lambda buf, new: buf.at[target * n : (target + 1) * n].set(new),
            self._vparams,
            pv.params,
        )
        self._slot_version[target] = pv.version
        self._newest_slot = target
        self.report.n_swaps += 1
        if self.telemetry.enabled:
            self.telemetry.instant(
                "serve.swap",
                "serve",
                self.telemetry.wall(),
                clock="wall",
                version=pv.version,
                slot=target,
            )
            self.telemetry.count("serve.swaps", 1)
        return True

    def sync_params(self) -> bool:
        """Pull the publisher's latest version if it is newer (the
        between-ticks hot-swap path). Returns True when a swap landed."""
        latest = self.publisher.latest
        if latest is None or latest.version <= self.current_version:
            return False
        return self.install(latest)

    @property
    def staleness(self) -> int:
        """How many published versions behind the service is serving."""
        return max(0, self.publisher.version - self.current_version)

    def _stage(self, bucket: int) -> tuple[np.ndarray, ...]:
        """The bucket's pooled staging buffers, allocated once per bucket
        for the service's lifetime."""
        hit = self._staging.get(bucket)
        if hit is None:
            hit = (
                np.zeros((bucket, *self.cfg.box_size), np.float32),  # obs
                np.zeros((bucket, 3), np.float32),  # norm_loc
                np.zeros(bucket, np.int32),  # program row (slot)
                np.zeros((bucket, 3), np.int32),  # locs
                np.zeros(bucket, np.int32),  # per-row volume side
            )
            self._staging[bucket] = hit
        return hit

    # -- request plane -----------------------------------------------------
    def submit(self, request: ServeRequest, *, not_before: float = 0.0) -> int:
        """Queue one request; returns its id (results keyed by it)."""
        ticket = _Ticket(self._next_request_id, request, self.cfg)
        self._next_request_id += 1
        self.queue.push(ticket, not_before)
        return ticket.request_id

    def _admit(self, now: float) -> None:
        while len(self.active) < self.max_batch:
            ticket = self.queue.pop_ready(now)
            if ticket is None:
                return
            ticket.vslot = self._newest_slot
            ticket.version = self.current_version
            ticket.admitted_at = now
            self._slot_active[ticket.vslot] += 1
            self.active.append(ticket)

    def _retire(self, ticket: _Ticket, now: float) -> None:
        self._slot_active[ticket.vslot] -= 1
        err = ticket.dist_err()
        result = ServeResult(
            request_id=ticket.request_id,
            final_loc=ticket.loc.copy(),
            version=ticket.version,
            n_ticks=ticket.n_ticks,
            dist_err=err,
        )
        ticket.result = result
        self.results[ticket.request_id] = result
        self.report.requests.append(
            RequestRecord(
                request_id=ticket.request_id,
                agent_id=ticket.request.agent_id,
                version=ticket.version,
                n_ticks=ticket.n_ticks,
                latency_s=now - ticket.submitted_at,
                queued_s=ticket.admitted_at - ticket.submitted_at,
                final_loc=ticket.loc.copy(),
                dist_err=err,
            )
        )
        v = self.report.versions_served
        v[ticket.version] = v.get(ticket.version, 0) + 1
        if self.telemetry.enabled:
            tel = self.telemetry
            # the request's life on its agent's wall-clock track
            tel.span(
                "request",
                f"agent{ticket.request.agent_id}",
                tel.to_wall(ticket.submitted_at),
                tel.to_wall(now),
                clock="wall",
                request_id=ticket.request_id,
                version=ticket.version,
                n_ticks=ticket.n_ticks,
            )
            tel.count("serve.requests.completed", 1)
            tel.observe("serve.latency_s", now - ticket.submitted_at)
            tel.observe("serve.queued_s", ticket.admitted_at - ticket.submitted_at)

    def tick(self) -> int:
        """One serving tick; returns how many requests completed."""
        now = time.perf_counter()
        tel = self.telemetry
        tick_t0 = tel.wall() if tel.enabled else 0.0
        traces0 = self.steps.n_traces
        self.sync_params()
        if self.staleness > self.max_staleness:
            # staleness bound: the swap is blocked by in-flight rollouts
            # on the oldest slot — pause admission until it lands
            self.report.n_stall_ticks += 1
            if tel.enabled:
                tel.instant(
                    "serve.stall",
                    "serve",
                    tel.wall(),
                    clock="wall",
                    staleness=self.staleness,
                )
                tel.count("serve.stall_ticks", 1)
        else:
            self._admit(now)
        self.report.queue_depth.append(len(self.queue))
        if not self.active:
            return 0
        n_active = len(self.active)
        bucket = next(b for b in self.buckets if b >= n_active)
        obs, norm, slot, loc_buf, vol = self._stage(bucket)
        for i, t in enumerate(self.active):
            if not 0 <= t.request.agent_id < self.n_agents:
                raise ValueError(f"agent_id out of range: {t.request.agent_id}")
            slot[i] = t.vslot * self.n_agents + t.request.agent_id
            loc_buf[i] = t.loc
            vol[i] = t.env.n
            obs[i] = t.env.observe(t.loc[None])[0]
            norm[i] = t.env.norm_loc(t.loc)
        if bucket > n_active:  # pad rows (discarded; lanes are independent)
            obs[n_active:] = 0.0
            norm[n_active:] = 0.0
            slot[n_active:] = 0
        locs = loc_buf[:n_active]
        actions, _ = self.steps.act(
            self._vparams, jnp.asarray(slot), jnp.asarray(obs), jnp.asarray(norm)
        )
        actions = np.asarray(actions)[:n_active]  # the tick's one host sync
        new_locs = apply_actions(locs, actions, vol[:n_active], self.cfg.step_size)
        now = time.perf_counter()
        done = 0
        still_active = []
        for ticket, new_loc in zip(self.active, new_locs, strict=True):
            if ticket.advance(new_loc):
                self._retire(ticket, now)
                done += 1
            else:
                still_active.append(ticket)
        self.active = still_active
        self.report.n_ticks += 1
        self.report.batch_sizes.append(bucket)
        self.report.act_traces_end = self.steps.n_traces
        if tel.enabled:
            tick_t1 = tel.wall()
            compiled = self.steps.n_traces - traces0
            tel.span(
                "serve.tick",
                "serve",
                tick_t0,
                tick_t1,
                clock="wall",
                n_active=n_active,
                bucket=bucket,
                done=done,
                compiled=compiled,
            )
            if compiled:
                tel.instant("serve.compile", "serve", tick_t1, clock="wall")
                tel.count("serve.compiles", compiled)
            tel.count("serve.ticks", 1)
            tel.observe("serve.tick.batch", n_active)
        return done

    def drain(self) -> ServeReport:
        """Tick until the queue and every batch slot are empty."""
        t0 = time.perf_counter()
        while self.queue or self.active:
            if self.tick() == 0 and not self.active:
                time.sleep(1e-4)  # open-loop: head-of-queue not arrived yet
        self.report.wall_time_s += time.perf_counter() - t0
        self.report.act_traces_end = self.steps.n_traces
        return self.report

    def serve(
        self, requests: Sequence[ServeRequest], *, rate: float | None = None
    ) -> ServeReport:
        """Submit a batch of requests and drain the service.

        ``rate`` (requests per second) spaces arrivals open-loop on the
        wall clock; None submits everything at once (closed-loop, the
        deterministic mode tests and benchmarks use).
        """
        t0 = time.perf_counter()
        for i, req in enumerate(requests):
            not_before = 0.0 if rate is None else t0 + i / rate
            self.submit(req, not_before=not_before)
        return self.drain()


__all__ = ["LocalizationService"]
