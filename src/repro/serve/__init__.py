"""Online inference plane: continuous-batching localization serving.

The repo's first inference-side subsystem: a request queue +
continuous-batching :class:`LocalizationService` over batch-size-
bucketed compiled act entrypoints, and a :class:`ParamPublisher` that
hot-swaps fleet params out of a live training engine between ticks
(train-while-serve with a bounded-staleness version ring).

    from repro.serve import (
        LocalizationService, ParamPublisher, ServeRequest,
        TrafficSpec, synthetic_requests,
    )
"""

import repro.core  # noqa: F401  (resolve the core<->rl import cycle first)
from repro.serve.driver import ServeSession, build_session, run_session
from repro.serve.publisher import ParamPublisher, ParamVersion
from repro.serve.queue import RequestQueue, ServeRequest, ServeResult
from repro.serve.report import RequestRecord, ServeReport
from repro.serve.service import LocalizationService
from repro.serve.traffic import TrafficSpec, synthetic_requests

__all__ = [
    "LocalizationService",
    "ParamPublisher",
    "ParamVersion",
    "RequestQueue",
    "RequestRecord",
    "ServeReport",
    "ServeRequest",
    "ServeResult",
    "ServeSession",
    "TrafficSpec",
    "build_session",
    "run_session",
    "synthetic_requests",
]
