"""Request plane: what a caller submits and how it waits.

A :class:`ServeRequest` is one localization query — a volume, a start
voxel, and which fleet agent should answer. The service wraps each in a
:class:`_Ticket` carrying the per-rollout host state (environment view,
pinned param version slot, visited-voxel cycle detector) and parks it in
a :class:`RequestQueue` until a batch slot frees up.

Requests know nothing about landmarks: termination is greedy-rollout
oscillation (the next move revisits a voxel the rollout has already
occupied — the classic landmark-localization stopping rule) or the step
budget. ``landmark`` is optional ground truth used only for accuracy
reporting on synthetic traffic.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.configs.adfll_dqn import DQNConfig
from repro.rl.env import LandmarkEnv

_NO_LANDMARK = np.zeros(3, np.float32)


@dataclass
class ServeRequest:
    """One localization query against the served fleet."""

    volume: np.ndarray  # [n,n,n] f32
    start: np.ndarray  # [3] int voxel
    agent_id: int = 0  # which fleet slot answers
    max_steps: int | None = None  # None -> cfg.max_episode_steps
    landmark: np.ndarray | None = None  # ground truth (reporting only)


@dataclass
class ServeResult:
    """Resolution of one request (also recorded in the ServeReport)."""

    request_id: int
    final_loc: np.ndarray  # [3] int voxel
    version: int  # param version of the whole rollout
    n_ticks: int
    dist_err: float | None = None


class _Ticket:
    """Host-side rollout state of one admitted (or queued) request."""

    __slots__ = (
        "request_id",
        "request",
        "env",
        "loc",
        "visited",
        "n_ticks",
        "vslot",
        "version",
        "max_steps",
        "submitted_at",
        "admitted_at",
        "result",
    )

    def __init__(self, request_id: int, request: ServeRequest, cfg: DQNConfig):
        self.request_id = request_id
        self.request = request
        # LandmarkEnv doubles as the observation view; the dummy landmark
        # is never read (serving uses observe/norm_loc only).
        self.env = LandmarkEnv(request.volume, _NO_LANDMARK, cfg)
        self.loc = np.asarray(request.start, np.int32).copy()
        self.visited = {tuple(int(v) for v in self.loc)}
        self.n_ticks = 0
        self.vslot: int = -1  # version ring slot pinned at admission
        self.version: int = -1  # ... and its monotonic version number
        self.max_steps = (
            request.max_steps
            if request.max_steps is not None
            else cfg.max_episode_steps
        )
        self.submitted_at = time.perf_counter()
        self.admitted_at: float = 0.0
        self.result: ServeResult | None = None

    def advance(self, new_loc: np.ndarray) -> bool:
        """Record one greedy move; True when the rollout terminated
        (oscillation back onto a visited voxel, or the step budget)."""
        self.n_ticks += 1
        key = tuple(int(v) for v in new_loc)
        if key in self.visited or self.n_ticks >= self.max_steps:
            self.loc = np.asarray(new_loc, np.int32)
            return True
        self.visited.add(key)
        self.loc = np.asarray(new_loc, np.int32)
        return False

    def dist_err(self) -> float | None:
        lm = self.request.landmark
        if lm is None:
            return None
        return float(np.linalg.norm(self.loc.astype(np.float32) - lm))


@dataclass
class RequestQueue:
    """FIFO admission queue with arrival-time gating.

    ``push`` accepts a ticket with an optional ``not_before`` wall-clock
    time (open-loop synthetic traffic schedules arrivals ahead of time);
    ``pop_ready`` releases tickets in submission order, never jumping a
    not-yet-arrived head (FIFO is part of the determinism contract).
    """

    _items: deque = field(default_factory=deque)

    def push(self, ticket: _Ticket, not_before: float = 0.0) -> None:
        self._items.append((not_before, ticket))

    def pop_ready(self, now: float) -> _Ticket | None:
        if not self._items:
            return None
        not_before, ticket = self._items[0]
        if not_before > now:
            return None
        self._items.popleft()
        return ticket

    def __len__(self) -> int:
        return len(self._items)


__all__ = ["RequestQueue", "ServeRequest", "ServeResult"]
