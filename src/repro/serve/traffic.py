"""Synthetic localization traffic: seeded request streams over the
synthetic BraTS-like task volumes.

A :class:`TrafficSpec` is the frozen, declarative description a
scenario or benchmark embeds (how many requests, batching limits,
hot-swap cadence); :func:`synthetic_requests` expands one into concrete
:class:`~repro.serve.queue.ServeRequest` values with known landmarks,
so served accuracy is measurable alongside latency.
"""

from __future__ import annotations

from collections.abc import Sequence
from dataclasses import dataclass

import numpy as np

from repro.configs.adfll_dqn import DQNConfig
from repro.rl.synth import make_volume, paper_eight_tasks
from repro.serve.queue import ServeRequest


@dataclass(frozen=True)
class TrafficSpec:
    """One declarative synthetic-traffic workload."""

    n_requests: int = 64
    max_batch: int = 8  # service admission limit (pow2-bucketed)
    n_version_slots: int = 2  # live param versions the ring can hold
    max_staleness: int = 1  # versions the service may lag the publisher
    max_steps: int | None = None  # per-request budget (None -> cfg)
    rate: float | None = None  # req/s open-loop; None = all at once
    n_tasks: int = 4  # distinct task volumes in the stream
    n_patients: int = 8  # distinct patients per task
    seed: int = 0


def synthetic_requests(
    spec: TrafficSpec,
    cfg: DQNConfig,
    *,
    n_agents: int = 1,
    tasks: Sequence | None = None,
) -> list[ServeRequest]:
    """Expand a spec into a seeded, deterministic request list.

    Requests cycle round-robin over tasks x patients x agents; start
    voxels draw from the same central band the training environments
    use. Landmarks ride along for accuracy reporting only.
    """
    task_list = list(tasks if tasks is not None else paper_eight_tasks())
    task_list = task_list[: spec.n_tasks] or task_list
    rng = np.random.default_rng(spec.seed)
    n = cfg.volume_shape[0]
    lo, hi = n // 4, 3 * n // 4
    out: list[ServeRequest] = []
    for i in range(spec.n_requests):
        task = task_list[i % len(task_list)]
        patient = int(rng.integers(0, spec.n_patients))
        vol, lm = make_volume(task, patient, n=n)
        out.append(
            ServeRequest(
                volume=vol,
                start=rng.integers(lo, hi, size=3).astype(np.int32),
                agent_id=i % n_agents,
                max_steps=spec.max_steps,
                landmark=lm,
            )
        )
    return out


__all__ = ["TrafficSpec", "synthetic_requests"]
