"""Train-while-serve session driver.

One reusable loop under ``launch.serve --fleet``, the
``serve_latency`` benchmark, and the ``serve_localization`` scenario:
build a fleet, give it a short training warm start, then alternate
serving traffic waves with training rounds — each round ends in a
``publish()`` the service hot-swaps in before the next wave, so every
session exercises the continuous-batching and hot-swap paths together.
"""

from __future__ import annotations

from collections.abc import Sequence
from dataclasses import dataclass

import numpy as np

from repro.configs.adfll_dqn import DQNConfig
from repro.core.federated import env_for
from repro.models.sharding import make_fleet_mesh
from repro.rl.agent import DQNAgent
from repro.rl.fleet import FleetEngine, collect_fleet
from repro.rl.synth import paper_eight_tasks, patient_split
from repro.serve.publisher import ParamPublisher
from repro.serve.report import ServeReport
from repro.serve.service import LocalizationService
from repro.serve.traffic import TrafficSpec, synthetic_requests
from repro.telemetry import Telemetry


@dataclass
class ServeSession:
    """A live fleet + publisher + service triple."""

    cfg: DQNConfig
    engine: FleetEngine
    agents: list[DQNAgent]
    publisher: ParamPublisher
    service: LocalizationService
    tasks: list
    patients: list

    def train_round(self, round_idx: int, train_steps: int) -> None:
        """One lifelong round per agent (personal replay, no federation
        — the serving session exercises the inference plane, not the
        sharing planes) followed by nothing: callers publish.

        The cohort collects through ONE stacked greedy-rollout program
        (:func:`repro.rl.fleet.collect_fleet`) and trains as one batched
        flush — bit-identical to per-agent rounds, since every rng draw
        stays in its agent's own stream order."""
        agents = self.agents
        tasks = [
            self.tasks[(round_idx + a.agent_id) % len(self.tasks)] for a in agents
        ]
        envs = [
            env_for(t, int(a.rng.choice(self.patients)), self.cfg)
            for a, t in zip(agents, tasks, strict=True)
        ]
        erbs = [
            a.new_round_erb(t, 512) for a, t in zip(agents, tasks, strict=True)
        ]
        collect_fleet(agents, envs, erbs, n_episodes=24)
        for agent, env, task, erb in zip(agents, envs, tasks, erbs, strict=True):
            agent.begin_round(
                env,
                task,
                incoming=(),
                erb_capacity=512,
                share_size=0,
                train_steps=train_steps,
                current=erb,
            )
        self.engine.flush()

    def publish(self) -> None:
        self.publisher.publish()


def build_session(
    cfg: DQNConfig,
    *,
    n_agents: int,
    traffic: TrafficSpec,
    seed: int = 0,
    tasks: Sequence | None = None,
    patients: Sequence[int] | None = None,
    warmup: bool = True,
    telemetry: Telemetry | None = None,
    devices: int = 0,
) -> ServeSession:
    """Fleet + publisher + service, params published once (version 0).
    ``devices`` > 0 (or -1 = all) shards the fleet axis across a device
    mesh (:func:`repro.models.sharding.make_fleet_mesh`)."""
    engine = FleetEngine(cfg, mesh=make_fleet_mesh(devices) if devices else None)
    if telemetry is not None:
        engine.telemetry = telemetry
    agents = [
        DQNAgent(i, cfg, seed=seed + i, engine=engine) for i in range(n_agents)
    ]
    if telemetry is not None and telemetry.enabled:
        # same contract as ADFLLSystem: enabled telemetry brings the
        # observatory (observe-only; bit-identical serve results)
        from repro.observatory import Observatory

        obs = Observatory(telemetry)
        engine.observatory = obs
        for i, a in enumerate(agents):
            obs.register_slot(a.slot, i)
    task_list = list(tasks if tasks is not None else paper_eight_tasks())
    if patients is None:
        patients, _ = patient_split(16)
    publisher = ParamPublisher(engine)
    publisher.publish()
    service = LocalizationService(
        cfg,
        publisher=publisher,
        max_batch=traffic.max_batch,
        n_version_slots=traffic.n_version_slots,
        max_staleness=traffic.max_staleness,
        warmup=warmup,
        telemetry=telemetry,
    )
    return ServeSession(
        cfg=cfg,
        engine=engine,
        agents=agents,
        publisher=publisher,
        service=service,
        tasks=task_list,
        patients=list(patients),
    )


def run_session(
    session: ServeSession,
    traffic: TrafficSpec,
    *,
    n_waves: int = 2,
    train_steps: int = 20,
    train_rounds_per_wave: int = 1,
) -> ServeReport:
    """Alternate traffic waves with train+publish rounds.

    Wave 0 serves on version 0; each later wave is preceded by
    ``train_rounds_per_wave`` fleet rounds and one publish, so waves
    1..n serve hot-swapped versions 1..n — train-while-serve in one
    thread (the simulator has no real concurrency; interleaving at wave
    granularity is the deterministic equivalent).
    """
    requests = synthetic_requests(
        traffic, session.cfg, n_agents=len(session.agents), tasks=session.tasks
    )
    waves = np.array_split(np.arange(len(requests)), max(1, n_waves))
    round_idx = 0
    for w, idx in enumerate(waves):
        if w > 0:
            for _ in range(train_rounds_per_wave):
                session.train_round(round_idx, train_steps)
                round_idx += 1
            session.publish()
        session.service.serve([requests[i] for i in idx], rate=traffic.rate)
    return session.service.report


__all__ = ["ServeSession", "build_session", "run_session"]
