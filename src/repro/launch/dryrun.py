import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# The forced 512 host devices exist ONLY for this dry-run process.

import argparse  # noqa: E402
import json  # noqa: E402
import math  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.configs.base import (  # noqa: E402
    ASSIGNED,
    INPUT_SHAPES,
    get_config,
    param_count,
)
from repro.launch import analysis  # noqa: E402
from repro.launch.mesh import (  # noqa: E402
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    make_production_mesh,
)
from repro.launch.specs import (  # noqa: E402
    cache_specs,
    input_specs,
    opt_cfg_for,
    params_specs,
    state_specs,
)
from repro.models.model import (  # noqa: E402
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.models.sharding import ShardingPolicy  # noqa: E402

# Per-(arch, mode) gradient-accumulation settings found during the baseline
# memory pass (EXPERIMENTS.md §Dry-run). Everything else runs k=1.
MICROBATCHES = {
    ("jamba-1.5-large-398b", "train"): 8,
    ("qwen3-moe-235b-a22b", "train"): 4,
    ("qwen2.5-14b", "train"): 2,
    ("starcoder2-15b", "train"): 2,
    ("moonshot-v1-16b-a3b", "train"): 2,
    ("deepseek-v2-lite-16b", "train"): 2,
}

# Beyond-paper launch settings derived from the §Perf measurement campaign
# (EXPERIMENTS.md): dense/audio/VLM <=4B -> pure DP + ZeRO-3; mid dense ->
# TP+SP with grad accumulation; MoE -> EP (baseline); 398B hybrid ->
# multi-pod + k=4 + no-SP.
OPTIMIZED = {
    ("h2o-danube-3-4b", "train"): {"dp_over_model": True},
    ("musicgen-medium", "train"): {"dp_over_model": True},
    ("qwen2-vl-2b", "train"): {"dp_over_model": True},
    ("xlstm-125m", "train"): {"dp_over_model": True},
    ("jamba-1.5-large-398b", "train"): {"microbatches": 4, "seq_shard": False},
}

SKIPS = {
    # long_500k needs a sub-quadratic path (DESIGN.md §4)
    ("musicgen-medium", "long_500k"): "full attention, no subquadratic path",
    ("qwen2.5-14b", "long_500k"): "full attention, no subquadratic path",
    ("moonshot-v1-16b-a3b", "long_500k"): "full attention, no subquadratic path",
    ("deepseek-v2-lite-16b", "long_500k"): "full attention, no subquadratic path",
    ("qwen3-moe-235b-a22b", "long_500k"): "full attention, no subquadratic path",
    ("starcoder2-15b", "long_500k"): "full attention, no subquadratic path",
    ("qwen2-vl-2b", "long_500k"): "full attention, no subquadratic path",
}


def _bytes_per_device(sds_tree) -> float:
    total = 0.0
    for leaf in jax.tree_util.tree_leaves(sds_tree):
        sh = getattr(leaf, "sharding", None)
        if sh is not None:
            shard = sh.shard_shape(leaf.shape)
        else:
            shard = leaf.shape
        if shard:
            total += math.prod(shard) * leaf.dtype.itemsize
        else:
            total += leaf.dtype.itemsize
    return total


def run_pair(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    policy_overrides: dict | None = None,
    print_analyses: bool = True,
    optimized: bool = False,
) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if optimized:
        base = OPTIMIZED.get((arch, shape.mode), {})
        policy_overrides = dict(base, **(policy_overrides or {}))
    if (arch, shape_name) in SKIPS:
        return {
            "arch": arch,
            "shape": shape_name,
            "multi_pod": multi_pod,
            "status": "skipped",
            "reason": SKIPS[(arch, shape_name)],
        }

    mesh = make_production_mesh(multi_pod=multi_pod)
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    kw = dict(
        batch_axes=batch_axes,
        fsdp_axes=("data",),
        microbatches=MICROBATCHES.get((arch, shape.mode), 1),
    )
    overrides = dict(policy_overrides or {})
    if overrides.pop("dp_over_model", False):
        # pure data parallelism: the model axis carries batch, weights are
        # FSDP-sharded over data and replicated over model
        kw.update(
            batch_axes=batch_axes + ("model",),
            tensor_parallel=False,
            seq_shard=False,
        )
    if overrides.pop("no_fsdp", False):
        kw.update(fsdp_axes=())
    kw.update(overrides)
    policy = ShardingPolicy(**kw)
    opt_cfg = opt_cfg_for(cfg)

    t0 = time.time()
    if shape.mode == "train":
        sspec, _ = state_specs(cfg, mesh, policy, opt_cfg)
        bspec = input_specs(cfg, shape, mesh, policy)
        step = make_train_step(cfg, opt_cfg, mesh=mesh, policy=policy)
        args = (sspec, bspec)
        jitted = jax.jit(step, donate_argnums=0)
    elif shape.mode == "prefill":
        pspec, _ = params_specs(cfg, mesh, policy)
        bspec = input_specs(cfg, shape, mesh, policy)
        step = make_prefill_step(cfg, mesh=mesh, policy=policy)
        args = (pspec, bspec)
        jitted = jax.jit(step)
    else:  # decode
        pspec, _ = params_specs(cfg, mesh, policy)
        cspec, _ = cache_specs(cfg, shape.global_batch, shape.seq_len, mesh, policy)
        bspec = input_specs(cfg, shape, mesh, policy)
        step = make_serve_step(cfg, mesh=mesh, policy=policy)
        args = (pspec, cspec, bspec)
        jitted = jax.jit(step, donate_argnums=1)

    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if print_analyses:
        arg_gb = ma.argument_size_in_bytes / 1e9
        out_gb = ma.output_size_in_bytes / 1e9
        tmp_gb = ma.temp_size_in_bytes / 1e9
        print(
            f"memory_analysis: arg={arg_gb:.3f}GB out={out_gb:.3f}GB "
            f"temp={tmp_gb:.3f}GB (proof of per-device footprint)"
        )
        flops = ca.get("flops", 0)
        bytes_acc = ca.get("bytes accessed", 0)
        print(
            f"cost_analysis: flops={flops:.3e} bytes={bytes_acc:.3e} "
            f"(while-bodies counted once — see corrected terms)"
        )

    # corrected global FLOPs from the jaxpr (scan-exact)
    n_dev = mesh.size
    flops_global = analysis.count_flops(step, *args, n_shards=n_dev)
    # per-device collective bytes from the optimized HLO
    coll = analysis.parse_collectives(compiled.as_text())

    chips = n_dev
    total_p, active_p = param_count(cfg)
    if shape.mode == "train":
        model_flops = 6.0 * active_p * shape.global_batch * shape.seq_len
    elif shape.mode == "prefill":
        model_flops = 2.0 * active_p * shape.global_batch * shape.seq_len
    else:
        model_flops = 2.0 * active_p * shape.global_batch  # one token

    # analytic HBM traffic (per device)
    dtype_b = 2 if cfg.dtype == "bfloat16" else 4
    if shape.mode == "train":
        param_dev = _bytes_per_device(args[0]["params"])
        opt_dev = _bytes_per_device(args[0]["opt"])
        cache_dev = 0.0
    else:
        param_dev = _bytes_per_device(args[0])
        opt_dev = 0.0
        cache_dev = _bytes_per_device(args[1]) if shape.mode == "decode" else 0.0
    mp = mesh.shape["model"]
    dp = chips // mp
    seq_div = mp if (policy.seq_shard and shape.seq_len % mp == 0) else 1
    if shape.mode != "decode":
        act_dev = (
            cfg.n_layers
            * shape.global_batch
            * shape.seq_len
            * cfg.d_model
            * dtype_b
            / max(dp, 1)
            / seq_div
            / policy.microbatches
        )
    else:
        act_dev = 0.0
    io_dev = _bytes_per_device(args[-1])
    hbm = analysis.analytic_hbm_bytes(
        mode=shape.mode,
        param_bytes_dev=param_dev,
        opt_bytes_dev=opt_dev,
        act_bytes_dev=act_dev,
        cache_bytes_dev=cache_dev,
        io_bytes_dev=io_dev,
    )

    ca_keep = {k: ca.get(k) for k in ("flops", "bytes accessed") if k in ca}
    compute_t = flops_global / (chips * PEAK_FLOPS_BF16)
    memory_t = hbm["total"] / HBM_BW  # per-device traffic
    collective_t = coll.get("total", 0.0) / ICI_BW

    terms = {
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": collective_t,
    }
    bottleneck = max(terms, key=terms.get)

    result = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "status": "ok",
        "mesh": dict(mesh.shape),
        "microbatches": policy.microbatches,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "arg_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_bytes": ma.argument_size_in_bytes + ma.temp_size_in_bytes,
        },
        "cost_analysis": ca_keep,
        "flops_global_jaxpr": flops_global,
        "collective_bytes_per_dev": coll,
        "hbm_bytes_per_dev": hbm["total"],
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / flops_global if flops_global else None,
        "roofline": dict(terms, bottleneck=bottleneck),
        "params_total": total_p,
        "params_active": active_p,
    }
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument(
        "--all", action="store_true", help="run every (arch x shape) in subprocesses"
    )
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", default=None, help="policy overrides k=v,k=v (ints/bools)")
    ap.add_argument(
        "--optimized",
        action="store_true",
        help="apply the EXPERIMENTS.md §Perf launch settings",
    )
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)

    if args.all:
        jobs = [(a, s) for a in ASSIGNED for s in INPUT_SHAPES]
        for a, s in jobs:
            tag = "multi" if args.multi_pod else "single"
            path = os.path.join(args.out, f"{a}__{s}__{tag}.json")
            if os.path.exists(path) and not args.force:
                print(f"[skip cached] {a} {s}")
                continue
            cmd = [
                sys.executable,
                "-m",
                "repro.launch.dryrun",
                "--arch",
                a,
                "--shape",
                s,
                "--out",
                args.out,
            ]
            if args.multi_pod:
                cmd.append("--multi-pod")
            print(f"[run] {a} {s} {tag}", flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True)
            tail = "\n".join((r.stdout or "").splitlines()[-8:])
            print(tail)
            if r.returncode != 0:
                err = "\n".join((r.stderr or "").splitlines()[-12:])
                print(f"[FAIL] {a} {s}: {err}")
                failure = {
                    "arch": a,
                    "shape": s,
                    "multi_pod": args.multi_pod,
                    "status": "error",
                    "error": err[-2000:],
                }
                with open(path, "w") as f:
                    json.dump(failure, f, indent=1)
        return

    overrides = {}
    if args.set:
        for kv in args.set.split(","):
            k, v = kv.split("=")
            overrides[k] = (v == "True") if v in ("True", "False") else int(v)

    res = run_pair(
        args.arch,
        args.shape,
        multi_pod=args.multi_pod,
        policy_overrides=overrides or None,
        optimized=args.optimized,
    )
    tag = "multi" if args.multi_pod else "single"
    path = os.path.join(args.out, f"{args.arch}__{args.shape}__{tag}.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    slim = {k: v for k, v in res.items() if k not in ("cost_analysis",)}
    print(json.dumps(slim, indent=1))


if __name__ == "__main__":
    main()
