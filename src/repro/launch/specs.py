"""ShapeDtypeStruct stand-ins (with shardings) for every model input.

Everything here is shape-level only: no device allocation ever happens.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import model as model_lib
from repro.models.sharding import ShardingPolicy, cache_shardings, tree_shardings
from repro.optim.adamw import AdamWConfig, adamw_init


def opt_cfg_for(cfg: ModelConfig) -> AdamWConfig:
    """bf16 moments for the >300B configs (f32 would not fit 16 GB/chip
    at 256-way sharding — see DESIGN.md §5)."""
    from repro.configs.base import param_count

    total, _ = param_count(cfg)
    dtype = "bfloat16" if total > 1e11 else "float32"
    return AdamWConfig(opt_dtype=dtype)


def _sds(tree, shardings=None):
    """eval-shaped pytree -> ShapeDtypeStructs with shardings attached."""
    if shardings is None:
        return jax.tree_util.tree_map(
            lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), tree
        )
    return jax.tree_util.tree_map(
        lambda v, s: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=s),
        tree,
        shardings,
    )


def _batch_pspec(mesh: Mesh | None, policy: ShardingPolicy, b: int):
    if mesh is None:
        return None
    batch = tuple(a for a in policy.batch_axes if a in mesh.axis_names)
    if not batch or b % math.prod(mesh.shape[a] for a in batch) != 0:
        # fall back: try fewer axes, else replicate
        batch = tuple(a for a in batch if b % mesh.shape[a] == 0)[:1]
    if not batch:
        return None
    return batch if len(batch) > 1 else batch[0]


def state_specs(
    cfg: ModelConfig,
    mesh: Mesh | None,
    policy: ShardingPolicy,
    opt_cfg: AdamWConfig,
):
    """TrainState ShapeDtypeStructs + shardings."""
    key = jax.random.PRNGKey(0)
    pshape = jax.eval_shape(lambda: model_lib.init_params(cfg, key))
    oshape = jax.eval_shape(lambda: adamw_init(opt_cfg, pshape))
    if mesh is None:
        return _sds({"params": pshape, "opt": oshape}), None
    pshard = tree_shardings(pshape, mesh, policy, cfg)
    oshard = {
        "m": tree_shardings(oshape["m"], mesh, policy, cfg),
        "v": tree_shardings(oshape["v"], mesh, policy, cfg),
        "count": NamedSharding(mesh, P()),
    }
    shardings = {"params": pshard, "opt": oshard}
    return _sds({"params": pshape, "opt": oshape}, shardings), shardings


def params_specs(cfg: ModelConfig, mesh: Mesh | None, policy: ShardingPolicy):
    key = jax.random.PRNGKey(0)
    pshape = jax.eval_shape(lambda: model_lib.init_params(cfg, key))
    if mesh is None:
        return _sds(pshape), None
    pshard = tree_shardings(pshape, mesh, policy, cfg)
    return _sds(pshape, pshard), pshard


def cache_specs(
    cfg: ModelConfig,
    batch: int,
    seq_len: int,
    mesh: Mesh | None,
    policy: ShardingPolicy,
):
    cshape = jax.eval_shape(lambda: model_lib.init_caches(cfg, batch, seq_len))
    if mesh is None:
        return _sds(cshape), None
    cshard = cache_shardings(cshape, mesh, policy)
    return _sds(cshape, cshard), cshard


def input_specs(
    cfg: ModelConfig,
    shape: InputShape,
    mesh: Mesh | None,
    policy: ShardingPolicy,
) -> dict[str, Any]:
    """Model-input ShapeDtypeStructs for one (arch x input-shape) pair."""
    b = shape.global_batch
    s = shape.seq_len
    bspec = _batch_pspec(mesh, policy, b)
    dt = jnp.dtype(cfg.dtype)

    def sh(*dims):
        return NamedSharding(mesh, P(*dims)) if mesh else None

    def sds(shape_, dtype, spec=None):
        if mesh is None:
            return jax.ShapeDtypeStruct(shape_, dtype)
        return jax.ShapeDtypeStruct(shape_, dtype, sharding=spec)

    if shape.mode in ("train", "prefill"):
        batch = {}
        if cfg.input_kind == "embeds":
            batch["embeds"] = sds((b, s, cfg.d_model), dt, sh(bspec, None, None))
        else:
            batch["tokens"] = sds((b, s), jnp.int32, sh(bspec, None))
        if shape.mode == "train":
            batch["labels"] = sds((b, s), jnp.int32, sh(bspec, None))
        if cfg.rope == "mrope":
            batch["positions"] = sds((3, b, s), jnp.int32, sh(None, bspec, None))
        return batch
    # decode: one token + position, cache comes separately
    batch = {}
    if cfg.input_kind == "embeds":
        batch["embeds"] = sds((b, 1, cfg.d_model), dt, sh(bspec, None, None))
    else:
        batch["tokens"] = sds((b, 1), jnp.int32, sh(bspec, None))
    batch["pos"] = sds((b,), jnp.int32, sh(bspec))
    return batch
