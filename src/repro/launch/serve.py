"""Serving CLI: the localization inference plane, plus the legacy
transformer prefill+decode driver.

Fleet mode — continuous-batching localization serving with train-while-
serve hot swaps (the production direction; see ``repro.serve``):

    PYTHONPATH=src python -m repro.launch.serve --fleet \
        [--agents 2] [--requests 64] [--max-batch 8] [--waves 2] \
        [--rate REQ_PER_S] [--seed 0] [--json OUT]

Transformer mode — one-shot prefill then batched greedy decode of a
model-zoo config (the original driver; all old flags keep working):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b-smoke \
        --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models.model import (
    init_caches,
    init_params,
    make_prefill_step,
    make_serve_step,
)
from repro.models.sharding import ShardingPolicy


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.launch.serve")
    mode = ap.add_argument_group("mode (exactly one)")
    mode.add_argument(
        "--fleet",
        action="store_true",
        help="serve the localization fleet under synthetic traffic",
    )
    mode.add_argument("--arch", default=None, help="transformer config to decode")
    ap.add_argument("--seed", type=int, default=0)
    # -- transformer-mode flags (unchanged) --------------------------------
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    # -- fleet-mode flags --------------------------------------------------
    ap.add_argument("--agents", type=int, default=2, help="fleet slots served")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument(
        "--waves",
        type=int,
        default=2,
        help="traffic waves; each later wave follows a train+publish "
        "round, exercising a param hot-swap",
    )
    ap.add_argument(
        "--rate",
        type=float,
        default=None,
        help="open-loop arrival rate (req/s); default: all at once",
    )
    ap.add_argument("--train-steps", type=int, default=20)
    ap.add_argument("--version-slots", type=int, default=2)
    ap.add_argument("--max-staleness", type=int, default=1)
    ap.add_argument("--json", default=None, metavar="OUT")
    args = ap.parse_args(argv)

    if args.fleet == (args.arch is not None):
        ap.error("exactly one of --fleet or --arch is required")
    if args.fleet:
        return _fleet_main(args)
    return _transformer_main(args)


def _fleet_main(args) -> int:
    """Thin driver over ``repro.serve``: build, serve, report."""
    from repro.configs.adfll_dqn import DQNConfig
    from repro.serve import TrafficSpec, build_session, run_session

    cfg = DQNConfig(
        volume_shape=(16, 16, 16),
        box_size=(6, 6, 6),
        conv_features=(4,),
        hidden=(32,),
        max_episode_steps=16,
        batch_size=16,
        eps_decay_steps=100,
    )
    traffic = TrafficSpec(
        n_requests=args.requests,
        max_batch=args.max_batch,
        n_version_slots=args.version_slots,
        max_staleness=args.max_staleness,
        rate=args.rate,
        seed=args.seed,
    )
    session = build_session(cfg, n_agents=args.agents, traffic=traffic, seed=args.seed)
    report = run_session(
        session, traffic, n_waves=args.waves, train_steps=args.train_steps
    )
    s = report.summary()
    print(
        f"served {s['n_requests']} requests in {s['wall_time_s']:.2f}s "
        f"({s['requests_per_sec']:.1f} req/s)"
    )
    print(
        f"latency p50={s['p50_latency_ms']:.1f}ms p99={s['p99_latency_ms']:.1f}ms "
        f"ticks/req={s['ticks_per_request']:.1f} "
        f"queue depth mean={s['mean_queue_depth']:.1f}"
    )
    print(
        f"hot swaps={s['n_swaps']} versions_served={s['versions_served']} "
        f"stall_ticks={s['n_stall_ticks']}"
    )
    print(
        f"compiled buckets={session.service.buckets} "
        f"recompiles_after_warmup={s['recompiles']}"
    )
    if s["mean_dist_err"] is not None:
        print(f"mean_dist_err={s['mean_dist_err']:.2f} voxels (synthetic landmarks)")
    if args.json:
        payload = {"benchmark": "serve", "fast": False, "configs": {"fleet": s}}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    return 0 if s["recompiles"] == 0 else 1


def _transformer_main(args) -> int:
    cfg = get_config(args.arch)
    policy = ShardingPolicy()
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    prefill = jax.jit(make_prefill_step(cfg, policy=policy))
    serve = jax.jit(make_serve_step(cfg, policy=policy), donate_argnums=1)

    rng = np.random.default_rng(args.seed)
    b, s = args.batch, args.prompt_len
    total = s + args.gen
    if cfg.input_kind == "embeds":
        emb = rng.standard_normal((b, s, cfg.d_model)).astype(np.float32)
        batch = {"embeds": jnp.asarray(emb)}
    else:
        tok0 = rng.integers(0, cfg.vocab_size, (b, s))
        batch = {"tokens": jnp.asarray(tok0, jnp.int32)}

    t0 = time.time()
    last_logits, pre_caches = prefill(params, batch)
    dt = time.time() - t0
    print(f"prefill [{b}x{s}] in {dt:.2f}s")

    # decode caches sized for the full conversation; copy prefill k/v in.
    caches = init_caches(cfg, b, total)
    caches = _load_prefill(cfg, caches, pre_caches, s)

    tok = jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen):
        step_batch = {"pos": jnp.full((b,), s + i, jnp.int32)}
        if cfg.input_kind == "embeds":
            step_batch["embeds"] = jnp.zeros((b, 1, cfg.d_model), jnp.float32)
        else:
            step_batch["tokens"] = tok
        logits, caches = serve(params, caches, step_batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(tok))
    dt = time.time() - t0
    tok_s = args.gen * b / dt
    print(f"decoded {args.gen} tokens x {b} reqs in {dt:.2f}s ({tok_s:.1f} tok/s)")
    print("sample token ids:", np.concatenate(out_tokens, 1)[0][:16])
    return 0


def _load_prefill(cfg, caches, pre_caches, s):
    """Copy prefill k/v (and recurrent states) into the decode caches.

    Every prefill leaf must either match its decode leaf exactly or be a
    same-rank prefix of it (kv caches sized for the full conversation);
    anything else is a wiring bug, and silently keeping the zero decode
    cache would serve garbage — raise instead.
    """

    def copy_leaf(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        if dst.ndim == src.ndim and all(
            sd <= dd for sd, dd in zip(src.shape, dst.shape)
        ):
            # group-stacked kv: [G, B, S_cache, H, D] <- [G, B, s, H, D]
            sl = tuple(slice(0, d) for d in src.shape)
            return dst.at[sl].set(src.astype(dst.dtype))
        raise ValueError(
            f"prefill cache leaf {src.shape} does not fit decode cache "
            f"leaf {dst.shape} (rank or axis mismatch)"
        )

    return jax.tree_util.tree_map(copy_leaf, caches, pre_caches)


if __name__ == "__main__":
    import sys

    sys.exit(main())
