"""Serving driver: prefill a batch of requests, then batched decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b-smoke \
        --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models.model import (
    init_caches,
    init_params,
    make_prefill_step,
    make_serve_step,
)
from repro.models.sharding import ShardingPolicy


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    policy = ShardingPolicy()
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    prefill = jax.jit(make_prefill_step(cfg, policy=policy))
    serve = jax.jit(make_serve_step(cfg, policy=policy), donate_argnums=1)

    rng = np.random.default_rng(args.seed)
    b, s = args.batch, args.prompt_len
    total = s + args.gen
    if cfg.input_kind == "embeds":
        emb = rng.standard_normal((b, s, cfg.d_model)).astype(np.float32)
        batch = {"embeds": jnp.asarray(emb)}
    else:
        tok0 = rng.integers(0, cfg.vocab_size, (b, s))
        batch = {"tokens": jnp.asarray(tok0, jnp.int32)}

    t0 = time.time()
    last_logits, pre_caches = prefill(params, batch)
    dt = time.time() - t0
    print(f"prefill [{b}x{s}] in {dt:.2f}s")

    # decode caches sized for the full conversation; copy prefill k/v in.
    caches = init_caches(cfg, b, total)
    caches = _load_prefill(cfg, caches, pre_caches, s)

    tok = jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen):
        step_batch = {"pos": jnp.full((b,), s + i, jnp.int32)}
        if cfg.input_kind == "embeds":
            step_batch["embeds"] = jnp.zeros((b, 1, cfg.d_model), jnp.float32)
        else:
            step_batch["tokens"] = tok
        logits, caches = serve(params, caches, step_batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(tok))
    dt = time.time() - t0
    tok_s = args.gen * b / dt
    print(f"decoded {args.gen} tokens x {b} reqs in {dt:.2f}s ({tok_s:.1f} tok/s)")
    print("sample token ids:", np.concatenate(out_tokens, 1)[0][:16])


def _load_prefill(cfg, caches, pre_caches, s):
    """Copy prefill k/v (and recurrent states) into the decode caches."""

    def copy_leaf(dst, src):
        try:
            if dst.shape == src.shape:
                return src.astype(dst.dtype)
            # group-stacked kv: [G, B, S_cache, H, D] <- [G, B, s, H, D]
            sl = tuple(slice(0, d) for d in src.shape)
            return dst.at[sl].set(src.astype(dst.dtype))
        except Exception:
            return dst

    return jax.tree_util.tree_map(copy_leaf, caches, pre_caches)


if __name__ == "__main__":
    main()
