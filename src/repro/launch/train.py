"""Training driver.

CPU: run any ``<arch>-smoke`` reduced config for real steps on the
synthetic pipeline. TPU pod: the same entry point with the production mesh
(the dry-run proves the sharded lowering; this driver is what a cluster
launcher would invoke on real hardware).

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m-smoke \
        --steps 20 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs.base import get_config
from repro.data.pipeline import TokenStreamConfig, token_batches
from repro.launch.specs import opt_cfg_for
from repro.models.model import init_train_state, make_train_step
from repro.models.sharding import ShardingPolicy


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, help="config id; use <id>-smoke on CPU")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument(
        "--production-mesh",
        action="store_true",
        help="build the 16x16 mesh (TPU pods)",
    )
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    mesh = None
    policy = ShardingPolicy()
    if args.production_mesh:
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh()

    opt_cfg = opt_cfg_for(cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(args.seed), opt_cfg)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(state["params"]))
    n_m = n_params / 1e6
    print(f"{cfg.name}: {n_m:.2f}M params, {cfg.n_layers}L d={cfg.d_model}")

    step = jax.jit(
        make_train_step(cfg, opt_cfg, mesh=mesh, policy=policy),
        donate_argnums=0,
    )
    sc = TokenStreamConfig(
        vocab_size=cfg.vocab_size,
        seq_len=args.seq,
        batch_size=args.batch,
        seed=args.seed,
    )
    stream = token_batches(sc)
    rng = np.random.default_rng(args.seed)

    t0 = time.time()
    for i in range(args.steps):
        raw = next(stream)
        if cfg.input_kind == "embeds":
            emb = rng.standard_normal((args.batch, args.seq, cfg.d_model)).astype(
                np.float32
            )
            batch = {
                "embeds": jnp.asarray(emb),
                "labels": jnp.asarray(raw["labels"] % cfg.vocab_size),
            }
        else:
            batch = {
                "tokens": jnp.asarray(raw["tokens"] % cfg.vocab_size),
                "labels": jnp.asarray(raw["labels"] % cfg.vocab_size),
            }
        state, metrics = step(state, batch)
        if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
            loss = float(metrics["loss"])
            gnorm = float(metrics["grad_norm"])
            print(f"step {i:4d} loss={loss:.4f} gnorm={gnorm:.3f}")
    dt = time.time() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"{args.steps} steps in {dt:.1f}s ({tok_s:.0f} tok/s)")
    if args.ckpt:
        save_pytree(args.ckpt, state["params"])
        print(f"saved params -> {args.ckpt}")


if __name__ == "__main__":
    main()
