"""Roofline analysis sources.

``compiled.cost_analysis()`` reports while-loop bodies ONCE (trip count is
not modelled), so a scan-over-layers model under-reports FLOPs by ~n_layers.
We therefore derive:

* FLOPs — exact traversal of the closed jaxpr (scan bodies multiplied by
  their static trip count, shard_map bodies by the mesh size). This counts
  GLOBAL (whole-cluster) FLOPs.
* collective bytes — parsed from the optimized (post-SPMD, per-device) HLO
  text; collectives inside ``while`` bodies are multiplied by the trip
  count recovered from the loop condition's comparison constant.
* memory traffic — an explicit analytic model (params + optimizer +
  activation checkpoints + KV-cache reads), stated in EXPERIMENTS.md.

``cost_analysis()`` numbers are still recorded for reference.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict

import jax
import numpy as np

# ---------------------------------------------------------------------------
# jaxpr FLOP counter
# ---------------------------------------------------------------------------
_ELEMENTWISE_1 = {
    "add",
    "sub",
    "mul",
    "div",
    "max",
    "min",
    "neg",
    "abs",
    "floor",
    "ceil",
    "round",
    "sign",
    "and",
    "or",
    "xor",
    "not",
    "select_n",
    "clamp",
    "rem",
    "pow",
    "integer_pow",
}
_ELEMENTWISE_T = {  # transcendental: count a few flops each
    "exp",
    "log",
    "tanh",
    "logistic",
    "sin",
    "cos",
    "sqrt",
    "rsqrt",
    "erf",
    "exp2",
    "log1p",
    "expm1",
    "cbrt",
    "tan",
    "atan2",
}
_REDUCE = {
    "reduce_sum",
    "reduce_max",
    "reduce_min",
    "reduce_prod",
    "reduce_and",
    "reduce_or",
    "argmax",
    "argmin",
    "cumsum",
    "cumprod",
    "cummax",
    "cummin",
    "reduce_precision",
}


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 1


def _sub_jaxprs(params):
    """Yield every Jaxpr held in an eqn's params (generic recursion)."""
    for v in params.values():
        tn = type(v).__name__
        if tn == "ClosedJaxpr":
            yield v.jaxpr
        elif tn == "Jaxpr":
            yield v
        elif isinstance(v, (list, tuple)):
            for u in v:
                un = type(u).__name__
                if un == "ClosedJaxpr":
                    yield u.jaxpr
                elif un == "Jaxpr":
                    yield u


def _jaxpr_flops(jaxpr, n_shards: int = 1) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            dnums = eqn.params["dimension_numbers"]
            (lc, _), _b = dnums
            lhs = eqn.invars[0].aval
            k = math.prod(lhs.shape[d] for d in lc) or 1
            out = _size(eqn.outvars[0].aval)
            total += 2.0 * out * k
        elif prim == "conv_general_dilated":
            rhs = eqn.invars[1].aval
            dn = eqn.params["dimension_numbers"]
            groups = eqn.params.get("feature_group_count", 1)
            k_spatial = math.prod(rhs.shape[d] for d in dn.rhs_spec[2:])
            cin = rhs.shape[dn.rhs_spec[1]]
            out = _size(eqn.outvars[0].aval)
            total += 2.0 * out * k_spatial * cin / max(groups, 1)
        elif prim == "scan":
            body = _jaxpr_flops(eqn.params["jaxpr"].jaxpr, n_shards)
            total += body * eqn.params["length"]
        elif prim == "cond":
            total += max(
                _jaxpr_flops(b.jaxpr, n_shards) for b in eqn.params["branches"]
            )
        elif prim == "shard_map":
            for sub in _sub_jaxprs(eqn.params):
                total += _jaxpr_flops(sub, 1) * n_shards
        elif prim in _ELEMENTWISE_1 or prim == "add_any":
            total += _size(eqn.outvars[0].aval)
        elif prim in _ELEMENTWISE_T:
            total += 5.0 * _size(eqn.outvars[0].aval)
        elif prim in _REDUCE:
            total += _size(eqn.invars[0].aval)
        else:
            # generic recursion (pjit, remat2, custom_vjp, ...)
            for sub in _sub_jaxprs(eqn.params):
                total += _jaxpr_flops(sub, n_shards)
    return total


def count_flops(fn, *args, n_shards: int = 1, **kw) -> float:
    """Global FLOPs of fn(*args) — exact for scan/shard_map programs."""
    jaxpr = jax.make_jaxpr(fn, **kw)(*args)
    return _jaxpr_flops(jaxpr.jaxpr, n_shards)


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------
_DTYPE_BYTES = {
    "f64": 8,
    "f32": 4,
    "bf16": 2,
    "f16": 2,
    "s64": 8,
    "u64": 8,
    "s32": 4,
    "u32": 4,
    "s16": 2,
    "u16": 2,
    "s8": 1,
    "u8": 1,
    "pred": 1,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(sig: str) -> int:
    """'f32[16,128]' -> bytes; tuples summed by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(sig):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict[str, float]:
    """Per-device collective bytes by type, while-bodies scaled by trip
    count. Returns {'all-gather': bytes, ..., 'total': bytes}."""
    # split into computations
    comps: dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$", line)
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
        elif cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)

    # map: computation -> list of (collective_kind, bytes)
    coll: dict[str, list] = defaultdict(list)
    # map: computation -> list of (called_comp, kind) for while/call ops
    calls: dict[str, list] = defaultdict(list)
    trip_hint: dict[str, int] = {}

    for cname, lines in comps.items():
        for line in lines:
            s = line.strip()
            m = re.match(r"%?[\w\.\-]+ = (.+?) ([\w\-]+)\(", s)
            if not m:
                continue
            sig, op = m.groups()
            base = op.split(".")[0]
            if base in _COLLECTIVES:
                coll[cname].append((base, _shape_bytes(sig)))
            elif base == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", s)
                cm = re.search(r"condition=%?([\w\.\-]+)", s)
                if bm:
                    calls[cname].append((bm.group(1), cm.group(1) if cm else None))
            elif base in ("call", "fusion", "conditional"):
                for sub in re.findall(r"(?:calls|to_apply)=%?([\w\.\-]+)", s):
                    calls[cname].append((sub, None))
                branch_re = (
                    r"(?:true_computation|false_computation"
                    r"|branch_computations)=\{?%?([\w\.\-, %]+)"
                )
                for sub in re.findall(branch_re, s):
                    for c2 in re.split(r"[,\s%]+", sub):
                        if c2:
                            calls[cname].append((c2, None))
        # trip count: biggest integer constant compared in a condition comp
        consts = [
            int(v) for line in lines for v in re.findall(r"constant\((\d+)\)", line)
        ]
        if consts:
            trip_hint[cname] = max(consts)

    def bytes_of(comp: str, seen) -> dict[str, float]:
        if comp in seen or comp not in comps:
            return {}
        seen = seen | {comp}
        out: dict[str, float] = defaultdict(float)
        for kind, b in coll.get(comp, []):
            out[kind] += b
        for sub, cond in calls.get(comp, []):
            subbytes = bytes_of(sub, seen)
            trips = trip_hint.get(cond, 1) if cond else 1
            for k, v in subbytes.items():
                out[k] += v * max(trips, 1)
        return out

    if entry is None:
        for cname in comps:
            if "entry" in cname.lower() or cname.startswith("main"):
                entry = cname
                break
    if entry is None and comps:
        entry = next(iter(comps))
    result = dict(bytes_of(entry, frozenset())) if entry else {}
    result["total"] = float(sum(v for k, v in result.items()))
    return result


def top_collectives(hlo_text: str, n: int = 20):
    """Debug attribution: the n largest individual collective op lines
    (per-device bytes; while-trip multiplication NOT applied here)."""
    out = []
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?([\w\.\-]+) = (.+?) ([\w\-]+)\(", s)
        if not m:
            continue
        name, sig, op = m.groups()
        base = op.split(".")[0]
        if base in _COLLECTIVES:
            meta = ""
            mm = re.search(r'op_name="([^"]+)"', s)
            if mm:
                meta = mm.group(1)[-90:]
            out.append((_shape_bytes(sig), base, sig[:48], meta))
    out.sort(reverse=True)
    return out[:n]


# ---------------------------------------------------------------------------
# analytic HBM-traffic model (per device, per step)
# ---------------------------------------------------------------------------
def analytic_hbm_bytes(
    *,
    mode: str,
    param_bytes_dev: float,
    opt_bytes_dev: float,
    act_bytes_dev: float,
    cache_bytes_dev: float,
    io_bytes_dev: float,
) -> dict[str, float]:
    """Assumptions (documented in EXPERIMENTS.md §Roofline):
    train : params read fwd + read bwd + write; grads write+read;
            moments read+write; checkpointed activations write+read plus
            one recompute read (remat); batch io once.
    prefill: params read once; activations write once; io once.
    decode: params read once (the decode wall); cache read + small write.
    """
    if mode == "train":
        grads = 2 * param_bytes_dev  # grads ~ params
        total = (
            3 * param_bytes_dev
            + grads
            + 2 * opt_bytes_dev
            + 3 * act_bytes_dev
            + io_bytes_dev
        )
    elif mode == "prefill":
        total = param_bytes_dev + 2 * act_bytes_dev + cache_bytes_dev + io_bytes_dev
    else:  # decode
        total = param_bytes_dev + cache_bytes_dev + io_bytes_dev
    return {"total": float(total)}
