"""Production mesh definitions (TPU v5e targets).

Functions, not module-level constants: importing this module never touches
jax device state (smoke tests must keep seeing 1 CPU device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (per chip) — roofline denominators.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
