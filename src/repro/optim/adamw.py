"""AdamW with global-norm clipping and cosine schedule (pure pytree ops).

Optimizer moments are stored in ``opt_dtype`` (f32 by default; bf16 for the
largest zoo configs where f32 moments would not fit the per-device HBM
budget — see DESIGN.md §5).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    opt_dtype: str = "float32"


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    frac = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps
    )
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(cfg: AdamWConfig, params):
    dt = jnp.dtype(cfg.opt_dtype)

    def zeros(p):
        return jnp.zeros(p.shape, dt)

    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_schedule(cfg, count)
    c = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**c
    bc2 = 1.0 - cfg.b2**c
    dt = jnp.dtype(cfg.opt_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        pnew = p.astype(jnp.float32) - lr * step
        return pnew.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [
        upd(p, g, m, v)
        for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v, strict=True)
    ]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, gnorm
