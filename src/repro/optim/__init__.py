from repro.optim.adamw import adamw_init, adamw_update, cosine_schedule  # noqa: F401
