"""Declarative population descriptions: one frozen spec for who joins,
when, how fast they are, and when they are reachable.

A :class:`PopulationSpec` unifies what used to be three ad-hoc scenario
channels — timed ``ChurnEvent`` schedules, ``HubFailure`` schedules, and
the implicit per-agent speed tuple — into one description of a *fleet
population*:

* :class:`Cohort` — a homogeneous slice of agents: arrival window,
  optional permanent departure, base speed with an optional lognormal
  straggler tail (compute heterogeneity as per-agent step-time
  multipliers), hub preference, and an availability process;
* :class:`Departure` — a timed removal of live agents (the paper's
  deletion ablation: newest joiners retire first);
* :class:`HubOutage` — a timed hub death (the paper's Table 2).

Availability processes come in three kinds, all deterministic functions
of the scenario seed (FLGo-style trace-driven client simulation):

* :class:`Diurnal` — day/night duty cycles with per-agent phase jitter;
* :class:`Sessions` — distribution-driven on/off session lengths;
* :class:`Trace` — replayable explicit windows (inline or loaded from a
  JSONL trace file via :mod:`repro.population.trace`).

Nothing here touches a scheduler: the spec is pure data, compiled onto a
running system by :func:`repro.population.compile.compile_onto`.
"""

from __future__ import annotations

from collections.abc import Sequence
from dataclasses import dataclass, replace

from repro.core.experiment import ChurnEvent, HubFailure

# ---------------------------------------------------------------------------
# availability processes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Diurnal:
    """Day/night duty cycle: online for the first ``on_fraction`` of
    every ``period``, starting ``phase`` into the cycle at join time.

    ``jitter`` (fraction of a period) adds a per-agent uniform phase
    shift drawn from the population stream, so a cohort's members do not
    all drop at the same instant.
    """

    period: float = 2.0
    on_fraction: float = 0.5
    phase: float = 0.0
    jitter: float = 0.0

    def __post_init__(self):
        if self.period <= 0.0:
            raise ValueError(f"period must be positive: {self.period}")
        if not 0.0 < self.on_fraction <= 1.0:
            raise ValueError(f"on_fraction not in (0, 1]: {self.on_fraction}")
        if self.jitter < 0.0:
            raise ValueError(f"negative jitter: {self.jitter}")


@dataclass(frozen=True)
class Sessions:
    """Alternating online/offline sessions with distribution-driven
    lengths (mean ``mean_on`` / ``mean_off``): ``"exp"`` (memoryless),
    ``"lognormal"`` (heavy-tailed, shape ``sigma``), or ``"fixed"``.
    Agents join online."""

    mean_on: float = 1.0
    mean_off: float = 1.0
    distribution: str = "exp"  # exp | lognormal | fixed
    sigma: float = 1.0

    def __post_init__(self):
        if self.mean_on <= 0.0 or self.mean_off <= 0.0:
            raise ValueError("session means must be positive")
        if self.distribution not in ("exp", "lognormal", "fixed"):
            raise ValueError(f"unknown distribution: {self.distribution!r}")


@dataclass(frozen=True)
class Trace:
    """Replayable availability windows.

    The agent is online during each ``(on, off)`` window (times relative
    to its join), offline between them.  ``stagger`` shifts member ``k``
    of a cohort by ``k * stagger``.  With ``repeat`` the windows tile
    every ``repeat`` time units forever; without it the agent comes back
    online after the last window and stays — a finite trace describes
    the disturbed prefix of a run, and a permanently-offline tail would
    deadlock the round policy.  Load windows from a JSONL trace file
    with :func:`repro.population.trace.load_windows`.
    """

    windows: tuple[tuple[float, float], ...] = ()
    stagger: float = 0.0
    repeat: float | None = None

    def __post_init__(self):
        last = 0.0
        for on, off in self.windows:
            if on < last or off <= on:
                raise ValueError(f"windows not disjoint/increasing: {self.windows}")
            last = off
        if self.repeat is not None and self.repeat < last:
            raise ValueError(f"repeat {self.repeat} shorter than the windows")


Availability = Diurnal | Sessions | Trace


# ---------------------------------------------------------------------------
# population structure
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Cohort:
    """One homogeneous slice of the population.

    Members join uniformly over ``[arrive_at, arrive_at + arrive_spread]``
    (a point in time when the spread is 0), each with speed ``speed``
    scaled by a per-agent lognormal multiplier of shape ``speed_sigma``
    (0 = homogeneous; larger values grow a long tail of stragglers —
    speed divides round duration, so a small multiplier is a slow
    machine).  ``depart_at`` removes every member permanently at that
    time; ``availability`` drives the member's online/offline timeline
    while it lives (None = always on).
    """

    n_agents: int
    name: str = ""
    arrive_at: float = 0.0
    arrive_spread: float = 0.0
    depart_at: float | None = None
    speed: float = 1.0
    speed_sigma: float = 0.0
    hub: int | None = None
    availability: Availability | None = None

    def __post_init__(self):
        if self.n_agents < 1:
            raise ValueError(f"cohort needs n_agents >= 1: {self.n_agents}")
        if self.arrive_at < 0.0 or self.arrive_spread < 0.0:
            raise ValueError("negative arrival window")
        if self.depart_at is not None and self.depart_at <= self.arrive_at:
            raise ValueError("depart_at must be after arrive_at")
        if self.speed <= 0.0 or self.speed_sigma < 0.0:
            raise ValueError("speed must be positive, speed_sigma >= 0")


@dataclass(frozen=True)
class Departure:
    """Timed removal of live agents: ``agent_id`` when given, else the
    ``count`` newest joiners (the paper's deletion-ablation order)."""

    at: float
    count: int = 1
    agent_id: int | None = None

    def __post_init__(self):
        if self.agent_id is not None and self.count != 1:
            raise ValueError("explicit agent_id implies count=1")


@dataclass(frozen=True)
class HubOutage:
    """Timed hub death (the paper's Table 2 as a population event)."""

    at: float
    hub_id: int

    def __post_init__(self):
        if self.hub_id < 0:
            raise ValueError(f"negative hub_id: {self.hub_id}")


@dataclass(frozen=True)
class PopulationSpec:
    """The whole population of a scenario, incumbents included.

    When a :class:`~repro.experiments.spec.ScenarioSpec` carries a
    population, the runner builds the system *empty* and compiles this
    spec onto its scheduler: every agent arrives through a cohort
    (``arrive_at=0`` cohorts are the incumbents).  Same-time events
    apply joins before departures before hub outages — a defined order,
    independent of construction order.
    """

    cohorts: tuple[Cohort, ...] = ()
    departures: tuple[Departure, ...] = ()
    hub_outages: tuple[HubOutage, ...] = ()

    def __post_init__(self):
        if not (self.cohorts or self.departures or self.hub_outages):
            raise ValueError("empty population: no cohorts, departures, or outages")

    @property
    def n_agents(self) -> int:
        """Total agents ever joining (not live at any one time)."""
        return sum(c.n_agents for c in self.cohorts)

    def event_times(self) -> tuple[float, ...]:
        """Sorted distinct times of the discrete membership events
        (cohort arrivals/departures, timed departures, hub outages) —
        what the runner probes evaluation at.  Availability toggles are
        continuous dynamics, not probe points."""
        times = set()
        for c in self.cohorts:
            times.add(c.arrive_at)
            if c.depart_at is not None:
                times.add(c.depart_at)
        times |= {d.at for d in self.departures}
        times |= {o.at for o in self.hub_outages}
        return tuple(sorted(times))

    def scaled(self, frac: float) -> "PopulationSpec":
        """The CI-sized population: every cohort shrunk to
        ``max(1, round(n_agents * frac))`` members, dynamics unchanged."""
        if frac == 1.0:
            return self
        return replace(
            self,
            cohorts=tuple(
                replace(c, n_agents=max(1, round(c.n_agents * frac)))
                for c in self.cohorts
            ),
        )

    @staticmethod
    def from_churn(
        events: Sequence[ChurnEvent] = (),
        hub_failures: Sequence[HubFailure] = (),
    ) -> "PopulationSpec":
        """Lift classic churn/hub-failure schedules into a population —
        the bridge the ``ADFLLSystem.schedule_churn`` /
        ``schedule_hub_failures`` shims ride.  Each ``add`` becomes a
        point-arrival cohort, each ``remove`` a :class:`Departure`, each
        :class:`~repro.core.experiment.HubFailure` a :class:`HubOutage`.
        """
        cohorts, departures = [], []
        for ev in sorted(events, key=lambda e: e.at):
            if ev.action == "add":
                cohorts.append(
                    Cohort(
                        n_agents=ev.count,
                        arrive_at=ev.at,
                        speed=ev.speed,
                        hub=ev.hub,
                    )
                )
            else:
                departures.append(
                    Departure(at=ev.at, count=ev.count, agent_id=ev.agent_id)
                )
        outages = tuple(
            HubOutage(at=f.at, hub_id=f.hub_id)
            for f in sorted(hub_failures, key=lambda f: f.at)
        )
        return PopulationSpec(
            cohorts=tuple(cohorts),
            departures=tuple(departures),
            hub_outages=outages,
        )


__all__ = [
    "Availability",
    "Cohort",
    "Departure",
    "Diurnal",
    "HubOutage",
    "PopulationSpec",
    "Sessions",
    "Trace",
]
