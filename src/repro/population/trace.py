"""Replayable availability trace files.

A trace file is JSONL: one ``{"on": t0, "off": t1}`` object per line,
times relative to the agent's join, windows disjoint and increasing —
the exact contract of :class:`repro.population.spec.Trace`.  Traces
round-trip losslessly (floats serialized with ``repr`` precision), so a
recorded availability timeline replays bit-identically.
"""

from __future__ import annotations

import json
from collections.abc import Sequence
from pathlib import Path

PathLike = str | Path


def load_windows(path: PathLike) -> tuple[tuple[float, float], ...]:
    """Read ``(on, off)`` windows from a JSONL trace file."""
    windows = []
    for i, line in enumerate(Path(path).read_text().splitlines()):
        line = line.strip()
        if not line:
            continue
        row = json.loads(line)
        try:
            windows.append((float(row["on"]), float(row["off"])))
        except (KeyError, TypeError) as e:
            raise ValueError(f"{path}:{i + 1}: bad trace row {line!r}") from e
    return tuple(windows)


def save_windows(path: PathLike, windows: Sequence[tuple[float, float]]) -> None:
    """Write ``(on, off)`` windows as a JSONL trace file."""
    lines = [json.dumps({"on": on, "off": off}) for on, off in windows]
    Path(path).write_text("\n".join(lines) + ("\n" if lines else ""))


__all__ = ["load_windows", "save_windows"]
