"""Trace-driven population simulation for ADFLL experiments.

One declarative :class:`PopulationSpec` describes the whole fleet —
cohorts with arrival windows, per-agent compute heterogeneity, diurnal /
session / trace availability, timed departures, and hub outages — and
is compiled onto the system's discrete-event scheduler by the runner.
See the README "Population dynamics" section for the migration path
from hand-placed ``ChurnEvent`` schedules.
"""

from repro.population.compile import PopulationState, compile_onto, member_rng
from repro.population.processes import AvailabilityProcess, availability_segments
from repro.population.spec import (
    Availability,
    Cohort,
    Departure,
    Diurnal,
    HubOutage,
    PopulationSpec,
    Sessions,
    Trace,
)
from repro.population.trace import load_windows, save_windows

__all__ = [
    "Availability",
    "AvailabilityProcess",
    "Cohort",
    "Departure",
    "Diurnal",
    "HubOutage",
    "PopulationSpec",
    "PopulationState",
    "Sessions",
    "Trace",
    "availability_segments",
    "compile_onto",
    "load_windows",
    "member_rng",
    "save_windows",
]
