"""Availability processes: pure segment generators plus the scheduler
driver that toggles one agent online/offline.

The split keeps determinism testable without a system: given the same
availability spec, rng stream, and member index,
:func:`availability_segments` yields a bit-identical timeline — the
scheduler driver (:class:`AvailabilityProcess`) only walks it.
"""

from __future__ import annotations

import math
from collections.abc import Callable, Iterator

import numpy as np

from repro.core.scheduler import Handle, Scheduler
from repro.population.spec import Availability, Diurnal, Sessions, Trace

Segment = tuple[float, float]  # (duration, online) with online in {0.0, 1.0}


def availability_segments(
    avail: Availability,
    rng: np.random.Generator,
    member_idx: int = 0,
) -> Iterator[tuple[float, bool]]:
    """Yield ``(duration, online)`` segments from the agent's join time.

    The generator is infinite for cyclic processes; a *finite* generator
    means the agent is online forever afterwards (a finite trace is the
    disturbed prefix of a run — a permanently-offline tail would
    deadlock the round policy).
    """
    if isinstance(avail, Diurnal):
        yield from _diurnal_segments(avail, rng)
    elif isinstance(avail, Sessions):
        yield from _session_segments(avail, rng)
    elif isinstance(avail, Trace):
        yield from _trace_segments(avail, member_idx)
    else:  # pragma: no cover - spec.Availability is a closed union
        raise TypeError(f"unknown availability process: {avail!r}")


def _diurnal_segments(avail: Diurnal, rng: np.random.Generator):
    period = avail.period
    on_len = avail.on_fraction * period
    off_len = period - on_len
    if off_len <= 0.0:
        return  # on_fraction == 1: always online
    p = (avail.phase + avail.jitter * period * float(rng.uniform())) % period
    if p < on_len:
        # p into the on-window: finish it, then the off-window, then cycle
        yield on_len - p, True
        yield off_len, False
    else:
        yield period - p, False
    while True:
        yield on_len, True
        yield off_len, False


def _session_segments(avail: Sessions, rng: np.random.Generator):
    if avail.distribution == "lognormal":
        # parameterize so the draw's *mean* is the configured mean
        def draw(mean: float) -> float:
            mu = math.log(mean) - 0.5 * avail.sigma**2
            return float(rng.lognormal(mu, avail.sigma))

    elif avail.distribution == "exp":

        def draw(mean: float) -> float:
            return float(rng.exponential(mean))

    else:  # fixed

        def draw(mean: float) -> float:
            return mean

    while True:
        yield draw(avail.mean_on), True
        yield draw(avail.mean_off), False


def _trace_segments(avail: Trace, member_idx: int):
    if not avail.windows:
        return  # empty trace: always online
    shift = member_idx * avail.stagger
    t = 0.0
    tile = 0
    while True:
        base = shift + (0.0 if avail.repeat is None else tile * avail.repeat)
        for on, off in avail.windows:
            on_t, off_t = on + base, off + base
            if on_t > t:
                yield on_t - t, False
            yield off_t - max(on_t, t), True
            t = off_t
        if avail.repeat is None:
            return  # online after the last window, forever
        tile += 1


class AvailabilityProcess:
    """Walks one agent's segment stream on the scheduler.

    Each state change is one scheduled event (cheap even for long runs);
    ``stop()`` — called when the agent departs — cancels the pending
    toggle through its :class:`~repro.core.scheduler.Handle`, which is
    safe even from inside the toggle's own callback.
    """

    def __init__(
        self,
        sched: Scheduler,
        agent_id: int,
        segments: Iterator[tuple[float, bool]],
        set_online: Callable[[int, bool], None],
        tag: str = "",
    ):
        self.sched = sched
        self.agent_id = agent_id
        self._segments = segments
        self._set_online = set_online
        self._tag = tag or f"A{agent_id}_avail"
        self._handle: Handle | None = None
        self.stopped = False

    def start(self) -> None:
        """Apply the first segment's state now and arm the next toggle."""
        self._advance(self.sched, self.sched.now)

    def stop(self) -> None:
        self.stopped = True
        if self._handle is not None:
            self._handle.cancel()

    def _advance(self, sched: Scheduler, t: float) -> None:
        if self.stopped:
            return
        seg = next(self._segments, None)
        if seg is None:
            # finite stream exhausted: online for good
            self._set_online(self.agent_id, True)
            return
        duration, online = seg
        self._set_online(self.agent_id, bool(online))
        self._handle = sched.at(t + duration, self._advance, tag=self._tag)


__all__ = ["AvailabilityProcess", "availability_segments"]
