"""Compile a :class:`~repro.population.spec.PopulationSpec` onto a
running system's scheduler.

Everything an agent population does — cohort arrivals, timed departures,
hub outages, availability toggles — becomes ordinary scheduler events
feeding the system's existing churn machinery (``_apply_churn`` /
``_apply_hub_failure``), so the ``done()`` accounting, lifecycle hooks,
and CI-gated churn behavior are shared, not reimplemented.  Simple
point-arrival cohorts (no spread, no straggler tail, no availability)
compile to the *same single grouped event* the classic
``schedule_churn`` emitted, which is what keeps the shim bit-identical.

The system is duck-typed (``sched`` / ``seed`` / ``sys_cfg`` /
``network`` / ``set_online`` / ``_apply_churn`` / ``_apply_hub_failure``
/ ``_pending_churn`` / ``_pending_failures``): this module must not
import :mod:`repro.core.federated`, which imports it back.

Every per-member random draw comes from
``np.random.default_rng((seed, _POP_STREAM, cohort_idx, member_idx))`` —
a pure function of the spec position and the ctor seed, disjoint from
the system's ``seed + k`` streams, so availability timelines are
bit-reproducible across processes.
"""

from __future__ import annotations

import hashlib

import numpy as np

from repro.core.experiment import ChurnEvent, HubFailure
from repro.population.processes import AvailabilityProcess, availability_segments
from repro.population.spec import Cohort, PopulationSpec

_POP_STREAM = 0x706F70  # "pop": keyed into the per-member rng spawn


class PopulationState:
    """Availability bookkeeping for one run: who joined when, who is
    online now, and the accumulated online time per agent.

    The system notifies it through ``note_join`` / ``note_toggle`` /
    ``note_depart`` (pure observers — they never touch the scheduler),
    gossip reads ``is_online`` through the system's availability view,
    and :meth:`summary` folds everything into the report's
    ``extra["population"]`` block, including a digest of the full
    timeline for bit-identity checks.
    """

    def __init__(self):
        self.joined: dict[int, float] = {}
        self.departed: dict[int, float] = {}
        self.speed: dict[int, float] = {}
        self.online_since: dict[int, float] = {}  # present iff online
        self.online_time: dict[int, float] = {}
        self.n_toggles = 0
        self.events: list[tuple[float, int, str]] = []
        self._processes: dict[int, AvailabilityProcess] = {}

    # -- observers wired into the system ------------------------------------
    def note_join(self, agent_id: int, t: float, speed: float) -> None:
        self.joined[agent_id] = t
        self.speed[agent_id] = speed
        self.online_since[agent_id] = t
        self.events.append((t, agent_id, "join"))

    def note_toggle(self, agent_id: int, online: bool, t: float) -> None:
        if agent_id not in self.joined or agent_id in self.departed:
            return
        if online == (agent_id in self.online_since):
            return  # idempotent: only state *changes* are events
        if online:
            self.online_since[agent_id] = t
        else:
            since = self.online_since.pop(agent_id)
            self.online_time[agent_id] = self.online_time.get(agent_id, 0.0) + (
                t - since
            )
        self.n_toggles += 1
        self.events.append((t, agent_id, "on" if online else "off"))

    def note_depart(self, agent_id: int, t: float) -> None:
        if agent_id in self.departed:
            return
        self.departed[agent_id] = t
        since = self.online_since.pop(agent_id, None)
        if since is not None:
            self.online_time[agent_id] = self.online_time.get(agent_id, 0.0) + (
                t - since
            )
        self.events.append((t, agent_id, "depart"))
        proc = self._processes.pop(agent_id, None)
        if proc is not None:
            proc.stop()

    def register_process(self, agent_id: int, proc: AvailabilityProcess) -> None:
        self._processes[agent_id] = proc

    # -- queries -------------------------------------------------------------
    def is_online(self, agent_id: int) -> bool:
        return agent_id in self.online_since

    def timeline_digest(self) -> str:
        """Stable digest of the full (time, agent, kind) event timeline;
        ``repr`` keeps float bits exact, so equal digests mean
        bit-identical availability histories."""
        text = "\n".join(f"{t!r} {aid} {kind}" for t, aid, kind in self.events)
        return hashlib.sha256(text.encode()).hexdigest()[:16]

    def summary(self, makespan: float) -> dict[str, object]:
        online = dict(self.online_time)
        for aid, since in self.online_since.items():
            online[aid] = online.get(aid, 0.0) + max(0.0, makespan - since)
        agent_time = sum(
            self.departed.get(aid, makespan) - t0 for aid, t0 in self.joined.items()
        )
        total_online = sum(online.values())
        step_times = [1.0 / s for s in self.speed.values()]
        return {
            "n_agents": len(self.joined),
            "n_departed": len(self.departed),
            "n_toggles": self.n_toggles,
            "agent_time": round(agent_time, 9),
            "online_time": round(total_online, 9),
            "availability": (
                round(total_online / agent_time, 9) if agent_time > 0 else 1.0
            ),
            "mean_step_time": (
                round(float(np.mean(step_times)), 9) if step_times else 1.0
            ),
            "timeline_digest": self.timeline_digest(),
        }


def _is_simple(c: Cohort) -> bool:
    """A cohort the classic churn path could have expressed: one grouped
    join event, no per-member randomness, no availability, no departure."""
    return (
        c.arrive_spread == 0.0
        and c.speed_sigma == 0.0
        and c.availability is None
        and c.depart_at is None
    )


def member_rng(seed: int, cohort_idx: int, member_idx: int) -> np.random.Generator:
    """The per-member stream: arrival offset, speed multiplier, and the
    availability process all draw from it, in that order."""
    return np.random.default_rng((seed, _POP_STREAM, cohort_idx, member_idx))


def compile_onto(system, pop: PopulationSpec) -> PopulationState:
    """Schedule every population event onto ``system.sched``.

    Same-time ordering is defined: joins, then departures, then hub
    outages (scheduling order + the scheduler's insertion-order ties).
    Hub outages are validated up front — bad specs raise before anything
    is scheduled, matching the classic ``schedule_hub_failures``
    contract.  Idempotent across calls on the shared state: the churn
    and hub-failure shims may each compile their own partial spec.
    """
    state = getattr(system, "population", None)
    if state is None:
        state = PopulationState()
        system.population = state
    sched = system.sched

    if pop.hub_outages:
        if system.sys_cfg.topology == "gossip":
            raise ValueError("topology='gossip' has no hubs to fail")
        for o in pop.hub_outages:
            if o.hub_id >= len(system.network.hubs):
                raise ValueError(
                    f"hub_id {o.hub_id} out of range "
                    f"(n_hubs={len(system.network.hubs)})"
                )

    for ci, c in enumerate(pop.cohorts):
        if _is_simple(c):
            # classic grouped join: value-equal ChurnEvent, same tag, same
            # pending accounting — bit-identical to old schedule_churn
            ev = ChurnEvent(
                at=c.arrive_at, action="add", count=c.n_agents, speed=c.speed, hub=c.hub
            )
            system._pending_churn += 1
            sched.at(ev.at, lambda s, t, e=ev: system._apply_churn(e, t), tag="churn")
            continue
        for mi in range(c.n_agents):
            rng = member_rng(system.seed, ci, mi)
            u = float(rng.uniform())
            z = float(rng.standard_normal())
            arrival = c.arrive_at + c.arrive_spread * u
            speed = c.speed * (
                float(np.exp(c.speed_sigma * z)) if c.speed_sigma else 1.0
            )
            ev = ChurnEvent(at=arrival, action="add", count=1, speed=speed, hub=c.hub)
            system._pending_churn += 1

            def join(s, t, e=ev, cohort=c, r=rng, m=mi):
                ids = system._apply_churn(e, t)
                for aid in ids:
                    if cohort.availability is not None:
                        proc = AvailabilityProcess(
                            s,
                            aid,
                            availability_segments(cohort.availability, r, m),
                            system.set_online,
                        )
                        state.register_process(aid, proc)
                        proc.start()
                    if cohort.depart_at is not None:
                        dep = ChurnEvent(
                            at=cohort.depart_at, action="remove", count=1, agent_id=aid
                        )
                        system._pending_churn += 1
                        s.at(
                            dep.at,
                            lambda s2, t2, e2=dep: system._apply_churn(e2, t2),
                            tag="churn",
                        )

            sched.at(arrival, join, tag="churn")

    for d in pop.departures:
        ev = ChurnEvent(at=d.at, action="remove", count=d.count, agent_id=d.agent_id)
        system._pending_churn += 1
        sched.at(ev.at, lambda s, t, e=ev: system._apply_churn(e, t), tag="churn")

    for o in pop.hub_outages:
        ev = HubFailure(at=o.at, hub_id=o.hub_id)
        system._pending_failures += 1
        sched.at(
            ev.at, lambda s, t, e=ev: system._apply_hub_failure(e, t), tag="hub_fail"
        )

    return state


__all__ = ["PopulationState", "compile_onto", "member_rng"]
