"""Unified observability: metrics registry + span tracing + exporters.

One :class:`Telemetry` bundle per run, threaded through the scheduler,
federated rounds, gossip, fleet engine, serve plane, and population
simulator.  Telemetry off (the ``NULL`` bundle) is the default
everywhere and is contractually free: bit-identical run outputs and
<2% overhead (gated in ``benchmarks/fleet_throughput.py``).  Telemetry
on is observe-only — it never mutates run numerics.

Capture a trace from the CLI with ``--trace PATH`` on
``python -m repro.experiments`` or any benchmark; inspect it with
``python -m repro.telemetry summarize PATH`` or load the JSON in
https://ui.perfetto.dev.
"""

from __future__ import annotations

from .dashboard import dashboard_from_telemetry, render_dashboard, write_dashboard
from .export import load_trace, to_perfetto, write_jsonl, write_perfetto, write_trace
from .registry import MetricsRegistry, NullRegistry
from .trace import NULL, JsonlTraceSink, NullTracer, Telemetry, Tracer

__all__ = [
    "NULL",
    "JsonlTraceSink",
    "MetricsRegistry",
    "NullRegistry",
    "NullTracer",
    "Telemetry",
    "Tracer",
    "dashboard_from_telemetry",
    "load_trace",
    "render_dashboard",
    "to_perfetto",
    "write_dashboard",
    "write_jsonl",
    "write_perfetto",
    "write_trace",
]
