"""Self-contained HTML dashboard rendered from telemetry traces.

:func:`render_dashboard` turns one ``load_trace``-shaped document
(``{"events": [...], "metrics": [...]}``) into a single HTML string
with every asset inline — pure stdlib, inline SVG charts, a few lines
of inline JS for panel collapsing, zero external requests — so the file
works as a CI artifact opened from disk.

Panels (each silently omitted when its data is absent):

* **Learning dynamics** — per-agent loss timelines from ``agent.loss``
  counter events (one polyline per agent track).
* **Staleness heatmap** — per-agent ``mix.staleness`` histogram series
  as a bucket-shaded grid.
* **Knowledge propagation** — ERB creation->consumption and gossip
  delivery latency CDFs from the ``propagation.*_latency_s``
  histograms (epidemic coverage curves).
* **Health** — status banner + incident table from ``health.*``
  instants and the ``health.incidents`` counters.
* **Span aggregates** — top tracing spans by total duration (the
  flame-graph's table form).
* **Metrics** — counter / gauge series dump.
* **Sweep comparison** (optional ``sweep_summary``) — the
  ``repro.sweeps`` summary's comparison rows, Holm-adjusted p included.

Entry points: ``--dashboard PATH`` on ``python -m repro.experiments``
and the benchmark CLIs (live run), or
``python -m repro.telemetry dashboard trace.jsonl -o out.html`` (saved
trace).
"""

from __future__ import annotations

import html
import json
from pathlib import Path
from typing import Any

PALETTE = (
    "#4c78a8",
    "#f58518",
    "#54a24b",
    "#e45756",
    "#72b7b2",
    "#b279a2",
    "#ff9da6",
    "#9d755d",
    "#eeca3b",
    "#bab0ac",
)

_CSS = """
body { font: 14px/1.5 system-ui, sans-serif; margin: 0; background: #f6f7f9;
       color: #1b1f24; }
header { background: #1b2a41; color: #fff; padding: 14px 24px; }
header h1 { margin: 0; font-size: 19px; }
header .sub { color: #9fb3c8; font-size: 12px; margin-top: 2px; }
section { background: #fff; margin: 14px 24px; padding: 12px 18px;
          border: 1px solid #dde3ea; border-radius: 6px; }
section h2 { font-size: 15px; margin: 0; cursor: pointer; user-select: none; }
section h2::before { content: "\\25BE "; color: #7a8799; }
section.closed h2::before { content: "\\25B8 "; }
section.closed > *:not(h2) { display: none; }
table { border-collapse: collapse; margin-top: 8px; font-size: 13px; }
th, td { border: 1px solid #dde3ea; padding: 3px 9px; text-align: right; }
th { background: #eef1f5; }
td.l, th.l { text-align: left; }
.ok { color: #1a7f37; font-weight: 600; }
.warn { color: #9a6700; font-weight: 600; }
.alert { color: #cf222e; font-weight: 600; }
.legend span { display: inline-block; margin-right: 14px; font-size: 12px; }
.legend i { display: inline-block; width: 10px; height: 10px;
            margin-right: 4px; border-radius: 2px; }
.cell { width: 26px; height: 18px; }
.muted { color: #7a8799; font-size: 12px; }
"""

_JS = """
for (const h of document.querySelectorAll("section h2"))
  h.addEventListener("click", () => h.parentElement.classList.toggle("closed"));
"""


def _esc(v: Any) -> str:
    return html.escape(str(v), quote=True)


def _fmt(v: Any) -> str:
    if isinstance(v, bool) or not isinstance(v, int | float):
        return _esc(v if v is not None else "-")
    if isinstance(v, int):
        return str(v)
    return f"{v:.4g}"


def _table(headers: list[str], rows: list[list[Any]], left: int = 1) -> str:
    """Plain HTML table; the first ``left`` columns are left-aligned."""
    th = "".join(
        f'<th class="l">{_esc(h)}</th>' if i < left else f"<th>{_esc(h)}</th>"
        for i, h in enumerate(headers)
    )
    body = []
    for row in rows:
        tds = "".join(
            f'<td class="l">{_fmt(c)}</td>' if i < left else f"<td>{_fmt(c)}</td>"
            for i, c in enumerate(row)
        )
        body.append(f"<tr>{tds}</tr>")
    return f"<table><tr>{th}</tr>{''.join(body)}</table>"


def _line_chart(
    series: list[tuple[str, list[tuple[float, float]]]],
    *,
    width: int = 680,
    height: int = 230,
    x_label: str = "",
    y_label: str = "",
) -> str:
    """Inline-SVG multi-series line chart with axes and a legend."""
    pts = [p for _, ps in series for p in ps]
    if not pts:
        return '<p class="muted">no data</p>'
    xs = [p[0] for p in pts]
    ys = [p[1] for p in pts]
    x0, x1 = min(xs), max(xs)
    y0, y1 = min(ys), max(ys)
    if x1 <= x0:
        x1 = x0 + 1.0
    if y1 <= y0:
        y1 = y0 + 1.0
    ml, mr, mt, mb = 58, 12, 8, 30  # margins
    pw, ph = width - ml - mr, height - mt - mb

    def sx(x: float) -> float:
        return ml + (x - x0) / (x1 - x0) * pw

    def sy(y: float) -> float:
        return mt + ph - (y - y0) / (y1 - y0) * ph

    parts = [
        f'<svg viewBox="0 0 {width} {height}" width="{width}" height="{height}"'
        ' xmlns="http://www.w3.org/2000/svg">'
    ]
    # axes + gridlines with tick labels
    for k in range(5):
        gy = mt + ph * k / 4
        val = y1 - (y1 - y0) * k / 4
        parts.append(
            f'<line x1="{ml}" y1="{gy:.1f}" x2="{width - mr}" y2="{gy:.1f}"'
            ' stroke="#e3e8ee"/>'
            f'<text x="{ml - 6}" y="{gy + 4:.1f}" text-anchor="end"'
            f' font-size="10" fill="#7a8799">{val:.3g}</text>'
        )
    for k in range(5):
        gx = ml + pw * k / 4
        val = x0 + (x1 - x0) * k / 4
        parts.append(
            f'<text x="{gx:.1f}" y="{height - 10}" text-anchor="middle"'
            f' font-size="10" fill="#7a8799">{val:.3g}</text>'
        )
    parts.append(
        f'<rect x="{ml}" y="{mt}" width="{pw}" height="{ph}" fill="none"'
        ' stroke="#b9c2cc"/>'
    )
    if x_label:
        parts.append(
            f'<text x="{ml + pw / 2:.0f}" y="{height - 1}" text-anchor="middle"'
            f' font-size="10" fill="#7a8799">{_esc(x_label)}</text>'
        )
    if y_label:
        parts.append(
            f'<text x="12" y="{mt + ph / 2:.0f}" font-size="10" fill="#7a8799"'
            f' transform="rotate(-90 12 {mt + ph / 2:.0f})"'
            f' text-anchor="middle">{_esc(y_label)}</text>'
        )
    legend = []
    for i, (label, ps) in enumerate(series):
        if not ps:
            continue
        color = PALETTE[i % len(PALETTE)]
        path = " ".join(f"{sx(x):.1f},{sy(y):.1f}" for x, y in sorted(ps))
        parts.append(
            f'<polyline points="{path}" fill="none" stroke="{color}"'
            f' stroke-width="1.6"><title>{_esc(label)}</title></polyline>'
        )
        legend.append(
            f'<span><i style="background:{color}"></i>{_esc(label)}</span>'
        )
    parts.append("</svg>")
    return "".join(parts) + f'<div class="legend">{"".join(legend)}</div>'


# -- trace readers -----------------------------------------------------------
def _hist_series(metrics: list[dict], name: str) -> list[dict]:
    return [m for m in metrics if m.get("kind") == "histogram" and m["name"] == name]


def _bucket_cdf(hist_value: dict) -> list[tuple[float, float]]:
    """Histogram buckets -> cumulative-fraction step points (inf bucket
    dropped: a CDF point at infinity renders nothing useful)."""
    buckets = hist_value.get("buckets") or {}
    n = hist_value.get("count") or 0
    if not n:
        return []
    finite = sorted(
        (float(b), c) for b, c in buckets.items() if b not in ("inf", "+inf")
    )
    out, cum = [], 0
    for bound, c in finite:
        cum += c
        out.append((bound, cum / n))
    return out


def _learning_panel(events: list[dict]) -> str | None:
    by_agent: dict[str, list[tuple[float, float]]] = {}
    for e in events:
        if e.get("kind") == "counter" and e.get("name") == "agent.loss":
            by_agent.setdefault(e.get("track", "?"), []).append(
                (float(e["t0"]), float(e["args"]["value"]))
            )
    if not by_agent:
        return None
    series = [(track, pts) for track, pts in sorted(by_agent.items())]
    chart = _line_chart(
        series, x_label="sim time (s)", y_label="chunk mean TD loss"
    )
    return f"<section><h2>Learning dynamics</h2>{chart}</section>"


def _staleness_panel(metrics: list[dict]) -> str | None:
    hists = _hist_series(metrics, "mix.staleness")
    if not hists:
        return None
    bounds: list[str] = []
    rows = []
    for h in sorted(hists, key=lambda m: m.get("labels", {}).get("agent", "")):
        for b in h["value"].get("buckets", {}):
            if b not in bounds:
                bounds.append(b)
    bounds.sort(key=lambda b: float("inf") if b == "inf" else float(b))
    peak = max(
        (c for h in hists for c in h["value"].get("buckets", {}).values()),
        default=1,
    )
    for h in sorted(hists, key=lambda m: m.get("labels", {}).get("agent", "")):
        agent = h.get("labels", {}).get("agent", "?")
        buckets = h["value"].get("buckets", {})
        cells = []
        for b in bounds:
            c = buckets.get(b, 0)
            alpha = (c / peak) if peak else 0.0
            cells.append(
                f'<td class="cell" style="background:rgba(76,120,168,'
                f'{alpha:.2f})"><title>{c}</title></td>'
            )
        rows.append(
            f'<tr><td class="l">agent {_esc(agent)}</td>{"".join(cells)}'
            f"<td>{h['value'].get('count', 0)}</td></tr>"
        )
    head = "".join(f"<th>&le;{_esc(b)}</th>" for b in bounds)
    table = (
        f'<table><tr><th class="l">mixes by staleness bucket</th>{head}'
        f"<th>n</th></tr>{''.join(rows)}</table>"
    )
    return f"<section><h2>Staleness heatmap</h2>{table}</section>"


def _propagation_panel(metrics: list[dict]) -> str | None:
    series = []
    for name, label in (
        ("propagation.erb_latency_s", "ERB create->remote consume"),
        ("propagation.gossip_latency_s", "gossip delivery (birth-relative)"),
    ):
        for h in _hist_series(metrics, name):
            pts = _bucket_cdf(h["value"])
            if pts:
                series.append((label, pts))
    if not series:
        return None
    chart = _line_chart(
        series, x_label="latency (sim s)", y_label="fraction covered"
    )
    return (
        "<section><h2>Knowledge propagation</h2>"
        '<p class="muted">Epidemic coverage: fraction of tracked records'
        " reaching consumers within t seconds of creation.</p>"
        f"{chart}</section>"
    )


def _health_panel(events: list[dict], metrics: list[dict]) -> str:
    incidents = [
        e
        for e in events
        if e.get("kind") == "instant" and str(e.get("name", "")).startswith("health.")
    ]
    counts = {
        m["labels"].get("kind", "?"): m["value"]
        for m in metrics
        if m.get("kind") == "counter" and m["name"] == "health.incidents"
    }
    kinds = set(counts) | {str(e["name"])[len("health.") :] for e in incidents}
    if any(k.startswith("nonfinite") for k in kinds):
        status, cls = "ALERT", "alert"
    elif kinds:
        status, cls = "WARN", "warn"
    else:
        status, cls = "OK", "ok"
    rows = [
        [
            f"{e['t0']:.4g}",
            str(e["name"])[len("health.") :],
            e.get("track", ""),
            json.dumps(e.get("args", {})),
        ]
        for e in incidents[:100]
    ]
    body = f'<p>fleet status: <span class="{cls}">{status}</span></p>'
    if counts:
        body += _table(
            ["incident kind", "count"], sorted(counts.items()), left=1
        )
    if rows:
        body += _table(["sim time", "kind", "track", "detail"], rows, left=4)
    return f"<section><h2>Health</h2>{body}</section>"


def _spans_panel(events: list[dict]) -> str | None:
    agg: dict[tuple[str, str], list[float]] = {}
    for e in events:
        if e.get("kind") != "span":
            continue
        key = (str(e.get("name", "?")), str(e.get("clock", "sim")))
        agg.setdefault(key, []).append(float(e["t1"]) - float(e["t0"]))
    if not agg:
        return None
    rows = []
    for (name, clock), durs in sorted(
        agg.items(), key=lambda kv: -sum(kv[1])
    )[:20]:
        total = sum(durs)
        rows.append(
            [name, clock, len(durs), total, total / len(durs), max(durs)]
        )
    table = _table(
        ["span", "clock", "count", "total (s)", "mean (s)", "max (s)"],
        rows,
        left=2,
    )
    return f"<section><h2>Span aggregates</h2>{table}</section>"


def _metrics_panel(metrics: list[dict]) -> str | None:
    rows = []
    for m in metrics:
        if m.get("kind") not in ("counter", "gauge"):
            continue
        labels = ",".join(f"{k}={v}" for k, v in sorted(m["labels"].items()))
        rows.append([m["name"], m["kind"], labels, m["value"]])
    if not rows:
        return None
    rows.sort(key=lambda r: (r[0], r[2]))
    table = _table(["metric", "kind", "labels", "value"], rows[:200], left=3)
    note = (
        f'<p class="muted">showing 200 of {len(rows)} series</p>'
        if len(rows) > 200
        else ""
    )
    return f"<section class='closed'><h2>Metric series</h2>{table}{note}</section>"


def _sweep_panel(sweep_summary: dict | None) -> str | None:
    if not sweep_summary:
        return None
    comparisons = sweep_summary.get("comparisons") or []
    if not comparisons:
        return None
    headers = list(comparisons[0].keys())
    rows = [[c.get(h) for h in headers] for c in comparisons]
    table = _table(headers, rows, left=2)
    return (
        "<section><h2>Sweep comparison</h2>"
        '<p class="muted">Arm vs baseline; p(t)_adj is Holm–Bonferroni'
        " adjusted across the metric family.</p>"
        f"{table}</section>"
    )


# -- entry points ------------------------------------------------------------
def render_dashboard(
    trace: dict[str, Any],
    *,
    sweep_summary: dict[str, Any] | None = None,
    title: str = "Fleet observatory",
) -> str:
    """Render one trace document into a self-contained HTML page."""
    events = trace.get("events") or []
    metrics = trace.get("metrics") or []
    panels = [
        _learning_panel(events),
        _staleness_panel(metrics),
        _propagation_panel(metrics),
        _health_panel(events, metrics),
        _spans_panel(events),
        _sweep_panel(sweep_summary),
        _metrics_panel(metrics),
    ]
    body = "".join(p for p in panels if p)
    sub = f"{len(events)} trace events &middot; {len(metrics)} metric series"
    return (
        "<!doctype html><html><head><meta charset='utf-8'>"
        f"<title>{_esc(title)}</title><style>{_CSS}</style></head><body>"
        f"<header><h1>{_esc(title)}</h1><div class='sub'>{sub}</div></header>"
        f"{body}<script>{_JS}</script></body></html>"
    )


def dashboard_from_telemetry(
    tel,
    *,
    sweep_summary: dict[str, Any] | None = None,
    title: str = "Fleet observatory",
) -> str:
    """Render a live :class:`~repro.telemetry.Telemetry` bundle."""
    trace = {
        "events": list(tel.tracer.events),
        "metrics": tel.registry.summary(),
    }
    return render_dashboard(trace, sweep_summary=sweep_summary, title=title)


def write_dashboard(
    path: str | Path,
    trace: dict[str, Any],
    *,
    sweep_summary: dict[str, Any] | None = None,
    title: str = "Fleet observatory",
) -> Path:
    """Render and write; returns the written path."""
    out = Path(path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(
        render_dashboard(trace, sweep_summary=sweep_summary, title=title)
    )
    return out


__all__ = ["dashboard_from_telemetry", "render_dashboard", "write_dashboard"]
