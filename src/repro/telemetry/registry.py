"""Metrics registry: counters, gauges, and histograms with label sets.

One :class:`MetricsRegistry` per run.  Instruments are identified by
``(name, sorted(labels))`` series keys, so the same metric name carries
any number of label combinations (``comm.bytes{plane=erb}`` next to
``comm.bytes{plane=weights}``) — bounded by ``max_series`` per metric:
telemetry is observe-only and must never take down a run, so a series
past the bound is *dropped and counted* (``n_dropped_series``), never
raised on.

The registry is deliberately dependency-free (stdlib only) and cheap:
one dict lookup and a float add per counter increment.  The disabled
path is :class:`NullRegistry`, whose methods are empty — call sites pay
one no-op method call, nothing else, which is what keeps the
telemetry-off contract (<2% overhead, bit-identical results) trivially
true: a disabled registry touches no state at all.
"""

from __future__ import annotations

from collections.abc import Iterator
from typing import Any

SeriesKey = tuple[str, tuple[tuple[str, str], ...]]

#: histogram bucket upper bounds double from 1; the last bucket is +inf
DEFAULT_BUCKETS = tuple(float(2**i) for i in range(0, 21)) + (float("inf"),)


def _series_key(name: str, labels: dict[str, Any]) -> SeriesKey:
    return name, tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Histogram:
    """Fixed-boundary histogram: counts per bucket + sum + count."""

    __slots__ = ("bounds", "counts", "total", "n")

    def __init__(self, bounds: tuple[float, ...]):
        self.bounds = bounds
        self.counts = [0] * len(bounds)
        self.total = 0.0
        self.n = 0

    def observe(self, value: float) -> None:
        self.n += 1
        self.total += value
        for i, b in enumerate(self.bounds):
            if value <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1  # defensive: last bound is +inf

    def summary(self) -> dict[str, Any]:
        return {
            "count": self.n,
            "sum": self.total,
            "mean": self.total / self.n if self.n else None,
            "buckets": {
                ("inf" if b == float("inf") else f"{b:g}"): c
                for b, c in zip(self.bounds, self.counts)
                if c
            },
        }


class MetricsRegistry:
    """Counters / gauges / histograms, keyed by name + label set."""

    enabled = True

    def __init__(
        self,
        *,
        max_series: int = 1024,
        buckets: tuple[float, ...] = DEFAULT_BUCKETS,
    ):
        self.max_series = int(max_series)
        self.buckets = buckets
        self._counters: dict[SeriesKey, float] = {}
        self._gauges: dict[SeriesKey, float] = {}
        self._hists: dict[SeriesKey, _Histogram] = {}
        self._per_metric: dict[str, int] = {}  # live series per metric name
        self.n_dropped_series = 0

    # -- series admission ----------------------------------------------------
    def _admit(self, key: SeriesKey, table: dict[SeriesKey, Any]) -> bool:
        if key in table:
            return True
        name = key[0]
        if self._per_metric.get(name, 0) >= self.max_series:
            self.n_dropped_series += 1
            return False
        self._per_metric[name] = self._per_metric.get(name, 0) + 1
        return True

    # -- instruments ---------------------------------------------------------
    def count(self, name: str, value: float = 1.0, **labels) -> None:
        """Increment the counter series ``name{labels}`` by ``value``."""
        key = _series_key(name, labels)
        cur = self._counters.get(key)
        if cur is not None:
            self._counters[key] = cur + value
        elif self._admit(key, self._counters):
            self._counters[key] = value

    def gauge(self, name: str, value: float, **labels) -> None:
        """Set the gauge series ``name{labels}`` to ``value``."""
        key = _series_key(name, labels)
        if key in self._gauges or self._admit(key, self._gauges):
            self._gauges[key] = value

    def observe(self, name: str, value: float, **labels) -> None:
        """Record one sample into the histogram series ``name{labels}``."""
        key = _series_key(name, labels)
        h = self._hists.get(key)
        if h is None:
            if not self._admit(key, self._hists):
                return
            h = self._hists[key] = _Histogram(self.buckets)
        h.observe(value)

    # -- reads ---------------------------------------------------------------
    def counter_value(self, name: str, **labels) -> float:
        return self._counters.get(_series_key(name, labels), 0.0)

    def gauge_value(self, name: str, **labels) -> float | None:
        return self._gauges.get(_series_key(name, labels))

    def histogram(self, name: str, **labels) -> dict[str, Any] | None:
        h = self._hists.get(_series_key(name, labels))
        return h.summary() if h is not None else None

    def counters_by_label(self, name: str, label: str) -> dict[str, float]:
        """``label value -> counter total`` over every series of ``name``
        (the view :class:`~repro.core.gossip.BandwidthMeter` reads)."""
        out: dict[str, float] = {}
        for (n, labels), v in self._counters.items():
            if n != name:
                continue
            for k, lv in labels:
                if k == label:
                    out[lv] = out.get(lv, 0.0) + v
        return out

    @property
    def n_series(self) -> int:
        return len(self._counters) + len(self._gauges) + len(self._hists)

    # -- export --------------------------------------------------------------
    def rows(self) -> Iterator[dict[str, Any]]:
        """Flat JSON-able rows, one per series (the JSONL export shape)."""
        for (name, labels), v in sorted(self._counters.items()):
            yield {"kind": "counter", "name": name, "labels": dict(labels), "value": v}
        for (name, labels), v in sorted(self._gauges.items()):
            yield {"kind": "gauge", "name": name, "labels": dict(labels), "value": v}
        for (name, labels), h in sorted(self._hists.items()):
            yield {
                "kind": "histogram",
                "name": name,
                "labels": dict(labels),
                "value": h.summary(),
            }

    def summary(self) -> list[dict[str, Any]]:
        return list(self.rows())


class NullRegistry(MetricsRegistry):
    """The disabled registry: every write is a no-op, every read empty."""

    enabled = False

    def __init__(self):
        super().__init__(max_series=0)

    def count(self, name: str, value: float = 1.0, **labels) -> None:
        pass

    def gauge(self, name: str, value: float, **labels) -> None:
        pass

    def observe(self, name: str, value: float, **labels) -> None:
        pass


__all__ = [
    "DEFAULT_BUCKETS",
    "MetricsRegistry",
    "NullRegistry",
]
