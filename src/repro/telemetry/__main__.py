"""Telemetry CLI: summarize, convert, and diff captured traces.

Usage::

    python -m repro.telemetry summarize out.json
    python -m repro.telemetry export run.jsonl run.perfetto.json
    python -m repro.telemetry diff before.json after.json
    python -m repro.telemetry dashboard run.jsonl -o dashboard.html

Long runs can capture traces with a bounded streaming writer
(``Telemetry(stream_path=...)``): events go straight to a size-capped
JSONL file (64 MiB by default) instead of accumulating in memory;
events past the cap are dropped and tallied in the ``trace.dropped``
counter, which every subcommand here reads back like any other
counter row.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Any

from .export import load_trace


def _span_stats(events: list[dict[str, Any]]) -> dict[str, dict[str, Any]]:
    """Per-name span aggregates: count, total/mean/max duration, clock."""
    stats: dict[str, dict[str, Any]] = {}
    for e in events:
        if e["kind"] != "span":
            continue
        dur = e["t1"] - e["t0"]
        s = stats.setdefault(
            e["name"], {"count": 0, "total": 0.0, "max": 0.0, "clock": e["clock"]}
        )
        s["count"] += 1
        s["total"] += dur
        s["max"] = max(s["max"], dur)
    for s in stats.values():
        s["mean"] = s["total"] / s["count"]
    return stats


def _instant_counts(events: list[dict[str, Any]]) -> dict[str, int]:
    out: dict[str, int] = {}
    for e in events:
        if e["kind"] == "instant":
            out[e["name"]] = out.get(e["name"], 0) + 1
    return out


def _fmt_s(x: float) -> str:
    return f"{x * 1e3:.3f}ms" if x < 1.0 else f"{x:.3f}s"


def _cmd_summarize(args: argparse.Namespace) -> int:
    trace = load_trace(args.trace)
    events = trace["events"]
    tracks = sorted({e["track"] for e in events})
    print(f"{args.trace}: {len(events)} events on {len(tracks)} tracks")
    if tracks:
        print(f"  tracks: {', '.join(tracks)}")

    stats = _span_stats(events)
    if stats:
        print(f"  {'span':<24} {'n':>6} {'total':>12} {'mean':>12} {'max':>12}  clock")
        for name in sorted(stats, key=lambda n: -stats[n]["total"]):
            s = stats[name]
            print(
                f"  {name:<24} {s['count']:>6} {_fmt_s(s['total']):>12}"
                f" {_fmt_s(s['mean']):>12} {_fmt_s(s['max']):>12}  {s['clock']}"
            )
    instants = _instant_counts(events)
    if instants:
        line = ", ".join(f"{k}={v}" for k, v in sorted(instants.items()))
        print(f"  instants: {line}")
    counters = [m for m in trace["metrics"] if m.get("kind") == "counter"]
    if counters:
        print("  counters:")

        def _key(m):
            return (m["name"], sorted(m["labels"].items()))

        for m in sorted(counters, key=_key):
            labels = ",".join(f"{k}={v}" for k, v in sorted(m["labels"].items()))
            suffix = f"{{{labels}}}" if labels else ""
            print(f"    {m['name']}{suffix} = {m['value']:g}")
    return 0


def _cmd_export(args: argparse.Namespace) -> int:
    # Re-render a loaded trace as Perfetto JSON via a throwaway bundle.
    from .export import write_trace
    from .trace import Telemetry

    trace = load_trace(args.trace)
    tel = Telemetry(enabled=True, max_events=len(trace["events"]) + 1)
    for e in trace["events"]:
        tel.tracer._emit(dict(e))
    for m in trace["metrics"]:
        if m.get("kind") == "counter":
            tel.registry.count(m["name"], m["value"], **m.get("labels", {}))
        elif m.get("kind") == "gauge":
            tel.registry.gauge(m["name"], m["value"], **m.get("labels", {}))
    out = write_trace(tel, args.out)
    print(f"wrote {out}")
    return 0


def _cmd_dashboard(args: argparse.Namespace) -> int:
    from .dashboard import write_dashboard

    trace = load_trace(args.trace)
    sweep = None
    if args.sweep is not None:
        sweep = json.loads(Path(args.sweep).read_text())
    out = write_dashboard(
        args.out, trace, sweep_summary=sweep, title=args.title
    )
    print(f"wrote {out}")
    return 0


def _cmd_diff(args: argparse.Namespace) -> int:
    a = _span_stats(load_trace(args.a)["events"])
    b = _span_stats(load_trace(args.b)["events"])
    names = sorted(set(a) | set(b))
    if not names:
        print("no spans in either trace")
        return 0
    print(
        f"{'span':<24} {'n(a)':>6} {'n(b)':>6} "
        f"{'total(a)':>12} {'total(b)':>12} {'delta':>9}"
    )
    for name in names:
        sa, sb = a.get(name), b.get(name)
        na = sa["count"] if sa else 0
        nb = sb["count"] if sb else 0
        ta = sa["total"] if sa else 0.0
        tb = sb["total"] if sb else 0.0
        delta = f"{(tb - ta) / ta * 100:+.1f}%" if ta else "new" if tb else "-"
        print(
            f"{name:<24} {na:>6} {nb:>6} {_fmt_s(ta):>12} {_fmt_s(tb):>12} {delta:>9}"
        )
    return 0


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.telemetry",
        description="Summarize, convert, and diff repro telemetry traces.",
    )
    sub = p.add_subparsers(dest="cmd", required=True)

    s = sub.add_parser("summarize", help="print span/instant/counter aggregates")
    s.add_argument("trace", type=Path)
    s.set_defaults(fn=_cmd_summarize)

    e = sub.add_parser("export", help="convert a trace (e.g. JSONL -> Perfetto JSON)")
    e.add_argument("trace", type=Path)
    e.add_argument("out", type=Path)
    e.set_defaults(fn=_cmd_export)

    h = sub.add_parser(
        "dashboard", help="render a saved trace into a self-contained HTML page"
    )
    h.add_argument("trace", type=Path)
    h.add_argument("-o", "--out", type=Path, default=Path("dashboard.html"))
    h.add_argument(
        "--sweep", type=Path, default=None, help="sweep summary JSON to embed"
    )
    h.add_argument("--title", default="Fleet observatory")
    h.set_defaults(fn=_cmd_dashboard)

    d = sub.add_parser("diff", help="compare span aggregates of two traces")
    d.add_argument("a", type=Path)
    d.add_argument("b", type=Path)
    d.set_defaults(fn=_cmd_diff)
    return p


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except FileNotFoundError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    except json.JSONDecodeError as e:
        print(f"error: not a valid trace file: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    raise SystemExit(main())
