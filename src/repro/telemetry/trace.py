"""Structured span tracing over simulated and host clocks.

A :class:`Tracer` records a flat list of events.  Every event carries a
``clock`` field naming which timeline it lives on:

- ``"sim"``   — simulated scheduler seconds (:class:`repro.core.scheduler.
  Scheduler` time).  Round phases, gossip exchanges, availability windows.
- ``"wall"``  — host ``perf_counter`` seconds.  Fleet flushes, serve
  ticks, XLA compiles — things that cost real time regardless of the
  simulated clock.

Event kinds mirror the Chrome ``trace_event`` phases they export to:

- ``span``    — a complete event (``ph: "X"``): name, track, t0, t1.
- ``instant`` — a point event (``ph: "i"``).
- ``counter`` — a sampled counter value (``ph: "C"``).

``track`` is a free-form string ("agent3", "gossip", "fleet", "serve")
that becomes a Perfetto thread row; sim-clock and wall-clock tracks are
grouped into separate Perfetto processes so the two timelines never
visually interleave.

The :class:`Telemetry` bundle ties one tracer to one
:class:`~repro.telemetry.registry.MetricsRegistry` and is the single
object threaded through the system ctors.  ``NULL`` is the shared
disabled bundle: every record method is a no-op and ``enabled`` is
False, so instrumented call sites can guard hot paths with one
attribute check and pay nothing when telemetry is off.
"""

from __future__ import annotations

import json
import time
from collections.abc import Iterator
from contextlib import contextmanager
from pathlib import Path
from typing import Any

from .registry import MetricsRegistry, NullRegistry

#: default byte cap for streaming JSONL sinks (64 MiB)
DEFAULT_STREAM_MAX_BYTES = 64 * 1024 * 1024


class JsonlTraceSink:
    """Size-capped streaming JSONL writer for long soaks.

    Events are written through to disk as they are emitted instead of
    accumulating in the tracer's in-memory list, so a multi-hour soak
    has O(1) memory for tracing.  The file is the same
    ``repro.telemetry/v1`` JSONL layout ``load_trace`` reads back:
    header row first, one event per line, metric rows appended at
    :meth:`close`.

    ``max_bytes`` caps the event portion of the file; past the cap,
    events are dropped and tallied (``n_dropped``) — the registry rows
    at close are small (bounded series cardinality) and always written,
    so the capped file still carries the final ``trace.dropped``
    counter.
    """

    def __init__(
        self, path: str | Path, *, max_bytes: int = DEFAULT_STREAM_MAX_BYTES
    ):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.max_bytes = int(max_bytes)
        self.n_written = 0
        self.n_dropped = 0
        self._f = self.path.open("w")
        header = {"kind": "header", "format": "repro.telemetry/v1", "streaming": True}
        line = json.dumps(header) + "\n"
        self._f.write(line)
        self._nbytes = len(line)

    def write(self, ev: dict[str, Any]) -> bool:
        """Stream one event row; False once closed or past the byte cap."""
        if self._f.closed:
            return False
        line = json.dumps(ev) + "\n"
        if self._nbytes + len(line) > self.max_bytes:
            self.n_dropped += 1
            return False
        self._f.write(line)
        self._nbytes += len(line)
        self.n_written += 1
        return True

    def write_metric_row(self, row: dict[str, Any]) -> None:
        """Append a registry row (exempt from the event byte cap)."""
        if self._f.closed:
            return
        self._f.write(json.dumps({**row, "kind": f"metric.{row['kind']}"}) + "\n")

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class Tracer:
    """Append-only event buffer with a bounded size.

    ``max_events`` bounds memory: once full, new events are dropped and
    tallied in ``n_dropped`` (telemetry never takes down a run).

    With a ``sink`` (see :class:`JsonlTraceSink`), events stream to disk
    instead of accumulating in ``events`` — memory stays O(1) and the
    sink's byte cap replaces ``max_events`` as the bound; sink-refused
    events are tallied in the same ``n_dropped``.
    """

    enabled = True

    def __init__(
        self, *, max_events: int = 200_000, sink: JsonlTraceSink | None = None
    ):
        self.max_events = int(max_events)
        self.events: list[dict[str, Any]] = []
        self.n_dropped = 0
        self.sink = sink
        self._wall0 = time.perf_counter()

    # -- clocks --------------------------------------------------------------
    def wall(self) -> float:
        """Host seconds since tracer creation (zero-based wall clock)."""
        return time.perf_counter() - self._wall0

    def to_wall(self, perf_t: float) -> float:
        """Rebase an absolute ``time.perf_counter()`` stamp onto the
        tracer's zero-based wall clock (for call sites that already hold
        perf_counter timestamps, e.g. the serve request plane)."""
        return perf_t - self._wall0

    # -- record --------------------------------------------------------------
    def _emit(self, ev: dict[str, Any]) -> None:
        if self.sink is not None:
            if not self.sink.write(ev):
                self.n_dropped += 1
            return
        if len(self.events) >= self.max_events:
            self.n_dropped += 1
            return
        self.events.append(ev)

    def span(
        self,
        name: str,
        track: str,
        t0: float,
        t1: float,
        *,
        clock: str = "sim",
        **args,
    ) -> None:
        """Record a complete span ``[t0, t1]`` on ``track``."""
        self._emit(
            {
                "kind": "span",
                "name": name,
                "track": track,
                "clock": clock,
                "t0": float(t0),
                "t1": float(t1),
                "args": args,
            }
        )

    def instant(
        self, name: str, track: str, t: float, *, clock: str = "sim", **args
    ) -> None:
        """Record a point event at ``t`` on ``track``."""
        self._emit(
            {
                "kind": "instant",
                "name": name,
                "track": track,
                "clock": clock,
                "t0": float(t),
                "t1": float(t),
                "args": args,
            }
        )

    def counter(
        self, name: str, track: str, t: float, value: float, *, clock: str = "sim"
    ) -> None:
        """Record a sampled counter value at ``t`` (Perfetto ``ph: "C"``)."""
        self._emit(
            {
                "kind": "counter",
                "name": name,
                "track": track,
                "clock": clock,
                "t0": float(t),
                "t1": float(t),
                "args": {"value": float(value)},
            }
        )

    @contextmanager
    def wall_span(self, name: str, track: str, **args) -> Iterator[None]:
        """Context manager recording a wall-clock span around its body."""
        t0 = self.wall()
        try:
            yield
        finally:
            self.span(name, track, t0, self.wall(), clock="wall", **args)

    # -- reads ---------------------------------------------------------------
    def spans(self, name: str | None = None) -> list[dict[str, Any]]:
        return [
            e
            for e in self.events
            if e["kind"] == "span" and (name is None or e["name"] == name)
        ]

    def __len__(self) -> int:
        return len(self.events)


class NullTracer(Tracer):
    """Disabled tracer: records nothing, yields immediately."""

    enabled = False

    def __init__(self):
        super().__init__(max_events=0)

    def _emit(self, ev: dict[str, Any]) -> None:
        pass

    def span(self, name, track, t0, t1, *, clock="sim", **args) -> None:
        pass

    def instant(self, name, track, t, *, clock="sim", **args) -> None:
        pass

    def counter(self, name, track, t, value, *, clock="sim") -> None:
        pass

    @contextmanager
    def wall_span(self, name: str, track: str, **args) -> Iterator[None]:
        yield


class Telemetry:
    """One tracer + one metrics registry, threaded through system ctors.

    ``Telemetry(enabled=False)`` (or the shared ``NULL`` singleton) is
    the no-op bundle; call sites may check ``tel.enabled`` to skip even
    argument construction on hot paths.
    """

    def __init__(
        self,
        *,
        enabled: bool = True,
        max_events: int = 200_000,
        max_series: int = 1024,
        stream_path: str | Path | None = None,
        stream_max_bytes: int = DEFAULT_STREAM_MAX_BYTES,
    ):
        self.enabled = bool(enabled)
        self.sink: JsonlTraceSink | None = None
        if self.enabled:
            if stream_path is not None:
                self.sink = JsonlTraceSink(stream_path, max_bytes=stream_max_bytes)
            self.tracer: Tracer = Tracer(max_events=max_events, sink=self.sink)
            self.registry: MetricsRegistry = MetricsRegistry(max_series=max_series)
        else:
            self.tracer = NullTracer()
            self.registry = NullRegistry()

    # convenience passthroughs so call sites read `tel.span(...)`
    def span(self, name, track, t0, t1, *, clock="sim", **args) -> None:
        self.tracer.span(name, track, t0, t1, clock=clock, **args)

    def instant(self, name, track, t, *, clock="sim", **args) -> None:
        self.tracer.instant(name, track, t, clock=clock, **args)

    def counter(self, name, track, t, value, *, clock="sim") -> None:
        self.tracer.counter(name, track, t, value, clock=clock)

    def count(self, name: str, value: float = 1.0, **labels) -> None:
        self.registry.count(name, value, **labels)

    def observe(self, name: str, value: float, **labels) -> None:
        self.registry.observe(name, value, **labels)

    def gauge(self, name: str, value: float, **labels) -> None:
        self.registry.gauge(name, value, **labels)

    def wall_span(self, name: str, track: str, **args):
        return self.tracer.wall_span(name, track, **args)

    def wall(self) -> float:
        return self.tracer.wall()

    def to_wall(self, perf_t: float) -> float:
        return self.tracer.to_wall(perf_t)

    def summary(self) -> dict[str, Any]:
        """Compact digest: event counts by name plus metric rows."""
        by_name: dict[str, int] = {}
        for e in self.tracer.events:
            key = f"{e['kind']}:{e['name']}"
            by_name[key] = by_name.get(key, 0) + 1
        out = {
            "n_events": len(self.tracer.events),
            "n_dropped_events": self.tracer.n_dropped,
            "events_by_name": dict(sorted(by_name.items())),
            "metrics": self.registry.summary(),
        }
        if self.sink is not None:
            out["n_streamed_events"] = self.sink.n_written
            out["stream_path"] = str(self.sink.path)
        return out

    def close(self) -> None:
        """Finalize the streaming sink (no-op without one).

        Records the final ``trace.dropped`` counter, appends every
        registry row to the JSONL file (so the on-disk trace is a
        complete ``load_trace``-compatible document), and closes the
        file.  Safe to call more than once.
        """
        if self.sink is None or self.sink._f.closed:
            return
        self.registry.count("trace.dropped", float(self.tracer.n_dropped))
        for row in self.registry.rows():
            self.sink.write_metric_row(row)
        self.sink.close()


#: shared disabled bundle — the default at every instrumented call site
NULL = Telemetry(enabled=False)


__all__ = [
    "DEFAULT_STREAM_MAX_BYTES",
    "NULL",
    "JsonlTraceSink",
    "NullTracer",
    "Telemetry",
    "Tracer",
]
