"""Structured span tracing over simulated and host clocks.

A :class:`Tracer` records a flat list of events.  Every event carries a
``clock`` field naming which timeline it lives on:

- ``"sim"``   — simulated scheduler seconds (:class:`repro.core.scheduler.
  Scheduler` time).  Round phases, gossip exchanges, availability windows.
- ``"wall"``  — host ``perf_counter`` seconds.  Fleet flushes, serve
  ticks, XLA compiles — things that cost real time regardless of the
  simulated clock.

Event kinds mirror the Chrome ``trace_event`` phases they export to:

- ``span``    — a complete event (``ph: "X"``): name, track, t0, t1.
- ``instant`` — a point event (``ph: "i"``).
- ``counter`` — a sampled counter value (``ph: "C"``).

``track`` is a free-form string ("agent3", "gossip", "fleet", "serve")
that becomes a Perfetto thread row; sim-clock and wall-clock tracks are
grouped into separate Perfetto processes so the two timelines never
visually interleave.

The :class:`Telemetry` bundle ties one tracer to one
:class:`~repro.telemetry.registry.MetricsRegistry` and is the single
object threaded through the system ctors.  ``NULL`` is the shared
disabled bundle: every record method is a no-op and ``enabled`` is
False, so instrumented call sites can guard hot paths with one
attribute check and pay nothing when telemetry is off.
"""

from __future__ import annotations

import time
from collections.abc import Iterator
from contextlib import contextmanager
from typing import Any

from .registry import MetricsRegistry, NullRegistry


class Tracer:
    """Append-only event buffer with a bounded size.

    ``max_events`` bounds memory: once full, new events are dropped and
    tallied in ``n_dropped`` (telemetry never takes down a run).
    """

    enabled = True

    def __init__(self, *, max_events: int = 200_000):
        self.max_events = int(max_events)
        self.events: list[dict[str, Any]] = []
        self.n_dropped = 0
        self._wall0 = time.perf_counter()

    # -- clocks --------------------------------------------------------------
    def wall(self) -> float:
        """Host seconds since tracer creation (zero-based wall clock)."""
        return time.perf_counter() - self._wall0

    def to_wall(self, perf_t: float) -> float:
        """Rebase an absolute ``time.perf_counter()`` stamp onto the
        tracer's zero-based wall clock (for call sites that already hold
        perf_counter timestamps, e.g. the serve request plane)."""
        return perf_t - self._wall0

    # -- record --------------------------------------------------------------
    def _emit(self, ev: dict[str, Any]) -> None:
        if len(self.events) >= self.max_events:
            self.n_dropped += 1
            return
        self.events.append(ev)

    def span(
        self,
        name: str,
        track: str,
        t0: float,
        t1: float,
        *,
        clock: str = "sim",
        **args,
    ) -> None:
        """Record a complete span ``[t0, t1]`` on ``track``."""
        self._emit(
            {
                "kind": "span",
                "name": name,
                "track": track,
                "clock": clock,
                "t0": float(t0),
                "t1": float(t1),
                "args": args,
            }
        )

    def instant(
        self, name: str, track: str, t: float, *, clock: str = "sim", **args
    ) -> None:
        """Record a point event at ``t`` on ``track``."""
        self._emit(
            {
                "kind": "instant",
                "name": name,
                "track": track,
                "clock": clock,
                "t0": float(t),
                "t1": float(t),
                "args": args,
            }
        )

    def counter(
        self, name: str, track: str, t: float, value: float, *, clock: str = "sim"
    ) -> None:
        """Record a sampled counter value at ``t`` (Perfetto ``ph: "C"``)."""
        self._emit(
            {
                "kind": "counter",
                "name": name,
                "track": track,
                "clock": clock,
                "t0": float(t),
                "t1": float(t),
                "args": {"value": float(value)},
            }
        )

    @contextmanager
    def wall_span(self, name: str, track: str, **args) -> Iterator[None]:
        """Context manager recording a wall-clock span around its body."""
        t0 = self.wall()
        try:
            yield
        finally:
            self.span(name, track, t0, self.wall(), clock="wall", **args)

    # -- reads ---------------------------------------------------------------
    def spans(self, name: str | None = None) -> list[dict[str, Any]]:
        return [
            e
            for e in self.events
            if e["kind"] == "span" and (name is None or e["name"] == name)
        ]

    def __len__(self) -> int:
        return len(self.events)


class NullTracer(Tracer):
    """Disabled tracer: records nothing, yields immediately."""

    enabled = False

    def __init__(self):
        super().__init__(max_events=0)

    def _emit(self, ev: dict[str, Any]) -> None:
        pass

    def span(self, name, track, t0, t1, *, clock="sim", **args) -> None:
        pass

    def instant(self, name, track, t, *, clock="sim", **args) -> None:
        pass

    def counter(self, name, track, t, value, *, clock="sim") -> None:
        pass

    @contextmanager
    def wall_span(self, name: str, track: str, **args) -> Iterator[None]:
        yield


class Telemetry:
    """One tracer + one metrics registry, threaded through system ctors.

    ``Telemetry(enabled=False)`` (or the shared ``NULL`` singleton) is
    the no-op bundle; call sites may check ``tel.enabled`` to skip even
    argument construction on hot paths.
    """

    def __init__(
        self,
        *,
        enabled: bool = True,
        max_events: int = 200_000,
        max_series: int = 1024,
    ):
        self.enabled = bool(enabled)
        if self.enabled:
            self.tracer: Tracer = Tracer(max_events=max_events)
            self.registry: MetricsRegistry = MetricsRegistry(max_series=max_series)
        else:
            self.tracer = NullTracer()
            self.registry = NullRegistry()

    # convenience passthroughs so call sites read `tel.span(...)`
    def span(self, name, track, t0, t1, *, clock="sim", **args) -> None:
        self.tracer.span(name, track, t0, t1, clock=clock, **args)

    def instant(self, name, track, t, *, clock="sim", **args) -> None:
        self.tracer.instant(name, track, t, clock=clock, **args)

    def counter(self, name, track, t, value, *, clock="sim") -> None:
        self.tracer.counter(name, track, t, value, clock=clock)

    def count(self, name: str, value: float = 1.0, **labels) -> None:
        self.registry.count(name, value, **labels)

    def observe(self, name: str, value: float, **labels) -> None:
        self.registry.observe(name, value, **labels)

    def gauge(self, name: str, value: float, **labels) -> None:
        self.registry.gauge(name, value, **labels)

    def wall_span(self, name: str, track: str, **args):
        return self.tracer.wall_span(name, track, **args)

    def wall(self) -> float:
        return self.tracer.wall()

    def to_wall(self, perf_t: float) -> float:
        return self.tracer.to_wall(perf_t)

    def summary(self) -> dict[str, Any]:
        """Compact digest: event counts by name plus metric rows."""
        by_name: dict[str, int] = {}
        for e in self.tracer.events:
            key = f"{e['kind']}:{e['name']}"
            by_name[key] = by_name.get(key, 0) + 1
        return {
            "n_events": len(self.tracer.events),
            "n_dropped_events": self.tracer.n_dropped,
            "events_by_name": dict(sorted(by_name.items())),
            "metrics": self.registry.summary(),
        }


#: shared disabled bundle — the default at every instrumented call site
NULL = Telemetry(enabled=False)


__all__ = ["NULL", "NullTracer", "Telemetry", "Tracer"]
