"""Trace exporters: Chrome/Perfetto ``trace_event`` JSON and flat JSONL.

Perfetto layout
---------------
Two synthetic processes separate the clocks so spans never interleave
across timelines:

- pid 1 (``sim``)  — simulated scheduler time; one thread row per
  track ("agent0".."agentN", "gossip", "population", "scheduler").
- pid 2 (``host``) — wall time; thread rows for "fleet", "serve", …

Timestamps are microseconds (the ``trace_event`` unit): sim seconds and
zero-based wall seconds both scale by 1e6.  Metric totals ride along as
``repro.metrics`` metadata on the trace-level ``otherData`` dict so the
Perfetto JSON alone round-trips the registry snapshot.

JSONL layout
------------
One JSON object per line: first a header row (``{"kind": "header"}``),
then every trace event verbatim, then one row per metric series — the
shape :class:`repro.sweeps.store.ReportStore` artifacts use, greppable
and streamable.  ``load_trace`` sniffs either format back into the
common event-dict list.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any

from .trace import Telemetry

_SIM_PID = 1
_WALL_PID = 2
_CLOCK_PID = {"sim": _SIM_PID, "wall": _WALL_PID}
_PROCESS_NAME = {_SIM_PID: "sim", _WALL_PID: "host"}


def _track_order(track: str) -> tuple[int, str, int]:
    """Sort agent tracks numerically, then everything else by name."""
    if track.startswith("agent"):
        suffix = track[5:]
        if suffix.isdigit():
            return (0, "agent", int(suffix))
    return (1, track, 0)


def to_perfetto(tel: Telemetry) -> dict[str, Any]:
    """Render the telemetry bundle as a ``trace_event`` JSON object."""
    events: list[dict[str, Any]] = []

    # stable tid assignment per (pid, track), ordered for a tidy UI
    tracks: dict[int, list[str]] = {_SIM_PID: [], _WALL_PID: []}
    for e in tel.tracer.events:
        pid = _CLOCK_PID.get(e["clock"], _SIM_PID)
        if e["track"] not in tracks[pid]:
            tracks[pid].append(e["track"])
    tids: dict[tuple[int, str], int] = {}
    for pid, names in tracks.items():
        for i, name in enumerate(sorted(names, key=_track_order)):
            tids[(pid, name)] = i + 1

    for pid, pname in _PROCESS_NAME.items():
        events.append(
            {
                "name": "process_name",
                "ph": "M",
                "pid": pid,
                "tid": 0,
                "args": {"name": pname},
            }
        )
    for (pid, track), tid in sorted(tids.items(), key=lambda kv: (kv[0][0], kv[1])):
        events.append(
            {
                "name": "thread_name",
                "ph": "M",
                "pid": pid,
                "tid": tid,
                "args": {"name": track},
            }
        )

    for e in tel.tracer.events:
        pid = _CLOCK_PID.get(e["clock"], _SIM_PID)
        tid = tids[(pid, e["track"])]
        ts = e["t0"] * 1e6
        base = {"name": e["name"], "pid": pid, "tid": tid, "ts": ts}
        if e["kind"] == "span":
            events.append(
                {
                    **base,
                    "ph": "X",
                    "dur": max(e["t1"] - e["t0"], 0.0) * 1e6,
                    "args": e["args"],
                }
            )
        elif e["kind"] == "counter":
            events.append(
                {**base, "ph": "C", "args": {e["name"]: e["args"].get("value", 0.0)}}
            )
        else:  # instant
            events.append({**base, "ph": "i", "s": "t", "args": e["args"]})

    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "repro.metrics": tel.registry.summary(),
            "repro.dropped_events": tel.tracer.n_dropped,
        },
    }


def write_perfetto(tel: Telemetry, path: str | Path) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(to_perfetto(tel)))
    return path


def write_jsonl(tel: Telemetry, path: str | Path) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("w") as f:
        header = {
            "kind": "header",
            "format": "repro.telemetry/v1",
            "n_events": len(tel.tracer.events),
            "n_dropped_events": tel.tracer.n_dropped,
        }
        f.write(json.dumps(header) + "\n")
        for e in tel.tracer.events:
            f.write(json.dumps(e) + "\n")
        for row in tel.registry.rows():
            f.write(json.dumps({**row, "kind": f"metric.{row['kind']}"}) + "\n")
    return path


def write_trace(tel: Telemetry, path: str | Path) -> Path:
    """Write Perfetto JSON, or JSONL when the suffix is ``.jsonl``."""
    path = Path(path)
    if path.suffix == ".jsonl":
        return write_jsonl(tel, path)
    return write_perfetto(tel, path)


# -- loaders -----------------------------------------------------------------


def _from_perfetto(doc: dict[str, Any]) -> dict[str, Any]:
    """Fold a Perfetto document back into the common event/metric shape."""
    names: dict[tuple[int, int], str] = {}
    pnames: dict[int, str] = {}
    for e in doc.get("traceEvents", []):
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            names[(e["pid"], e["tid"])] = e["args"]["name"]
        elif e.get("ph") == "M" and e.get("name") == "process_name":
            pnames[e["pid"]] = e["args"]["name"]

    events: list[dict[str, Any]] = []
    for e in doc.get("traceEvents", []):
        ph = e.get("ph")
        if ph not in ("X", "i", "C"):
            continue
        clock = "wall" if pnames.get(e["pid"]) == "host" else "sim"
        t0 = e["ts"] / 1e6
        common = {
            "name": e["name"],
            "track": names.get((e["pid"], e["tid"]), f"tid{e.get('tid')}"),
            "clock": clock,
        }
        if ph == "X":
            events.append(
                {
                    "kind": "span",
                    **common,
                    "t0": t0,
                    "t1": t0 + e.get("dur", 0.0) / 1e6,
                    "args": e.get("args", {}),
                }
            )
        elif ph == "C":
            args = e.get("args", {})
            value = args.get(e["name"], next(iter(args.values()), 0.0))
            events.append(
                {
                    "kind": "counter",
                    **common,
                    "t0": t0,
                    "t1": t0,
                    "args": {"value": value},
                }
            )
        else:
            events.append(
                {
                    "kind": "instant",
                    **common,
                    "t0": t0,
                    "t1": t0,
                    "args": e.get("args", {}),
                }
            )
    metrics = doc.get("otherData", {}).get("repro.metrics", [])
    return {"events": events, "metrics": metrics}


def _from_jsonl(lines: list[dict[str, Any]]) -> dict[str, Any]:
    events = [r for r in lines if r.get("kind") in ("span", "instant", "counter")]
    metrics = [
        {**r, "kind": r["kind"][len("metric.") :]}
        for r in lines
        if str(r.get("kind", "")).startswith("metric.")
    ]
    return {"events": events, "metrics": metrics}


def load_trace(path: str | Path) -> dict[str, Any]:
    """Load either export format into ``{"events": [...], "metrics": [...]}``."""
    text = Path(path).read_text()
    stripped = text.lstrip()
    if stripped.startswith("{") and "\n{" not in stripped.rstrip():
        doc = json.loads(stripped)
        if "traceEvents" in doc:
            return _from_perfetto(doc)
        return _from_jsonl([doc])
    return _from_jsonl([json.loads(line) for line in text.splitlines() if line.strip()])


__all__ = [
    "load_trace",
    "to_perfetto",
    "write_jsonl",
    "write_perfetto",
    "write_trace",
]
