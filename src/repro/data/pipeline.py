"""Deterministic synthetic data pipelines.

Two kinds of streams:
* token streams for the LM zoo (structured synthetic language: enough
  statistical structure that loss decreases, fully deterministic per seed);
* federated task streams: per-agent shards of task-tagged batches, the LM
  analogue of the paper's imaging task-environments, consumable as ERBs by
  the LifelongTrainer.
"""

from __future__ import annotations

from collections.abc import Iterator, Sequence
from dataclasses import dataclass

import numpy as np

from repro.core.erb import ERB, ERBMeta, TaskTag, new_erb_id


@dataclass
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    # markov-ish structure: each "task" has its own transition flavor
    n_styles: int = 4


def _style_tokens(rng, vocab, seq, style):
    """Branching-walk tokens: style shifts the transition kernel so
    different tasks are statistically distinct (forgetting measurable)."""
    base = rng.integers(0, vocab, size=seq)
    walk = np.cumsum(rng.integers(-3 - style, 4 + style, size=seq))
    return (base + walk) % vocab


def token_batches(
    cfg: TokenStreamConfig, style: int = 0
) -> Iterator[dict[str, np.ndarray]]:
    rng = np.random.default_rng(cfg.seed + 7919 * style)
    while True:
        toks = np.stack(
            [
                _style_tokens(rng, cfg.vocab_size, cfg.seq_len + 1, style)
                for _ in range(cfg.batch_size)
            ]
        ).astype(np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def lm_task_erb(
    cfg: TokenStreamConfig,
    style: int,
    n_batches: int,
    *,
    source_agent: int = -1,
    round_idx: int = 0,
) -> ERB:
    """Materialize an LM 'task' as an ERB of (tokens, labels) rows —
    the supervised analogue of the paper's experience tuples."""
    it = token_batches(cfg, style)
    toks, labs = [], []
    for _ in range(n_batches):
        b = next(it)
        toks.append(b["tokens"])
        labs.append(b["labels"])
    data = {"tokens": np.concatenate(toks, 0), "labels": np.concatenate(labs, 0)}
    n = data["tokens"].shape[0]
    task = TaskTag(
        modality=f"style{style}",
        orientation="lm",
        pathology="none",
        landmark="next_token",
    )
    meta = ERBMeta(new_erb_id("LMERB"), task, source_agent, round_idx, n)
    erb = ERB(meta=meta, data=data, capacity=n, size=n, cursor=0)
    return erb


def federated_shards(
    cfg: TokenStreamConfig, n_agents: int
) -> Sequence[Iterator[dict[str, np.ndarray]]]:
    """Disjoint per-agent streams (different seeds + style rotation)."""
    return [
        token_batches(
            TokenStreamConfig(
                cfg.vocab_size,
                cfg.seq_len,
                cfg.batch_size,
                seed=cfg.seed + 104729 * a,
                n_styles=cfg.n_styles,
            ),
            style=a % cfg.n_styles,
        )
        for a in range(n_agents)
    ]
