"""Quickstart: the three layers of the framework in ~60 seconds on CPU.

1. model zoo   — one reduced config, one train step, one decode step
2. ADFLL core  — two agents share experience through a hub
3. kernels     — fused flash-attention vs its oracle

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.adfll_dqn import DQNConfig
from repro.configs.base import get_config
from repro.core.erb import TaskTag
from repro.core.hub import Hub
from repro.core.network import Network
from repro.models.model import build_model, init_caches
from repro.rl.agent import DQNAgent
from repro.rl.env import LandmarkEnv
from repro.rl.synth import make_volume

# ---------------------------------------------------------------- 1. zoo
cfg = get_config("qwen3-moe-235b-a22b-smoke")  # reduced MoE variant
model = build_model(cfg)
state = model.init_train_state(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
batch = {
    "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32),
    "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32),
}
state, metrics = jax.jit(model.train_step)(state, batch)
print(
    f"[zoo] {cfg.name}: loss={float(metrics['loss']):.3f} "
    f"aux={float(metrics['aux']):.3f}"
)
caches = init_caches(cfg, 2, 16)
logits, caches = jax.jit(model.serve_step)(
    state["params"],
    caches,
    {"tokens": jnp.zeros((2, 1), jnp.int32), "pos": jnp.zeros((2,), jnp.int32)},
)
print(f"[zoo] decode logits {logits.shape}")

# ------------------------------------------------------------- 2. ADFLL
dqn = DQNConfig(
    volume_shape=(16, 16, 16),
    box_size=(6, 6, 6),
    conv_features=(4,),
    hidden=(32,),
    max_episode_steps=12,
    batch_size=16,
)
task_a = TaskTag("t1", "axial", "HGG")
task_b = TaskTag("t2", "coronal", "LGG")
net = Network(hubs=[Hub(0)])
net.attach_agent(0)
net.attach_agent(1)
a0 = DQNAgent(0, dqn, seed=0)
a1 = DQNAgent(1, dqn, seed=1)
vol, lm = make_volume(task_a, 0, n=16)
shared, _ = a0.train_round(
    LandmarkEnv(vol, lm, dqn),
    task_a,
    (),
    erb_capacity=512,
    share_size=64,
    train_steps=20,
)
net.agent_push(0, shared)  # A0 -> hub
incoming = net.agent_pull(1, a1.seen_erb_ids)
vol, lm = make_volume(task_b, 1, n=16)
_, loss = a1.train_round(
    LandmarkEnv(vol, lm, dqn),
    task_b,
    incoming,
    erb_capacity=512,
    share_size=64,
    train_steps=20,
)
print(
    f"[adfll] agent1 trained on its task + {len(incoming)} foreign "
    f"ERB(s) from the hub, loss={loss:.4f}"
)

# -------------------------------------------------- 2b. weight plane
# Beyond the paper: the same hub can also carry FedAsync-style parameter
# snapshots, mixed with staleness-discounted rates alpha * s(dtau).
from repro.core.plane import WeightPlane, staleness_alphas

net.register_plane(WeightPlane(max_versions=2))
net.agent_push(0, a0.snapshot_params(sim_time=1.0), plane="weights")
snaps = net.agent_pull(1, a1.seen_snap_ids, plane="weights")
alphas = staleness_alphas(snaps, a1.rounds_done, alpha=0.5, flag="poly")
n = a1.mix_params(snaps, alphas)
print(
    f"[adfll] agent1 mixed {n} peer weight snapshot(s), "
    f"alpha={[round(float(a), 3) for a in alphas]}"
)

# ------------------------------------------------------------ 3. kernels
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

q = jnp.asarray(rng.standard_normal((1, 128, 4, 32)), jnp.float32)
k = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.float32)
v = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.float32)
out = flash_attention(q, k, v, block_q=64, block_k=64)
err = float(jnp.abs(out - attention_ref(q, k, v)).max())
print(f"[kernels] flash attention (interpret) max err vs oracle: {err:.2e}")
print("done.")
