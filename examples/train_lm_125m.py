"""End-to-end LM training driver: the FULL xlstm-125m config (125M params)
for a few hundred steps on the synthetic pipeline.

This is real training of a real-scale model on CPU — expect minutes to
hours depending on --steps; use --steps 20 for a quick check. On a TPU
pod the identical entry point runs under the production mesh via
``repro.launch.train --production-mesh``.

    PYTHONPATH=src python examples/train_lm_125m.py --steps 300 --batch 4
"""

import argparse

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()
    train_driver.main(
        [
            "--arch",
            "xlstm-125m",
            "--steps",
            str(args.steps),
            "--batch",
            str(args.batch),
            "--seq",
            str(args.seq),
            "--ckpt",
            "experiments/xlstm125m_params.npz",
        ]
    )


if __name__ == "__main__":
    main()
