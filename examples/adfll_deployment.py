"""End-to-end driver: the paper's deployment experiment (Fig. 2).

Four DQN agents (two fast "V100", two slow "T4"), three hubs,
asynchronous rounds over the 8 BraTS-like task-environments, compared
against Agent X / Y / M — the full Table 1 pipeline at a CPU-tractable
scale. Expect a few minutes of wall time.

    PYTHONPATH=src python examples/adfll_deployment.py [--fast]
"""
import argparse

from benchmarks import deployment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    means, best = deployment.run(seed=0, fast=args.fast)
    print("\nsummary:")
    for name, m in sorted(means.items(), key=lambda kv: kv[1]):
        marker = " <- best ADFLL agent" if name == best else ""
        print(f"  {name:8s} mean distance error {m:6.2f}{marker}")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, ".")
    main()
