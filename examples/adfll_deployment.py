"""End-to-end driver: the paper's deployment experiment (Fig. 2).

Everything runs through the declarative scenario API: ``paper_fig2`` is
four DQN agents (two fast "V100", two slow "T4") on three hubs running
asynchronous rounds over the 8 BraTS-like task-environments, and the
``baseline_*`` scenarios are Agent X / Y / M — the full Table 1 pipeline
at a CPU-tractable scale. Expect a few minutes of wall time.

    PYTHONPATH=src python examples/adfll_deployment.py [--fast]
    PYTHONPATH=src python examples/adfll_deployment.py --scenario gossip_hetero
    PYTHONPATH=src python -m repro.experiments --list
"""

import argparse

from repro import experiments

BASELINES = ("baseline_all_knowing", "baseline_partial", "baseline_sequential")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument(
        "--scenario",
        default="paper_fig2",
        help="any scenario from `python -m repro.experiments --list`",
    )
    args = ap.parse_args()

    report = experiments.run(args.scenario, fast=args.fast)
    scenario_means = report.agent_means()
    means = dict(scenario_means)
    if args.scenario == "paper_fig2":  # add the Table-1 comparison rows
        for name in BASELINES:
            means.update(experiments.run(name, fast=args.fast).agent_means())

    print(f"\nscenario {args.scenario}: sim makespan {report.makespan:.2f}")
    print("summary:")
    best = None
    if report.system == "adfll":
        best = min(scenario_means, key=scenario_means.get)
    for name, m in sorted(means.items(), key=lambda kv: kv[1]):
        marker = " <- best ADFLL agent" if name == best else ""
        print(f"  {name:8s} mean distance error {m:6.2f}{marker}")


if __name__ == "__main__":
    main()
