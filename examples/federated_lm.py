"""ADFLL beyond the paper: federated lifelong learning of TRANSFORMERS.

The paper's mechanism is experience-level, hence architecture-agnostic.
Here three agents — each running a *different* zoo architecture (dense,
MoE, xLSTM; heterogeneity no weight-averaging scheme could support) —
train on disjoint synthetic text styles and share LM ERBs through a hub.
Replay of foreign ERBs reduces per-style loss on styles an agent never
saw natively, and protects against forgetting its own style.

    PYTHONPATH=src python examples/federated_lm.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.hub import Hub
from repro.core.lifelong import LifelongTrainer
from repro.core.network import Network
from repro.data.pipeline import TokenStreamConfig, lm_task_erb
from repro.launch.specs import opt_cfg_for
from repro.models.model import init_train_state, make_loss_fn, make_train_step

ARCHS = ["h2o-danube-3-4b-smoke", "qwen3-moe-235b-a22b-smoke", "xlstm-125m-smoke"]
VOCAB = 512
SEQ = 64
STEPS_PER_ROUND = 25


def build_agent(arch, seed):
    cfg = get_config(arch)
    opt = opt_cfg_for(cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(seed), opt)
    raw_step = jax.jit(make_train_step(cfg, opt))
    loss_fn = jax.jit(make_loss_fn(cfg))

    def np_step(state, batch):
        batch = {k: jnp.asarray(v % cfg.vocab_size) for k, v in batch.items()}
        return raw_step(state, batch)

    tr = LifelongTrainer(np_step, state, batch_size=8, rng=np.random.default_rng(seed))
    return cfg, tr, loss_fn


def eval_style(cfg, loss_fn, params, style):
    sc = TokenStreamConfig(VOCAB, SEQ, 16, seed=999, n_styles=4)
    erb = lm_task_erb(sc, style=style, n_batches=1)
    batch = {k: jnp.asarray(v % cfg.vocab_size) for k, v in erb.data.items()}
    _, m = loss_fn(params, batch)
    return float(m["loss"])


def main():
    net = Network(hubs=[Hub(0), Hub(1)])
    agents = []
    for i, arch in enumerate(ARCHS):
        net.attach_agent(i)
        agents.append(build_agent(arch, seed=i))
    sc = TokenStreamConfig(VOCAB, SEQ, 8, seed=0, n_styles=4)

    print("round 0: every agent trains its own style, shares its ERB")
    for i, (cfg, tr, _) in enumerate(agents):
        erb = lm_task_erb(sc, style=i, n_batches=8, source_agent=i)
        tr.steps(STEPS_PER_ROUND, erb)
        shared = erb  # LM ERBs are already a selective slice
        net.agent_push(i, shared)
    net.sync()

    print("round 1: agents pull foreign ERBs and lifelong-learn them")
    for i, (cfg, tr, _) in enumerate(agents):
        incoming = net.agent_pull(i, tr.seen_erb_ids)
        erb = lm_task_erb(sc, style=i, n_batches=8, source_agent=i)
        tr.steps(STEPS_PER_ROUND, erb, incoming=incoming)
        print(
            f"  agent{i} ({cfg.name}): learned from {len(incoming)} "
            f"foreign ERBs"
        )

    print("\nper-style eval loss (rows: agents/archs, cols: styles):")
    for i, (cfg, tr, loss_fn) in enumerate(agents):
        row = [
            eval_style(cfg, loss_fn, tr.state["params"], s) for s in range(len(ARCHS))
        ]
        own = row[i]
        print(
            f"  {cfg.name:32s} "
            + " ".join(f"{x:6.3f}" for x in row)
            + f"   (own style: {own:.3f})"
        )
    print(
        "\nheterogeneous architectures, one federation — no weight "
        "averaging involved."
    )


if __name__ == "__main__":
    main()
